//! The paper's Table-5 ablation in miniature: every rounding function on
//! the same model/bits, demonstrating the ordering
//! Ours > AdaRound > Nearest > Stochastic ≫ Floor/Ceil.
//!
//! Runs on any checkout (PJRT with artifacts, host backend without).
//!
//! ```bash
//! cargo run --release --example rounding_comparison
//! ```

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::experiments::Ctx;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::quant::rounding::Rounding;
use attention_round::report::Table;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = Ctx::auto(&artifacts, CalibConfig::quick(), "results")?;
    let model_name =
        ctx.primary_model(std::env::var("REPRO_MODEL").ok().as_deref())?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;

    let mut table = Table::new(
        format!("Rounding functions, {model_name} 4/32 [{}]", ctx.backend.name()),
        &["Rounding", "Top-1 %", "Wall s"],
    );
    for method in [
        Rounding::Floor,
        Rounding::Ceil,
        Rounding::Stochastic,
        Rounding::Nearest,
        Rounding::AdaRound,
        Rounding::Attention,
    ] {
        let mut cfg = ctx.cfg.clone();
        cfg.method = method;
        let out = quantize_and_eval(
            ctx.backend.as_ref(),
            &ctx.manifest,
            &QuantSpec {
                model: model_name.clone(),
                wbits: resolve_uniform_bits(&model, 4),
                abits: None,
            },
            &cfg,
            &ctx.calib,
            &ctx.eval,
        )?;
        table.row(vec![
            method.name().to_string(),
            format!("{:.2}", out.acc * 100.0),
            format!("{:.1}", out.wall_s),
        ]);
    }
    println!("{}", table.render());
    println!("(FP32 reference: {:.2}%)", model.info.fp_acc * 100.0);
    Ok(())
}
