//! The paper's Table-5 ablation in miniature: every rounding function on
//! the same model/bits, demonstrating the ordering
//! Ours > AdaRound > Nearest > Stochastic ≫ Floor/Ceil.
//!
//! ```bash
//! cargo run --release --example rounding_comparison
//! ```

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::model::LoadedModel;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::data::Split;
use attention_round::io::manifest::Manifest;
use attention_round::quant::rounding::Rounding;
use attention_round::report::Table;
use attention_round::runtime::Runtime;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    let rt = Runtime::new(artifacts.as_str())?;
    let model = LoadedModel::load(&manifest, "resnet18t")?;
    let data_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&data_dir, "calib")?;
    let eval = Split::load(&data_dir, "eval")?;

    let mut table = Table::new(
        "Rounding functions, resnet18t 4/32",
        &["Rounding", "Top-1 %", "Wall s"],
    );
    for method in [
        Rounding::Floor,
        Rounding::Ceil,
        Rounding::Stochastic,
        Rounding::Nearest,
        Rounding::AdaRound,
        Rounding::Attention,
    ] {
        let mut cfg = CalibConfig::quick();
        cfg.method = method;
        let out = quantize_and_eval(
            &rt,
            &manifest,
            &QuantSpec {
                model: model.info.name.clone(),
                wbits: resolve_uniform_bits(&model, 4),
                abits: None,
            },
            &cfg,
            &calib,
            &eval,
        )?;
        table.row(vec![
            method.name().to_string(),
            format!("{:.2}", out.acc * 100.0),
            format!("{:.1}", out.wall_s),
        ]);
    }
    println!("{}", table.render());
    println!("(FP32 reference: {:.2}%)", model.info.fp_acc * 100.0);
    Ok(())
}
