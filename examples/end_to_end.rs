//! END-TO-END DRIVER (the repo's required full-system validation).
//!
//! Exercises every layer of the stack on a real workload, proving they
//! compose — on **either execution backend**:
//!
//! * with built artifacts: L1 Pallas kernels → L2 JAX calib graphs →
//!   AOT HLO → PJRT → L3 Rust pipeline;
//! * without artifacts (any bare checkout, CI): the pure-host backend
//!   runs the same pipeline natively against the in-memory synthetic
//!   model — zero files needed.
//!
//! 1. FP32 baseline evaluation.
//! 2. Weight-only 4-bit PTQ with Attention Round (1,024-image
//!    calibration, per-module Adam — the paper's headline configuration)
//!    vs the Nearest baseline.
//! 3. Weights + activations 4/4.
//! 4. Mixed-precision Algorithm-1 allocation at [3,4,5,6].
//! 5. Throughput + phase timing report (feeds EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example end_to_end          # host backend
//! make artifacts && cargo run --release --example end_to_end  # PJRT
//! ```

use std::time::Instant;

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::evaluate::evaluate;
use attention_round::coordinator::experiments::Ctx;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::mixed;
use attention_round::quant::rounding::Rounding;
use attention_round::report::Table;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let t_start = Instant::now();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let have_artifacts =
        std::path::Path::new(&artifacts).join("manifest.json").exists();

    let mut cfg = CalibConfig::quick();
    if !have_artifacts {
        // host-backend toy model: a smaller Adam budget already converges
        // and keeps the CI job brisk
        cfg.iters = 64;
    }
    let ctx = Ctx::auto(&artifacts, cfg.clone(), "results")?;
    let model_name =
        ctx.primary_model(std::env::var("REPRO_MODEL").ok().as_deref())?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;
    println!(
        "== end-to-end: {} ({} layers, {} params) on {} [{} backend] ==",
        model_name,
        model.num_layers(),
        model.total_params(),
        ctx.backend.platform(),
        ctx.backend.name(),
    );

    let mut table = Table::new(
        format!("End-to-end results — {model_name}"),
        &["Stage", "Bits(W/A)", "Top-1 %", "Wall s"],
    );

    // 1. FP32 baseline (re-measured through the backend, not trusted
    //    from the manifest).
    let t0 = Instant::now();
    let fp_acc = evaluate(
        ctx.backend.as_ref(), &ctx.manifest, &model, &model.weights, &ctx.eval,
    )?;
    table.row(vec![
        "FP32 eval".into(),
        "32/32".into(),
        format!("{:.2}", fp_acc * 100.0),
        format!("{:.1}", t0.elapsed().as_secs_f64()),
    ]);
    let drift = (fp_acc - model.info.fp_acc).abs();
    assert!(
        drift < 0.01,
        "backend eval drifted {drift} from the recorded accuracy — artifact mismatch?"
    );

    // 2. 4-bit weights: Nearest baseline vs Attention Round.
    for (label, method) in [
        ("Nearest PTQ", Rounding::Nearest),
        ("Attention Round PTQ", Rounding::Attention),
    ] {
        let mut c = cfg.clone();
        c.method = method;
        let out = quantize_and_eval(
            ctx.backend.as_ref(),
            &ctx.manifest,
            &QuantSpec {
                model: model_name.clone(),
                wbits: resolve_uniform_bits(&model, 4),
                abits: None,
            },
            &c,
            &ctx.calib,
            &ctx.eval,
        )?;
        table.row(vec![
            label.into(),
            "4/32".into(),
            format!("{:.2}", out.acc * 100.0),
            format!("{:.1}", out.wall_s),
        ]);
    }

    // 3. Weights + activations.
    let out44 = quantize_and_eval(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: resolve_uniform_bits(&model, 4),
            abits: Some(4),
        },
        &cfg,
        &ctx.calib,
        &ctx.eval,
    )?;
    table.row(vec![
        "Attention Round PTQ".into(),
        "4/4".into(),
        format!("{:.2}", out44.acc * 100.0),
        format!("{:.1}", out44.wall_s),
    ]);

    // 4. Mixed precision via Algorithm 1.
    let alloc = mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6], 1e-3)?;
    let out_mixed = quantize_and_eval(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: alloc.bits.clone(),
            abits: None,
        },
        &cfg,
        &ctx.calib,
        &ctx.eval,
    )?;
    table.row(vec![
        format!("Mixed [3,4,5,6] ({})", mixed::format_size_mb(alloc.size_bytes)),
        "mixed/32".into(),
        format!("{:.2}", out_mixed.acc * 100.0),
        format!("{:.1}", out_mixed.wall_s),
    ]);

    println!("{}", table.render());
    println!("--- pipeline metrics ---\n{}", ctx.backend.metrics().report());
    println!("total wall: {:.1}s", t_start.elapsed().as_secs_f64());

    // Invariants this driver asserts (the "does it compose" signal):
    let rows: Vec<f64> = vec![fp_acc, out44.acc, out_mixed.acc];
    assert!(rows.iter().all(|&a| a.is_finite() && a > 1.0 / 16.0),
        "every stage must beat random chance");
    Ok(())
}
