//! END-TO-END DRIVER (the repo's required full-system validation).
//!
//! Exercises every layer of the stack on a real workload, proving they
//! compose:
//!
//!   L1 Pallas fake-quant/erf kernels ──lowered into──► L2 JAX calib
//!   graphs ──AOT──► HLO text ──PJRT──► L3 Rust pipeline:
//!
//! 1. FP32 baseline evaluation (2,048 held-out images).
//! 2. Weight-only 4-bit PTQ with Attention Round (1,024-image
//!    calibration, per-module Adam — the paper's headline configuration)
//!    vs the Nearest baseline.
//! 3. Weights + activations 4/4.
//! 4. Mixed-precision Algorithm-1 allocation at [3,4,5,6].
//! 5. Throughput + phase timing report (feeds EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::evaluate::evaluate;
use attention_round::coordinator::model::LoadedModel;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::data::Split;
use attention_round::io::manifest::Manifest;
use attention_round::mixed;
use attention_round::quant::rounding::Rounding;
use attention_round::report::Table;
use attention_round::runtime::Runtime;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let t_start = Instant::now();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let model_name =
        std::env::var("REPRO_MODEL").unwrap_or_else(|_| "resnet18t".into());

    let manifest = Manifest::load(&artifacts)?;
    let rt = Runtime::new(artifacts.as_str())?;
    let model = LoadedModel::load(&manifest, &model_name)?;
    let data_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&data_dir, "calib")?;
    let eval = Split::load(&data_dir, "eval")?;
    println!(
        "== end-to-end: {} ({} layers, {} params) on {} ==",
        model_name,
        model.num_layers(),
        model.total_params(),
        rt.platform()
    );

    let mut table = Table::new(
        format!("End-to-end results — {model_name}"),
        &["Stage", "Bits(W/A)", "Top-1 %", "Wall s"],
    );

    // 1. FP32 baseline (re-measured through the PJRT path, not trusted
    //    from the manifest).
    let t0 = Instant::now();
    let fp_acc = evaluate(&rt, &manifest, &model, &model.weights, &eval)?;
    table.row(vec![
        "FP32 eval".into(),
        "32/32".into(),
        format!("{:.2}", fp_acc * 100.0),
        format!("{:.1}", t0.elapsed().as_secs_f64()),
    ]);
    let drift = (fp_acc - model.info.fp_acc).abs();
    assert!(
        drift < 0.01,
        "PJRT eval drifted {drift} from the build-time accuracy — artifact mismatch?"
    );

    // 2. 4-bit weights: Nearest baseline vs Attention Round.
    let cfg = CalibConfig::quick();
    for (label, method) in [
        ("Nearest PTQ", Rounding::Nearest),
        ("Attention Round PTQ", Rounding::Attention),
    ] {
        let mut c = cfg.clone();
        c.method = method;
        let out = quantize_and_eval(
            &rt,
            &manifest,
            &QuantSpec {
                model: model_name.clone(),
                wbits: resolve_uniform_bits(&model, 4),
                abits: None,
            },
            &c,
            &calib,
            &eval,
        )?;
        table.row(vec![
            label.into(),
            "4/32".into(),
            format!("{:.2}", out.acc * 100.0),
            format!("{:.1}", out.wall_s),
        ]);
    }

    // 3. Weights + activations.
    let out44 = quantize_and_eval(
        &rt,
        &manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: resolve_uniform_bits(&model, 4),
            abits: Some(4),
        },
        &cfg,
        &calib,
        &eval,
    )?;
    table.row(vec![
        "Attention Round PTQ".into(),
        "4/4".into(),
        format!("{:.2}", out44.acc * 100.0),
        format!("{:.1}", out44.wall_s),
    ]);

    // 4. Mixed precision via Algorithm 1.
    let alloc = mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6], 1e-3)?;
    let out_mixed = quantize_and_eval(
        &rt,
        &manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: alloc.bits.clone(),
            abits: None,
        },
        &cfg,
        &calib,
        &eval,
    )?;
    table.row(vec![
        format!("Mixed [3,4,5,6] ({})", mixed::format_size_mb(alloc.size_bytes)),
        "mixed/32".into(),
        format!("{:.2}", out_mixed.acc * 100.0),
        format!("{:.1}", out_mixed.wall_s),
    ]);

    println!("{}", table.render());
    println!("--- pipeline metrics ---\n{}", rt.metrics.report());
    println!("total wall: {:.1}s", t_start.elapsed().as_secs_f64());

    // Invariants this driver asserts (the "does it compose" signal):
    let rows: Vec<f64> = vec![fp_acc, out44.acc, out_mixed.acc];
    assert!(rows.iter().all(|&a| a.is_finite() && a > 1.0 / 16.0),
        "every stage must beat random chance");
    Ok(())
}
