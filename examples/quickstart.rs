//! Quickstart: quantize one model to 4-bit weights with Attention Round
//! and report top-1 before/after.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::model::LoadedModel;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::data::Split;
use attention_round::io::manifest::Manifest;
use attention_round::runtime::Runtime;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // 1. Load the artifact manifest and the PJRT runtime.
    let manifest = Manifest::load(&artifacts)?;
    let rt = Runtime::new(artifacts.as_str())?;
    println!("PJRT platform: {}", rt.platform());

    // 2. Pick a model and the calibration data (1,024 images, as in §4.1).
    let model = LoadedModel::load(&manifest, "resnet18t")?;
    let data_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&data_dir, "calib")?;
    let eval = Split::load(&data_dir, "eval")?;

    // 3. Quantize: 4-bit weights everywhere except the 8-bit-pinned
    //    first/last layers, activations left in FP32.
    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&model, 4),
        abits: None,
    };
    let cfg = CalibConfig::quick(); // 200 Adam iters/module; `paper` = 2k
    let out = quantize_and_eval(&rt, &manifest, &spec, &cfg, &calib, &eval)?;

    println!(
        "resnet18t 4/32 Attention Round: top-1 {:.2}% (FP32 {:.2}%) in {:.1}s",
        out.acc * 100.0,
        out.fp_acc * 100.0,
        out.wall_s
    );
    for l in out.per_layer.iter().take(4) {
        println!(
            "  {:<12} {}b scale={:.5} recon loss {:.2e} -> {:.2e}",
            l.name, l.bits, l.scale, l.first_loss, l.last_loss
        );
    }
    println!("  ... ({} layers total)", out.per_layer.len());
    Ok(())
}
