//! Quickstart: quantize one model to 4-bit weights with Attention Round
//! and report top-1 before/after.
//!
//! Runs on any checkout: with built artifacts it uses the PJRT backend,
//! otherwise the pure-host backend + synthetic model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::experiments::Ctx;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // 1. Build an experiment context: backend + manifest + data splits.
    //    `auto` picks PJRT when artifacts exist, the host backend else.
    let cfg = CalibConfig::quick(); // 200 Adam iters/module; `paper` = 2k
    let ctx = Ctx::auto(&artifacts, cfg.clone(), "results")?;
    println!("backend: {} ({})", ctx.backend.name(), ctx.backend.platform());

    // 2. Pick a model; calibration uses 1,024 images as in §4.1.
    let model_name =
        ctx.primary_model(std::env::var("REPRO_MODEL").ok().as_deref())?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;

    // 3. Quantize: 4-bit weights everywhere except the 8-bit-pinned
    //    first/last layers, activations left in FP32.
    let spec = QuantSpec {
        model: model_name.clone(),
        wbits: resolve_uniform_bits(&model, 4),
        abits: None,
    };
    let out = quantize_and_eval(
        ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg, &ctx.calib, &ctx.eval,
    )?;

    println!(
        "{model_name} 4/32 Attention Round: top-1 {:.2}% (FP32 {:.2}%) in {:.1}s",
        out.acc * 100.0,
        out.fp_acc * 100.0,
        out.wall_s
    );
    for l in out.per_layer.iter().take(4) {
        println!(
            "  {:<12} {}b scale={:.5} recon loss {:.2e} -> {:.2e}",
            l.name, l.bits, l.scale, l.first_loss, l.last_loss
        );
    }
    println!("  ... ({} layers total)", out.per_layer.len());
    Ok(())
}
