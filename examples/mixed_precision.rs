//! Mixed-precision allocation (paper §3.4, Algorithm 1) walkthrough:
//! compute per-layer coding lengths, cluster them onto a bit list, then
//! calibrate + evaluate the mixed model against single-precision at the
//! same size budget.
//!
//! Runs on any checkout (PJRT with artifacts, host backend without).
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::experiments::Ctx;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::mixed;
use attention_round::util::logging;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    logging::init();
    let artifacts = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = Ctx::auto(&artifacts, CalibConfig::quick(), "results")?;
    let model_name =
        ctx.primary_model(std::env::var("REPRO_MODEL").ok().as_deref())?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;

    // Algorithm 1: coding length per layer -> 1-D k-means -> bit list.
    let bit_list = [3u8, 4, 5, 6];
    let alloc = mixed::allocate(&model.info.layers, &model.weights, &bit_list, 1e-3)?;
    println!("Algorithm 1 allocation (ε²=1e-3) [{}]:", ctx.backend.name());
    for (l, (&bits, &len)) in model
        .info
        .layers
        .iter()
        .zip(alloc.bits.iter().zip(alloc.lengths.iter()))
    {
        println!(
            "  {:<16} L(W)={:>8.1} bits -> {}b{}",
            l.name,
            len,
            bits,
            if l.downsample {
                "  (downsample, narrowest — §4.5.3)"
            } else {
                ""
            }
        );
    }
    println!("mixed model size: {}", mixed::format_size_mb(alloc.size_bytes));

    let cfg = ctx.cfg.clone();
    let mixed_out = quantize_and_eval(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: alloc.bits.clone(),
            abits: None,
        },
        &cfg,
        &ctx.calib,
        &ctx.eval,
    )?;

    // single-precision 4-bit reference at a similar size
    let single = mixed::uniform_allocation(&model.info.layers, 4);
    let single_out = quantize_and_eval(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &QuantSpec {
            model: model_name.clone(),
            wbits: resolve_uniform_bits(&model, 4),
            abits: None,
        },
        &cfg,
        &ctx.calib,
        &ctx.eval,
    )?;

    println!(
        "mixed {:?}: {:.2}% @ {}   |   single 4b: {:.2}% @ {}   (FP {:.2}%)",
        bit_list,
        mixed_out.acc * 100.0,
        mixed::format_size_mb(alloc.size_bytes),
        single_out.acc * 100.0,
        mixed::format_size_mb(single.size_bytes),
        mixed_out.fp_acc * 100.0
    );
    Ok(())
}
