//! Fixture: `#[target_feature]` without a scalar sibling (AR002).

/// SAFETY: caller must ensure AVX support and a non-empty slice.
#[target_feature(enable = "avx")]
pub unsafe fn sum_avx(xs: &[f32]) -> f32 {
    // SAFETY: caller contract above.
    unsafe { *xs.as_ptr() }
}
