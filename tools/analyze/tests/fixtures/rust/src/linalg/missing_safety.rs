//! Fixture: `unsafe` without a SAFETY argument (AR001).

pub fn read_first(p: *const f32) -> f32 {
    unsafe { *p }
}

/// SAFETY: caller passes a valid, aligned, readable pointer.
pub unsafe fn read_ok(p: *const f32) -> f32 {
    // SAFETY: caller contract above.
    unsafe { *p }
}
