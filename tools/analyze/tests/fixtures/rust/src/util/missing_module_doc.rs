// A line comment is not a module doc.
pub fn noop() {}
