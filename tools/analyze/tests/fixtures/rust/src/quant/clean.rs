//! Fixture: a clean hot-path file — every rule passes.

/// Typed-error style: no unwrap/expect outside tests.
pub fn safe_div(a: f32, b: f32) -> Option<f32> {
    if b == 0.0 {
        None
    } else {
        Some(a / b)
    }
}

pub fn waived_unwrap(x: Option<u32>) -> u32 {
    // analyzer: allow(AR003): fixture exercising a justified waiver.
    x.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(safe_div(4.0, 2.0).unwrap(), 2.0);
    }
}
