//! Fixture: forbidden APIs in a kernel hot path (AR003).

pub fn hot(x: Option<f32>) -> f32 {
    let v = x.unwrap();
    let w = x.expect("present");
    let _t = std::time::Instant::now();
    std::process::exit((v + w) as i32);
}

pub fn spawns() {
    let h = std::thread::spawn(|| 1);
    let _ = h;
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let _ = Some(1).unwrap();
    }
}
