//! Analyzer acceptance tests: every rule trips on its seeded fixture at
//! the exact `file:line`, the clean fixture and the real repo tree scan
//! clean, and the binary's exit codes match the CI contract.

use std::path::{Path, PathBuf};
use std::process::Command;

use analyze::{scan_source, scan_tree, Rule};

fn fixtures_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn repo_root() -> PathBuf {
    // tools/analyze -> tools -> repo root
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("repo root above tools/analyze")
        .to_path_buf()
}

fn scan_fixture(rel: &str) -> Vec<analyze::Violation> {
    let src = std::fs::read_to_string(fixtures_root().join(rel))
        .unwrap_or_else(|e| panic!("read fixture {rel}: {e}"));
    scan_source(rel, &src)
}

#[test]
fn forbidden_api_fixture_trips_ar003_at_exact_lines() {
    let v = scan_fixture("rust/src/quant/forbidden_api.rs");
    assert!(
        v.iter().all(|x| x.rule == Rule::ForbiddenApi),
        "only AR003 expected, got {v:?}"
    );
    let lines: Vec<usize> = v.iter().map(|x| x.line).collect();
    assert_eq!(
        lines,
        vec![4, 5, 6, 7, 11],
        "unwrap, expect, Instant::now, process::exit, thread::spawn: {v:?}"
    );
    assert!(v.iter().all(|x| x.rule.id() == "AR003"));
}

#[test]
fn missing_safety_fixture_trips_ar001_once() {
    let v = scan_fixture("rust/src/linalg/missing_safety.rs");
    assert_eq!(v.len(), 1, "exactly the uncommented unsafe block: {v:?}");
    assert_eq!(v[0].rule, Rule::UnsafeNeedsSafety);
    assert_eq!(v[0].rule.id(), "AR001");
    assert_eq!(v[0].line, 4);
}

#[test]
fn no_scalar_sibling_fixture_trips_ar002_at_fn_line() {
    let v = scan_fixture("rust/src/linalg/no_scalar_sibling.rs");
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::SimdScalarSibling);
    assert_eq!(v[0].rule.id(), "AR002");
    assert_eq!(v[0].line, 5, "reported at the #[target_feature] fn");
}

#[test]
fn missing_module_doc_fixture_trips_ar004() {
    let v = scan_fixture("rust/src/util/missing_module_doc.rs");
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::ModuleDoc);
    assert_eq!(v[0].rule.id(), "AR004");
    assert_eq!(v[0].line, 1);
}

#[test]
fn clean_fixture_scans_clean() {
    let v = scan_fixture("rust/src/quant/clean.rs");
    assert!(v.is_empty(), "clean fixture must pass every rule: {v:?}");
}

#[test]
fn fixture_tree_scan_finds_every_seeded_rule() {
    let (v, files) = scan_tree(&fixtures_root()).expect("scan fixtures");
    assert_eq!(files, 5, "five fixture files");
    for rule in [
        Rule::UnsafeNeedsSafety,
        Rule::SimdScalarSibling,
        Rule::ForbiddenApi,
        Rule::ModuleDoc,
    ] {
        assert!(
            v.iter().any(|x| x.rule == rule),
            "rule {} not tripped by fixtures: {v:?}",
            rule.id()
        );
    }
}

#[test]
fn repo_tree_scans_clean() {
    let root = repo_root();
    assert!(
        root.join("rust/src").is_dir(),
        "repo root misresolved: {root:?}"
    );
    let (v, files) = scan_tree(&root).expect("scan repo");
    assert!(files > 40, "expected the full source tree, saw {files} files");
    assert!(
        v.is_empty(),
        "the swept repo must scan clean; violations:\n{}",
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn binary_exits_nonzero_on_fixture_violations_and_zero_on_repo() {
    let bad = Command::new(env!("CARGO_BIN_EXE_analyze"))
        .arg("--root")
        .arg(fixtures_root())
        .output()
        .expect("run analyze on fixtures");
    assert!(
        !bad.status.success(),
        "seeded violations must exit nonzero; stdout: {}",
        String::from_utf8_lossy(&bad.stdout)
    );
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("AR001"), "report names rule IDs: {stdout}");
    assert!(
        stdout.contains("rust/src/linalg/missing_safety.rs:4:"),
        "report carries file:line spans: {stdout}"
    );

    let good = Command::new(env!("CARGO_BIN_EXE_analyze"))
        .arg("--root")
        .arg(repo_root())
        .output()
        .expect("run analyze on repo");
    assert!(
        good.status.success(),
        "swept repo must exit zero; stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&good.stdout),
        String::from_utf8_lossy(&good.stderr)
    );
}
