//! CLI for the repo-invariant analyzer (`cargo run -p analyze`).
//!
//! Modes:
//!
//! * no operands — scan the default tree (`rust/src` + the analyzer's
//!   own source) under the repo root, which is found by walking up from
//!   the current directory until a `rust/src` appears;
//! * `--root DIR` — use `DIR` as the repo root (a fixture tree in tests,
//!   a worktree in CI);
//! * explicit file/dir operands — scan just those, reported relative to
//!   the root when they live under it.
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage or IO error. CI
//! treats this binary as a blocking gate, so the output format —
//! `path:line: ARnnn (rule-name): message` — is stable.

use std::path::PathBuf;
use std::process::ExitCode;

use analyze::{scan_paths, scan_tree, ALL_RULES, DEFAULT_SCAN_DIRS};

/// Walk up from `start` to the first directory containing `rust/src`.
fn find_root(start: PathBuf) -> Option<PathBuf> {
    let mut dir = start;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn usage() {
    eprintln!("usage: analyze [--root DIR] [--list-rules] [paths...]");
    eprintln!("  scans {} for invariant violations", DEFAULT_SCAN_DIRS.join(" and "));
}

fn main() -> ExitCode {
    let mut root_arg: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(d) => root_arg = Some(PathBuf::from(d)),
                None => {
                    usage();
                    return ExitCode::from(2);
                }
            },
            "--list-rules" => {
                for r in ALL_RULES {
                    println!("{} {}", r.id(), r.name());
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            _ => paths.push(PathBuf::from(a)),
        }
    }

    let cwd = match std::env::current_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("analyze: cannot read current dir: {e}");
            return ExitCode::from(2);
        }
    };
    let root = match root_arg.or_else(|| find_root(cwd)) {
        Some(r) => r,
        None => {
            eprintln!("analyze: no repo root (a directory containing rust/src) found; use --root");
            return ExitCode::from(2);
        }
    };

    let scanned = if paths.is_empty() {
        scan_tree(&root)
    } else {
        scan_paths(&root, &paths)
    };
    let (violations, files) = match scanned {
        Ok(r) => r,
        Err(e) => {
            eprintln!("analyze: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!(
            "analyze: OK — {files} file(s) clean under {} rule(s)",
            ALL_RULES.len()
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "analyze: {} violation(s) across {files} scanned file(s)",
            violations.len()
        );
        ExitCode::FAILURE
    }
}
