//! Repo-invariant static analyzer: the soundness gate behind
//! `cargo run -p analyze`.
//!
//! The repo's core contract — Eq.-3/Eq.-6 kernels and the packed-serving
//! path stay bit-identical across scalar/AVX/SSE2 dispatch, thread
//! counts, and fused vs. unfused execution — is sampled by property
//! tests but *proved* nowhere. This crate enforces the structural half
//! of that contract statically, as typed `file:line` violations:
//!
//! * **AR001 `unsafe-needs-safety`** — every `unsafe` block / `unsafe fn`
//!   / `unsafe impl` carries a `SAFETY:` comment (same line, first line
//!   of the block, or in the comment/attribute run directly above).
//! * **AR002 `simd-scalar-sibling`** — every `#[target_feature]` item
//!   has a scalar sibling in the same file (a `*_scalar` fn sharing its
//!   name stem), and any file gated on `feature = "simd"` defines at
//!   least one `*_scalar` fallback. This is the bit-identity pairing in
//!   `linalg/simd.rs` and `quant/kernel.rs`: the vector path can never
//!   exist without the reference it is property-tested against.
//! * **AR003 `forbidden-api`** — outside tests and bins: no
//!   `unwrap()`/`expect()` in the kernel hot paths (`quant/`, `linalg/`,
//!   `deploy/`, `tensor/` — typed errors only), no `std::process::exit`
//!   outside `main.rs`, no `Instant::now` inside `quant`/`linalg`/
//!   `deploy` kernels (time-dependent kernels cannot be bit-identical),
//!   and no bare `thread::spawn` bypassing the width-capped pool
//!   (`util/threadpool.rs` is the only sanctioned spawner).
//! * **AR004 `module-doc`** — every module file opens with a `//!`
//!   doc-comment.
//!
//! The scan is a lexer-lite pass: comments, string/char literals, and
//! raw strings are stripped with a small state machine (so patterns in
//! strings or docs never false-positive), `#[cfg(test)]` items are
//! brace-matched and excluded from AR003, and everything else is plain
//! token matching. No dependencies, no `syn` — the analyzer must build
//! in the same offline container as the crate it guards.
//!
//! Known lexer limits (fine for this repo, documented for honesty):
//! byte-raw strings (`br"…"`) are not recognized as raw, and attributes
//! are assumed to occupy whole lines.
//!
//! A site that must use a forbidden API can carry a justified waiver on
//! the same line or the line above:
//!
//! ```text
//! // analyzer: allow(AR003): poisoned lock means a worker panicked;
//! // propagating the panic is the supervision contract.
//! ```
//!
//! Waivers with an empty reason are rejected — the justification *is*
//! the point.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The rule set, stable IDs first. IDs are load-bearing: tests, CI
/// greps, and waiver comments name them.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Rule {
    /// AR001: `unsafe` without an adjacent `SAFETY:` argument.
    UnsafeNeedsSafety,
    /// AR002: SIMD item without a scalar bit-identity sibling.
    SimdScalarSibling,
    /// AR003: forbidden API outside tests/bins.
    ForbiddenApi,
    /// AR004: module file without a `//!` doc-comment.
    ModuleDoc,
}

/// Every rule, in report order.
pub const ALL_RULES: [Rule; 4] = [
    Rule::UnsafeNeedsSafety,
    Rule::SimdScalarSibling,
    Rule::ForbiddenApi,
    Rule::ModuleDoc,
];

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::UnsafeNeedsSafety => "AR001",
            Rule::SimdScalarSibling => "AR002",
            Rule::ForbiddenApi => "AR003",
            Rule::ModuleDoc => "AR004",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rule::UnsafeNeedsSafety => "unsafe-needs-safety",
            Rule::SimdScalarSibling => "simd-scalar-sibling",
            Rule::ForbiddenApi => "forbidden-api",
            Rule::ModuleDoc => "module-doc",
        }
    }
}

/// One finding: rule, repo-relative path, 1-based line, human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: Rule,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {} ({}): {}",
            self.path,
            self.line,
            self.rule.id(),
            self.rule.name(),
            self.message
        )
    }
}

// ---- lexer-lite source view ---------------------------------------------

/// Per-line views of one source file: `code` has comments stripped and
/// literal contents blanked (delimiters kept); `comment` holds the
/// comment text of the line; `test` marks lines inside `#[cfg(test)]`
/// items; `raw` keeps the original line for `//!` detection.
struct SourceView {
    code: Vec<String>,
    comment: Vec<String>,
    test: Vec<bool>,
    raw: Vec<String>,
}

/// Lexer state carried across lines.
enum LexState {
    Code,
    /// Block comment at the given nesting depth (Rust block comments nest).
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string with this many `#`s in its delimiter.
    RawStr(u8),
}

impl SourceView {
    fn parse(src: &str) -> SourceView {
        let mut state = LexState::Code;
        let mut code_lines = Vec::new();
        let mut comment_lines = Vec::new();
        let mut raw_lines = Vec::new();
        for line in src.lines() {
            raw_lines.push(line.to_string());
            let chars: Vec<char> = line.chars().collect();
            let n = chars.len();
            let mut code = String::new();
            let mut comment = String::new();
            let mut i = 0usize;
            while i < n {
                match state {
                    LexState::Block(depth) => {
                        if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                            state = if depth <= 1 {
                                LexState::Code
                            } else {
                                LexState::Block(depth - 1)
                            };
                            i += 2;
                        } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                            state = LexState::Block(depth + 1);
                            i += 2;
                        } else {
                            comment.push(chars[i]);
                            i += 1;
                        }
                    }
                    LexState::Str => {
                        if chars[i] == '\\' {
                            i += 2; // escape: skip the escaped char
                        } else if chars[i] == '"' {
                            code.push('"');
                            state = LexState::Code;
                            i += 1;
                        } else {
                            i += 1;
                        }
                    }
                    LexState::RawStr(hashes) => {
                        if chars[i] == '"' {
                            let have = chars[i + 1..]
                                .iter()
                                .take_while(|&&c| c == '#')
                                .count();
                            if have >= hashes as usize {
                                code.push('"');
                                i += 1 + hashes as usize;
                                state = LexState::Code;
                            } else {
                                i += 1;
                            }
                        } else {
                            i += 1;
                        }
                    }
                    LexState::Code => {
                        let c = chars[i];
                        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                            // line comment (covers ///, //!): rest of line
                            comment.extend(&chars[i + 2..]);
                            i = n;
                        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                            state = LexState::Block(1);
                            i += 2;
                        } else if c == '"' {
                            code.push('"');
                            state = LexState::Str;
                            i += 1;
                        } else if c == 'r' && raw_string_hashes(&chars, i).is_some() {
                            let hashes = match raw_string_hashes(&chars, i) {
                                Some(h) => h,
                                None => 0,
                            };
                            code.push('"');
                            i += 1 + hashes + 1; // r + hashes + opening quote
                            state = LexState::RawStr(hashes as u8);
                        } else if c == '\'' {
                            i = skip_char_or_lifetime(&chars, i, &mut code);
                        } else {
                            code.push(c);
                            i += 1;
                        }
                    }
                }
            }
            code_lines.push(code);
            comment_lines.push(comment);
        }
        let test = test_regions(&code_lines);
        SourceView {
            code: code_lines,
            comment: comment_lines,
            test,
            raw: raw_lines,
        }
    }

    /// Whitespace-squashed code of line `li` (for punctuation patterns).
    fn squashed(&self, li: usize) -> String {
        self.code[li].chars().filter(|c| !c.is_whitespace()).collect()
    }
}

/// If `chars[i] == 'r'` opens a raw string (`r"`, `r#"`, …) and is not
/// the tail of an identifier, return the `#` count.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<usize> {
    if i > 0 {
        let prev = chars[i - 1];
        if prev.is_alphanumeric() || prev == '_' {
            return None;
        }
    }
    let hashes = chars[i + 1..].iter().take_while(|&&c| c == '#').count();
    match chars.get(i + 1 + hashes) {
        Some('"') => Some(hashes),
        _ => None,
    }
}

/// At a `'`: skip a char literal (returning the index after it) or emit
/// the `'` as code when it is a lifetime.
fn skip_char_or_lifetime(chars: &[char], i: usize, code: &mut String) -> usize {
    let n = chars.len();
    if i + 1 < n && chars[i + 1] == '\\' {
        // escaped char literal: closing quote is the next ' at or after i+3
        let mut j = i + 3;
        while j < n && chars[j] != '\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && chars[i + 2] == '\'' {
        return i + 3; // simple 'x'
    }
    code.push('\''); // lifetime
    i + 1
}

/// Mark every line belonging to a `#[cfg(test)]` item by brace-matching
/// from the attribute to the item's closing brace (literals are already
/// blanked, so brace counting is reliable).
fn test_regions(code_lines: &[String]) -> Vec<bool> {
    let n = code_lines.len();
    let mut test = vec![false; n];
    let mut i = 0usize;
    while i < n {
        let squashed: String = code_lines[i]
            .chars()
            .filter(|c| !c.is_whitespace())
            .collect();
        if squashed.contains("#[cfg(test)]") || squashed.contains("#[cfg(all(test") {
            let mut depth = 0i64;
            let mut opened = false;
            let mut j = i;
            while j < n {
                test[j] = true;
                for c in code_lines[j].chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                if !opened && code_lines[j].contains(';') {
                    break; // brace-less item, e.g. `#[cfg(test)] mod t;`
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    test
}

// ---- token helpers ------------------------------------------------------

/// Split a code line into identifier tokens and single punctuation chars.
fn tokens(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut ident = String::new();
    for c in line.chars() {
        if c.is_alphanumeric() || c == '_' {
            ident.push(c);
        } else {
            if !ident.is_empty() {
                out.push(std::mem::take(&mut ident));
            }
            if !c.is_whitespace() {
                out.push(c.to_string());
            }
        }
    }
    if !ident.is_empty() {
        out.push(ident);
    }
    out
}

/// Does `squashed` contain `pattern` at an identifier boundary?
fn contains_pattern(squashed: &str, pattern: &str) -> bool {
    // Boundary checks apply only on sides where the pattern itself ends in
    // an identifier char; `.unwrap(` legitimately follows `x`/`)`/`]`.
    let head_is_ident = pattern
        .chars()
        .next()
        .map(|c| c.is_alphanumeric() || c == '_')
        .unwrap_or(false);
    let mut from = 0usize;
    while let Some(pos) = squashed[from..].find(pattern) {
        let at = from + pos;
        let boundary_ok = !head_is_ident
            || at == 0
            || !squashed[..at]
                .chars()
                .next_back()
                .map(|p| p.is_alphanumeric() || p == '_')
                .unwrap_or(false);
        if boundary_ok {
            // also require a non-identifier char after the pattern when
            // the pattern itself ends in an identifier char
            let end = at + pattern.len();
            let tail_ok = !pattern
                .chars()
                .next_back()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false)
                || !squashed[end..]
                    .chars()
                    .next()
                    .map(|c| c.is_alphanumeric() || c == '_')
                    .unwrap_or(false);
            if tail_ok {
                return true;
            }
        }
        from = at + 1;
    }
    false
}

// ---- rule implementations -----------------------------------------------

/// SIMD-suffix → scalar-sibling stems recognized by AR002.
const SIMD_SUFFIXES: [&str; 6] = ["_avx512", "_avx2", "_avx", "_sse41", "_sse2", "_neon"];

/// Path classification for AR003 scopes, derived from the repo-relative
/// path (always `/`-separated).
struct Scope {
    /// Kernel hot paths: typed errors only, no panicking shortcuts.
    hot_path: bool,
    /// `quant`/`linalg`/`deploy`: no wall-clock reads inside kernels.
    timed_kernel: bool,
    /// A binary crate root (`main.rs`): `process::exit` is its job.
    bin_root: bool,
    /// The sanctioned spawner (`util/threadpool.rs`).
    pool: bool,
}

impl Scope {
    fn of(rel_path: &str) -> Scope {
        let p = rel_path.replace('\\', "/");
        let hot = ["rust/src/quant/", "rust/src/linalg/", "rust/src/deploy/", "rust/src/tensor/"]
            .iter()
            .any(|d| p.starts_with(d));
        let timed = ["rust/src/quant/", "rust/src/linalg/", "rust/src/deploy/"]
            .iter()
            .any(|d| p.starts_with(d));
        Scope {
            hot_path: hot,
            timed_kernel: timed,
            bin_root: p.ends_with("/main.rs") || p == "main.rs",
            pool: p.ends_with("util/threadpool.rs"),
        }
    }
}

/// Is there a `SAFETY:` argument attached to line `li`? Looks at the
/// line itself, the first line inside a block opened here, and the
/// comment/attribute run directly above (doc comments count).
fn has_safety_comment(view: &SourceView, li: usize) -> bool {
    if view.comment[li].contains("SAFETY:") {
        return true;
    }
    if li + 1 < view.comment.len()
        && view.code[li + 1].trim().is_empty()
        && view.comment[li + 1].contains("SAFETY:")
    {
        return true;
    }
    let mut j = li;
    while j > 0 {
        j -= 1;
        let code = view.code[j].trim();
        let comment = view.comment[j].trim();
        let is_attr = code.starts_with("#[") || code.starts_with("#![");
        let is_comment_only = code.is_empty() && !comment.is_empty();
        if !(is_attr || is_comment_only) {
            return false; // hit real code or a blank line: run ended
        }
        if comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// Is a waiver for `rule` present on line `li` or the line above, with a
/// non-empty reason after the closing paren?
fn waived(view: &SourceView, li: usize, rule: Rule) -> bool {
    let check = |comment: &str| -> bool {
        let lower = comment.to_ascii_lowercase();
        let needle_id = format!("analyzer: allow({})", rule.id().to_ascii_lowercase());
        let needle_name = format!("analyzer: allow({})", rule.name());
        for needle in [needle_id, needle_name] {
            if let Some(pos) = lower.find(&needle) {
                let reason = lower[pos + needle.len()..]
                    .trim_start_matches([':', ' ', '-', '—'])
                    .trim();
                if reason.len() >= 4 {
                    return true;
                }
            }
        }
        false
    };
    if check(&view.comment[li]) {
        return true;
    }
    li > 0 && check(&view.comment[li - 1])
}

/// AR001: every `unsafe` block/fn/impl needs a `SAFETY:` argument.
fn check_unsafe_safety(rel_path: &str, view: &SourceView, out: &mut Vec<Violation>) {
    let n = view.code.len();
    for li in 0..n {
        let toks = tokens(&view.code[li]);
        for (k, t) in toks.iter().enumerate() {
            if t != "unsafe" {
                continue;
            }
            // what does this `unsafe` introduce?
            let next = toks.get(k + 1).cloned().or_else(|| {
                (li + 1..n)
                    .find(|&j| !view.code[j].trim().is_empty())
                    .and_then(|j| tokens(&view.code[j]).first().cloned())
            });
            let introduces = matches!(
                next.as_deref(),
                Some("{") | Some("fn") | Some("impl") | Some("extern") | Some("trait")
            );
            if introduces && !has_safety_comment(view, li) && !waived(view, li, Rule::UnsafeNeedsSafety)
            {
                out.push(Violation {
                    rule: Rule::UnsafeNeedsSafety,
                    path: rel_path.to_string(),
                    line: li + 1,
                    message: format!(
                        "`unsafe {}` without a `// SAFETY:` argument on or above it",
                        next.as_deref().unwrap_or("?")
                    ),
                });
            }
        }
    }
}

/// One declared `fn` in a file.
struct FnDecl {
    name: String,
    line: usize,
    target_feature: bool,
}

/// Collect `fn` declarations with whether their attribute run carries
/// `#[target_feature]`.
fn fn_decls(view: &SourceView) -> Vec<FnDecl> {
    let n = view.code.len();
    let mut out = Vec::new();
    for li in 0..n {
        let toks = tokens(&view.code[li]);
        for w in 0..toks.len() {
            if toks[w] != "fn" {
                continue;
            }
            let Some(name) = toks.get(w + 1) else { continue };
            if !name.chars().next().map(|c| c.is_alphabetic() || c == '_').unwrap_or(false) {
                continue;
            }
            // walk the attribute/comment run above for #[target_feature
            let mut tf = view.code[li].contains("target_feature");
            let mut j = li;
            while !tf && j > 0 {
                j -= 1;
                let code = view.code[j].trim();
                let comment_only = code.is_empty() && !view.comment[j].trim().is_empty();
                let is_attr = code.starts_with("#[");
                let is_kw_tail = code.ends_with("unsafe") || code.ends_with("pub");
                if !(is_attr || comment_only || is_kw_tail) {
                    break;
                }
                tf = code.contains("target_feature");
            }
            out.push(FnDecl {
                name: name.clone(),
                line: li + 1,
                target_feature: tf,
            });
            break; // one decl per line is enough for this codebase
        }
    }
    out
}

/// AR002: `#[target_feature]` fns need a `*_scalar` sibling sharing
/// their stem; `feature = "simd"` files need at least one `*_scalar`.
fn check_simd_siblings(rel_path: &str, view: &SourceView, out: &mut Vec<Violation>) {
    let decls = fn_decls(view);
    let scalar_bases: Vec<String> = decls
        .iter()
        .filter(|d| d.name.ends_with("_scalar"))
        .map(|d| d.name[..d.name.len() - "_scalar".len()].to_string())
        .collect();
    for d in decls.iter().filter(|d| d.target_feature) {
        let stem = SIMD_SUFFIXES
            .iter()
            .find_map(|s| d.name.strip_suffix(s))
            .unwrap_or(&d.name);
        let paired = scalar_bases
            .iter()
            .any(|b| b.starts_with(stem) || stem.starts_with(b.as_str()));
        if !paired && !waived(view, d.line - 1, Rule::SimdScalarSibling) {
            out.push(Violation {
                rule: Rule::SimdScalarSibling,
                path: rel_path.to_string(),
                line: d.line,
                message: format!(
                    "`#[target_feature]` fn `{}` has no `{}*_scalar` bit-identity sibling in this file",
                    d.name, stem
                ),
            });
        }
    }
    if scalar_bases.is_empty() {
        for li in 0..view.code.len() {
            if view.squashed(li).contains("feature=\"simd\"") {
                out.push(Violation {
                    rule: Rule::SimdScalarSibling,
                    path: rel_path.to_string(),
                    line: li + 1,
                    message: "file is gated on `feature = \"simd\"` but defines no `*_scalar` fallback"
                        .to_string(),
                });
                break; // one per file is enough signal
            }
        }
    }
}

/// AR003: forbidden APIs outside tests/bins, scoped by path.
fn check_forbidden_apis(rel_path: &str, view: &SourceView, out: &mut Vec<Violation>) {
    let scope = Scope::of(rel_path);
    let mut push = |li: usize, message: String| {
        if !waived(view, li, Rule::ForbiddenApi) {
            out.push(Violation {
                rule: Rule::ForbiddenApi,
                path: rel_path.to_string(),
                line: li + 1,
                message,
            });
        }
    };
    for li in 0..view.code.len() {
        if view.test[li] {
            continue;
        }
        let squashed = view.squashed(li);
        if squashed.is_empty() {
            continue;
        }
        if !scope.bin_root && contains_pattern(&squashed, "process::exit") {
            push(
                li,
                "`process::exit` outside a binary root: return a typed error instead".to_string(),
            );
        }
        if scope.timed_kernel && contains_pattern(&squashed, "Instant::now") {
            push(
                li,
                "`Instant::now` inside a kernel module: timing belongs to callers, \
                 kernels must be deterministic"
                    .to_string(),
            );
        }
        if !scope.pool && contains_pattern(&squashed, "thread::spawn") {
            push(
                li,
                "bare `thread::spawn` bypasses the width-capped pool: use \
                 `util::threadpool` (scoped APIs or the global pool)"
                    .to_string(),
            );
        }
        if scope.hot_path {
            for pat in [".unwrap(", ".expect("] {
                if contains_pattern(&squashed, pat) {
                    push(
                        li,
                        format!(
                            "`{})` in a kernel hot path: convert to a typed \
                             `util::error::Error` (or waive with a justified \
                             `analyzer: allow(AR003)`)",
                            &pat[1..]
                        ),
                    );
                }
            }
        }
    }
}

/// AR004: the file opens with a `//!` module doc-comment (inner
/// attributes may precede it).
fn check_module_doc(rel_path: &str, view: &SourceView, out: &mut Vec<Violation>) {
    for li in 0..view.raw.len() {
        if view.raw[li].trim_start().starts_with("//!") {
            return;
        }
        let code = view.code[li].trim();
        if code.is_empty() || code.starts_with("#![") {
            continue;
        }
        break; // reached the first real item without a module doc
    }
    out.push(Violation {
        rule: Rule::ModuleDoc,
        path: rel_path.to_string(),
        line: 1,
        message: "module file has no `//!` doc-comment".to_string(),
    });
}

// ---- entry points -------------------------------------------------------

/// Scan one file's source under its repo-relative path.
pub fn scan_source(rel_path: &str, src: &str) -> Vec<Violation> {
    let view = SourceView::parse(src);
    let mut out = Vec::new();
    check_unsafe_safety(rel_path, &view, &mut out);
    check_simd_siblings(rel_path, &view, &mut out);
    check_forbidden_apis(rel_path, &view, &mut out);
    check_module_doc(rel_path, &view, &mut out);
    out.sort_by_key(|v| v.line);
    out
}

/// Recursively collect `.rs` files under `dir` (sorted for stable output).
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rs_files(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// The directories a default scan covers, relative to the repo root.
/// `rust/src` is the library under guard; the analyzer dogfoods itself.
pub const DEFAULT_SCAN_DIRS: [&str; 2] = ["rust/src", "tools/analyze/src"];

/// Scan the default directory set under `root`. Missing directories are
/// skipped (a fixture tree has no `tools/`), unreadable files are IO
/// errors. Returns violations sorted by path then line, plus the number
/// of files scanned.
pub fn scan_tree(root: &Path) -> io::Result<(Vec<Violation>, usize)> {
    let mut files = Vec::new();
    for dir in DEFAULT_SCAN_DIRS {
        let d = root.join(dir);
        if d.is_dir() {
            rs_files(&d, &mut files)?;
        }
    }
    let mut all = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(f)?;
        all.extend(scan_source(&rel, &src));
    }
    all.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)));
    Ok((all, files.len()))
}

/// Scan explicit files/directories (CLI operands). Paths are reported
/// relative to `root` when they live under it, verbatim otherwise.
pub fn scan_paths(root: &Path, paths: &[PathBuf]) -> io::Result<(Vec<Violation>, usize)> {
    let mut files = Vec::new();
    for p in paths {
        if p.is_dir() {
            rs_files(p, &mut files)?;
        } else {
            files.push(p.clone());
        }
    }
    let mut all = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(f)?;
        all.extend(scan_source(&rel, &src));
    }
    all.sort_by(|a, b| a.path.cmp(&b.path).then(a.line.cmp(&b.line)));
    Ok((all, files.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_blanks_strings_and_comments() {
        let v = SourceView::parse(
            "let x = \".unwrap( unsafe {\"; // unsafe in a comment\nlet y = 2;",
        );
        assert!(!v.code[0].contains("unwrap"));
        assert!(!v.code[0].contains("unsafe"));
        assert!(v.comment[0].contains("unsafe in a comment"));
        assert_eq!(v.code[1].trim(), "let y = 2;");
    }

    #[test]
    fn lexer_handles_raw_strings_and_chars() {
        let v = SourceView::parse("let p = r#\"x \".unwrap(\" y\"#; let c = '{'; let l: &'static str = \"\";");
        assert!(!v.code[0].contains("unwrap"));
        assert!(!v.code[0].contains('{'), "char-literal brace must be blanked");
        assert!(v.code[0].contains("'static"), "lifetime must survive");
    }

    #[test]
    fn nested_block_comments_end_correctly() {
        let v = SourceView::parse("/* a /* b */ still comment */ let z = 1;");
        assert_eq!(v.code[0].trim(), "let z = 1;");
    }

    #[test]
    fn test_region_covers_braced_mod() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn live2() {}\n";
        let v = SourceView::parse(src);
        assert!(!v.test[0]);
        assert!(v.test[1] && v.test[2] && v.test[3] && v.test[4]);
        assert!(!v.test[5]);
    }

    #[test]
    fn boundary_matching_rejects_identifier_tails() {
        assert!(contains_pattern("std::process::exit(1)", "process::exit"));
        assert!(!contains_pattern("my_process::exit(1)", "process::exit"));
        assert!(contains_pattern("x.unwrap()", ".unwrap("));
        assert!(!contains_pattern("unsafe_op_in_unsafe_fn", "unsafe"));
    }

    #[test]
    fn safety_comment_found_through_attr_run() {
        let src = "/// SAFETY: caller checks lengths.\n#[target_feature(enable = \"avx\")]\npub unsafe fn f_avx() {}\npub fn f_scalar() {}\n//! not a doc\n";
        let v = scan_source("rust/src/linalg/x.rs", src);
        assert!(
            v.iter().all(|x| x.rule != Rule::UnsafeNeedsSafety),
            "{v:?}"
        );
    }

    #[test]
    fn waiver_needs_a_reason() {
        let bare = "//! doc\n// analyzer: allow(AR003)\nlet v = x.unwrap();\n";
        let good = "//! doc\n// analyzer: allow(AR003): poison propagation is the contract here\nlet v = x.unwrap();\n";
        assert!(scan_source("rust/src/quant/w.rs", bare)
            .iter()
            .any(|v| v.rule == Rule::ForbiddenApi));
        assert!(scan_source("rust/src/quant/w.rs", good)
            .iter()
            .all(|v| v.rule != Rule::ForbiddenApi));
    }
}
