#!/usr/bin/env python3
"""Structural validator for `repro --trace` Chrome trace-event exports.

Checks the invariants the tracing subsystem promises (the same ones
`rust/tests/trace.rs` pins in-process, re-verified here on a real
end-to-end export):

  * the file is well-formed JSON of the object form {"traceEvents": [...]};
  * every event carries name/ph/pid/tid/ts, with ts a non-negative number;
  * per tid, Begin/End events are balanced and timestamps are monotonic
    non-decreasing;
  * instant events carry the thread scope ("s": "t");
  * counter events carry a numeric args.value;
  * with --workers N: exactly N `worker-<i>` thread_name lanes exist
    (the fleet labeled every supervised worker);
  * with --expect-chaos: at least one chaos-category instant exists
    (the injection actually fired and was recorded);
  * with --expect-cats a,b,...: every listed category appears.

Usage:
    scripts/validate_trace.py TRACE.json [--workers N] [--expect-chaos]
        [--expect-cats pipeline,calib,...] [--min-events N]
"""

import argparse
import json
import re
import sys

KNOWN_PHASES = {"B", "E", "i", "C", "M"}


def fail(msg):
    print(f"validate_trace: FAIL -- {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON written by --trace")
    ap.add_argument(
        "--workers",
        type=int,
        default=None,
        help="assert exactly this many worker-<i> thread_name lanes",
    )
    ap.add_argument(
        "--expect-chaos",
        action="store_true",
        help="assert at least one chaos-category instant event",
    )
    ap.add_argument(
        "--expect-cats",
        default=None,
        help="comma-separated categories that must each appear at least once",
    )
    ap.add_argument(
        "--min-events",
        type=int,
        default=1,
        help="minimum total event count (default 1: a trace was recorded)",
    )
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {args.trace}: {e}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail('top level must be an object with a "traceEvents" array')
    events = doc["traceEvents"]
    if not isinstance(events, list):
        fail('"traceEvents" must be an array')
    if len(events) < args.min_events:
        fail(f"only {len(events)} events, expected >= {args.min_events}")

    depth = {}  # tid -> open span count
    last_ts = {}  # tid -> last timestamp seen
    lanes = {}  # tid -> thread_name
    cats = set()
    chaos_instants = 0

    for idx, ev in enumerate(events):
        where = f"event #{idx}"
        if not isinstance(ev, dict):
            fail(f"{where}: not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                fail(f"{where}: missing {key!r}")
        ph = ev["ph"]
        if ph not in KNOWN_PHASES:
            fail(f"{where}: unknown phase {ph!r}")
        tid = ev["tid"]

        if ph == "M":
            if ev["name"] != "thread_name":
                fail(f"{where}: unexpected metadata {ev['name']!r}")
            lanes[tid] = ev.get("args", {}).get("name", "")
            continue

        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"{where}: bad ts {ts!r}")
        if ts < last_ts.get(tid, 0):
            fail(f"{where}: tid {tid} ts went backwards ({ts} < {last_ts[tid]})")
        last_ts[tid] = ts
        cats.add(ev.get("cat", ""))

        if ph == "B":
            depth[tid] = depth.get(tid, 0) + 1
        elif ph == "E":
            depth[tid] = depth.get(tid, 0) - 1
            if depth[tid] < 0:
                fail(f"{where}: tid {tid} has End before Begin")
        elif ph == "i":
            if ev.get("s") != "t":
                fail(f"{where}: instant without thread scope")
            if ev.get("cat") == "chaos":
                chaos_instants += 1
        elif ph == "C":
            value = ev.get("args", {}).get("value")
            if not isinstance(value, (int, float)):
                fail(f"{where}: counter without numeric args.value")

    unbalanced = {tid: d for tid, d in depth.items() if d != 0}
    if unbalanced:
        fail(f"unbalanced Begin/End per tid: {unbalanced}")

    if args.workers is not None:
        worker_lanes = sorted(
            name for name in lanes.values() if re.fullmatch(r"worker-\d+", name)
        )
        if len(worker_lanes) != args.workers:
            fail(
                f"expected {args.workers} worker lanes, found "
                f"{len(worker_lanes)}: {worker_lanes}"
            )

    if args.expect_chaos and chaos_instants == 0:
        fail("no chaos-category instants recorded (injection never traced)")

    if args.expect_cats:
        want = {c.strip() for c in args.expect_cats.split(",") if c.strip()}
        missing = want - cats
        if missing:
            fail(f"categories never seen: {sorted(missing)} (saw {sorted(cats)})")

    print(
        f"validate_trace: OK -- {len(events)} events, {len(lanes)} named lanes, "
        f"{chaos_instants} chaos instants, categories {sorted(c for c in cats if c)}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
