#!/usr/bin/env python3
"""CI bench regression gate.

Compares a freshly measured `cargo bench --bench hotpath -- --json` output
against the committed `BENCH_host.json` baseline and fails (exit 1) when any
bench shared by both files regressed by more than the threshold on `mean_s`.

Self-skip: while the committed file is still the "baseline pending first
toolchain run" placeholder (it carries only a `_meta` block and no per-bench
entries), there is nothing honest to compare against, so the gate exits 0
with a notice. It arms automatically the first time a measured baseline is
committed — no workflow change needed.

Usage:
    scripts/bench_regression.py COMMITTED.json FRESH.json [--threshold 0.20]

Notes:
  * Only `mean_s` is gated. Percentiles of a --quick profile on shared CI
    runners are too noisy to gate on.
  * An absolute-delta floor (default 2us) keeps nanosecond-scale benches
    from tripping the relative threshold on scheduler noise.
  * Benches present in only one file are reported informationally, never
    fatally — adding or retiring a bench must not require a baseline bump
    in the same commit.
"""

import argparse
import json
import sys


def bench_entries(doc):
    """Per-bench rows: every non-underscore key mapping to a stats object."""
    return {
        name: row
        for name, row in doc.items()
        if not name.startswith("_") and isinstance(row, dict) and "mean_s" in row
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("committed", help="committed baseline (BENCH_host.json)")
    ap.add_argument("fresh", help="freshly measured bench JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="max allowed relative mean_s regression (default 0.20 = 20%%)",
    )
    ap.add_argument(
        "--abs-floor-s",
        type=float,
        default=2e-6,
        help="ignore regressions smaller than this absolute delta in seconds",
    )
    args = ap.parse_args()

    with open(args.committed) as f:
        committed = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    base = bench_entries(committed)
    meas = bench_entries(fresh)

    if not base:
        status = committed.get("_meta", {}).get("status", "<no _meta.status>")
        print(
            "bench_regression: committed baseline has no per-bench entries "
            f"(status: {status!r}) -- gate self-skips until a measured "
            "baseline is committed."
        )
        return 0
    if not meas:
        print("bench_regression: FRESH file has no per-bench entries", file=sys.stderr)
        return 1

    shared = sorted(set(base) & set(meas))
    only_base = sorted(set(base) - set(meas))
    only_fresh = sorted(set(meas) - set(base))
    if only_base:
        print(f"bench_regression: note: in baseline only: {', '.join(only_base)}")
    if only_fresh:
        print(f"bench_regression: note: new (unbaselined): {', '.join(only_fresh)}")
    if not shared:
        print("bench_regression: no shared bench names to compare", file=sys.stderr)
        return 1

    regressions = []
    for name in shared:
        old = float(base[name]["mean_s"])
        new = float(meas[name]["mean_s"])
        if old <= 0.0:
            continue
        rel = (new - old) / old
        mark = ""
        if rel > args.threshold and (new - old) > args.abs_floor_s:
            mark = "  << REGRESSION"
            regressions.append((name, old, new, rel))
        print(f"  {name:55s} {old:.3e}s -> {new:.3e}s  ({rel:+7.1%}){mark}")

    if regressions:
        print(
            f"\nbench_regression: FAIL -- {len(regressions)} bench(es) regressed "
            f"more than {args.threshold:.0%} on mean_s:",
            file=sys.stderr,
        )
        for name, old, new, rel in regressions:
            print(f"  {name}: {old:.3e}s -> {new:.3e}s ({rel:+.1%})", file=sys.stderr)
        return 1

    print(
        f"bench_regression: OK -- {len(shared)} shared benches within "
        f"{args.threshold:.0%} of the committed baseline"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
