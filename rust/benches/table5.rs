//! Bench: regenerate Table 5 (rounding-function ablation) at bench scale.
//! Full-scale: `repro reproduce table5 --profile paper`.

mod common;

use attention_round::coordinator::experiments;

fn main() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    // bench-scale: static roundings + ours, weights-only (full 6-method
    // W+A table via `repro reproduce table5`)
    use attention_round::coordinator::pipeline::{
        quantize_and_eval, resolve_uniform_bits, QuantSpec,
    };
    use attention_round::quant::rounding::Rounding;
    let loaded = ctx.backend.load_model(&ctx.manifest, "resnet18t").expect("model");
    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&loaded, 4),
        abits: None,
    };
    let mut accs = std::collections::BTreeMap::new();
    for m in [
        Rounding::Floor,
        Rounding::Ceil,
        Rounding::Stochastic,
        Rounding::Nearest,
        Rounding::Attention,
    ] {
        let mut cfg = ctx.cfg.clone();
        cfg.method = m;
        let out = quantize_and_eval(
            ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg, &ctx.calib, &ctx.eval,
        )
        .expect("run");
        println!("table5 bench row: {:<10} 4/32 -> {:.2}%", m.name(), out.acc * 100.0);
        accs.insert(m.name(), out.acc);
    }
    // The static-rounding collapse must hold even at bench scale; the
    // trained methods need a real iteration budget to separate (16 iters
    // leaves attention ≈ nearest within noise — Table 5 proper uses
    // `repro reproduce table5 --profile paper`), so allow a 3% margin.
    assert!(accs["attention"] >= accs["nearest"] - 0.03);
    assert!(accs["nearest"] > accs["floor"] + 0.5);
    assert!(accs["nearest"] > accs["ceil"] + 0.5);
    let _ = experiments::table5 as usize;
}
