//! Bench: regenerate Table 4 (mixed vs single precision) at bench scale,
//! and time Algorithm 1 itself (the paper's "avoids combinatorial
//! search" claim — allocation must be ≪ 1 s).
//! Full-scale: `repro reproduce table4`.

mod common;

use attention_round::bench_harness::Bencher;
use attention_round::coordinator::experiments;
use attention_round::mixed;

fn main() {
    let Some(ctx) = common::bench_ctx(48) else { return };

    // Algorithm 1 timing across the zoo (pure Rust, no device).
    let b = Bencher::default();
    for name in ["resnet18t", "resnet50t", "mobilenetv2t"] {
        let model = ctx.backend.load_model(&ctx.manifest, name).expect("model");
        let stats = b.run(&format!("table4/allocate/{name}"), || {
            mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6], 1e-3)
                .unwrap()
        });
        assert!(
            stats.mean_s < 1.0,
            "Algorithm 1 must run in < 1s (paper's efficiency claim), got {}",
            stats.mean_s
        );
    }

    // one mixed-precision quantize+eval end to end (full table via
    // `repro reproduce table4`)
    use attention_round::coordinator::pipeline::{quantize_and_eval, QuantSpec};
    let model = ctx.backend.load_model(&ctx.manifest, "resnet18t").expect("model");
    let alloc = mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6], 1e-3)
        .expect("alloc");
    let out = quantize_and_eval(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &QuantSpec {
            model: "resnet18t".into(),
            wbits: alloc.bits.clone(),
            abits: None,
        },
        &ctx.cfg,
        &ctx.calib,
        &ctx.eval,
    )
    .expect("mixed run");
    println!(
        "table4 bench row: resnet18t mixed[3,4,5,6] ({}) -> {:.2}% in {:.1}s",
        mixed::format_size_mb(alloc.size_bytes),
        out.acc * 100.0,
        out.wall_s
    );
    let _ = experiments::table4 as usize;
}
