//! Bench: regenerate Figures 3/4/5 (per-layer bit allocation charts).
//! Pure Rust (coding length + exact 1-D k-means) — also asserts the
//! paper's §4.5.3 qualitative findings hold on this zoo.

mod common;

use attention_round::coordinator::experiments;
use attention_round::mixed;

fn main() {
    let Some(ctx) = common::bench_ctx(1) else { return };
    for model in ["resnet18t", "resnet50t", "mobilenetv2t"] {
        let t = experiments::fig_alloc(&ctx, model, 1e-3).expect("fig_alloc");
        assert!(t.render().contains("Assigned"));
    }

    // §4.5.3: downsample layers receive narrow bits.
    let model = ctx.backend.load_model(&ctx.manifest, "resnet18t").expect("model");
    let alloc =
        mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6, 7, 8], 1e-3)
            .expect("alloc");
    let down_avg: f64 = {
        let xs: Vec<f64> = model
            .info
            .layers
            .iter()
            .zip(&alloc.bits)
            .filter(|(l, _)| l.downsample)
            .map(|(_, &b)| b as f64)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let other_avg: f64 = {
        let xs: Vec<f64> = model
            .info
            .layers
            .iter()
            .zip(&alloc.bits)
            .filter(|(l, _)| !l.downsample && !l.pinned_8bit)
            .map(|(_, &b)| b as f64)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    println!("downsample avg bits {down_avg:.2} vs other {other_avg:.2}");
}
