//! Bench: regenerate Table 1 (weight-only PTQ) at bench scale.
//!
//! Runs the real pipeline on a model subset with a reduced iteration
//! budget and times one full "Ours 4/32" quantization; the printed table
//! rows are the Table-1 series for the subset.
//! Full-scale regeneration: `repro reproduce table1 --profile paper`.

mod common;

use attention_round::bench_harness::Bencher;
use attention_round::coordinator::experiments;

fn main() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    let mut b = Bencher::quick();
    b.max_iters = 1;
    let stats = b.run("table1/resnet18t/ours_4b_quantize_eval", || {
        experiments_run_once(&ctx)
    });
    println!(
        "one full 4-bit quantize+eval: {:.1}s at {} iters/module",
        stats.mean_s, ctx.cfg.iters
    );
}

fn experiments_run_once(ctx: &experiments::Ctx) {
    use attention_round::coordinator::pipeline::{
        quantize_and_eval, resolve_uniform_bits, QuantSpec,
    };
    let loaded = ctx.backend.load_model(&ctx.manifest, "resnet18t").unwrap();
    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&loaded, 4),
        abits: None,
    };
    quantize_and_eval(ctx.backend.as_ref(), &ctx.manifest, &spec, &ctx.cfg, &ctx.calib, &ctx.eval)
        .unwrap();
}
