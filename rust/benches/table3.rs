//! Bench: regenerate Table 3 (PTQ vs budgeted QAT) at bench scale.
//! Times the QAT step loop — the most expensive single executable in the
//! repo (full fwd+bwd of the model).
//! Full-scale: `repro reproduce table3 --steps 1000`.

mod common;

use attention_round::coordinator::experiments;

fn main() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    // bench-scale QAT: a short step budget; full table via `repro reproduce table3`
    use attention_round::coordinator::qat::run_qat;
    use attention_round::data::Split;
    let dir = ctx.manifest.path(&ctx.manifest.dataset.dir);
    let train = Split::load(&dir, "train").expect("train split");
    let out = run_qat(
        ctx.backend.as_ref(), &ctx.manifest, "resnet18t", 4, 4, 20, 1e-3, &train, &ctx.eval, 7,
    )
    .expect("qat");
    println!(
        "table3 bench row: STE-QAT resnet18t 4/4, 20 steps -> {:.2}% in {:.1}s",
        out.acc * 100.0,
        out.wall_s
    );
    let _ = experiments::table3 as usize;
}
