//! Hot-path microbenchmarks — the §Perf profiling surface.
//!
//! Device-path benches:
//!   * calib scan throughput (steps/s) vs single-step (quantifies the
//!     K-step fusion win)
//!   * eval throughput (imgs/s)
//!   * executable compile latency
//! Host-path benches:
//!   * MSE scale search, rounding kernels, coding length + k-means,
//!     JSON/npy parsing, RNG, batch gather.

mod common;

use attention_round::bench_harness::{artifacts_dir, Bencher};
use attention_round::coordinator::capture::{capture, reference_outputs};
use attention_round::coordinator::model::LoadedModel;
use attention_round::data::{synth, Split};
use attention_round::io::npy;
use attention_round::mixed::{self, kmeans};
use attention_round::quant::rounding;
use attention_round::quant::scale::mse_optimal_scale;
use attention_round::quant::QGrid;
use attention_round::tensor::Tensor;
use attention_round::util::json;
use attention_round::util::rng::Rng;

fn host_benches() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);

    // RNG + gaussian fill
    let mut buf = vec![0.0f32; 1 << 16];
    b.run("host/rng_gaussian_64k", || {
        rng.fill_gaussian(&mut buf, 0.0, 1.0);
    });

    // rounding kernels on a resnet-sized layer (3x3x128x128)
    let mut w = vec![0.0f32; 3 * 3 * 128 * 128];
    Rng::new(2).fill_gaussian(&mut w, 0.0, 0.05);
    let grid = QGrid::signed(4, 0.01).unwrap();
    b.run("host/nearest_147k", || rounding::nearest(&w, &grid));
    let alpha = vec![0.1f32; w.len()];
    b.run("host/attention_finalize_147k", || {
        rounding::attention_finalize(&w, &alpha, &grid)
    });

    // MSE-optimal scale search (3 refinement rounds x 25 candidates)
    b.run("host/mse_scale_search_147k", || {
        mse_optimal_scale(&w, 4).unwrap()
    });

    // coding length on the largest zoo layer view (1152 x 128)
    let wt = Tensor::new(vec![1152, 128], w.clone()).unwrap();
    b.run("host/coding_length_1152x128", || {
        let m = mixed::coding_view(&wt, 1152, 128).unwrap();
        mixed::coding_length(&m, 1e-3).unwrap()
    });

    // exact 1-D k-means over 24 layer lengths
    let lengths: Vec<f64> = (0..24).map(|i| (i as f64 * 7.3) % 97.0).collect();
    b.run("host/kmeans_dp_24x4", || {
        kmeans::cluster_1d(&lengths, 4).unwrap()
    });

    // synthetic workload generation (bench workload path)
    b.run("host/synth_generate_32", || synth::generate(32, 7));

    // JSON manifest parse (if present)
    let dir = artifacts_dir();
    if let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) {
        b.run("host/json_parse_manifest", || json::parse(&text).unwrap());
    }

    // npy read of a weight file (if present)
    if let Some(m) = json_first_weight(&dir) {
        b.run("host/npy_read_weight", || npy::read_f32(&m).unwrap());
    }

    // batch gather (the calibration sampling path)
    let cache = Tensor::zeros(vec![1024, 16, 16, 16]);
    let mut r2 = Rng::new(3);
    b.run("host/gather_8x32_batches", || {
        let idx: Vec<usize> = (0..256).map(|_| r2.below(1024)).collect();
        cache.gather_axis0(&idx).unwrap()
    });
}

fn json_first_weight(dir: &std::path::Path) -> Option<std::path::PathBuf> {
    let j = json::parse(&std::fs::read_to_string(dir.join("manifest.json")).ok()?).ok()?;
    let models = j.get("models").ok()?.as_obj().ok()?;
    let (_, m) = models.iter().next()?;
    let f = m.get("w_files").ok()?.as_arr().ok()?.first()?.as_str().ok()?;
    Some(dir.join(f))
}

fn device_benches() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    let b = Bencher::quick();

    // executable compile latency
    let model = LoadedModel::load(&ctx.manifest, "resnet18t").expect("model");
    let layer = &model.info.layers[1];
    b.run("device/compile_calib_scan", || {
        // fresh runtime so the cache doesn't absorb the cost
        let rt = attention_round::runtime::Runtime::new(
            artifacts_dir().to_str().unwrap(),
        )
        .unwrap();
        rt.load(&layer.calib_scan).unwrap()
    });

    // eval throughput
    let eval_batch = ctx.manifest.dataset.eval_batch;
    let stats = b.run("device/eval_forward_batch128", || {
        use attention_round::coordinator::evaluate::evaluate;
        let small = Split {
            images: ctx.eval.images.slice_axis0(0, eval_batch).unwrap(),
            labels: ctx.eval.labels[..eval_batch].to_vec(),
        };
        evaluate(&ctx.rt, &ctx.manifest, &model, &model.weights, &small).unwrap()
    });
    println!(
        "  -> eval throughput ~{:.0} imgs/s",
        stats.throughput(eval_batch as f64)
    );

    // calibration scan throughput: K fused steps per dispatch
    let cache = capture(
        &ctx.rt, &ctx.manifest, &model, &model.weights, &ctx.calib, 256,
    )
    .expect("capture");
    let x = cache.peek(1).expect("layer1 acts").clone();
    let yref = reference_outputs(
        &ctx.rt,
        &layer.layer_fwd,
        &x,
        &model.weights[1],
        ctx.manifest.dataset.calib_batch,
    )
    .expect("yref");
    let mut cfg = ctx.cfg.clone();
    let scan_k = ctx.manifest.scan_k;
    cfg.iters = scan_k; // exactly one scan call per bench iter
    let mut rng = Rng::new(5);
    let stats = b.run("device/calib_scan_K_steps", || {
        attention_round::coordinator::calibrate::calibrate_attention(
            &ctx.rt,
            layer,
            &model.weights[1],
            &x,
            &yref,
            4,
            &cfg,
            scan_k,
            ctx.manifest.dataset.calib_batch,
            &mut rng,
        )
        .unwrap()
    });
    println!(
        "  -> calibration ~{:.0} Adam steps/s (scan_k={scan_k})",
        stats.throughput(scan_k as f64)
    );

    // single-step loop for the same K steps (the naive baseline the scan
    // replaces — quantifies the §Perf fusion win)
    let exe = ctx.rt.load(&layer.calib_step).expect("calib_step");
    let w = &model.weights[1];
    let stats1 = b.run("device/calib_single_K_steps", || {
        use attention_round::runtime::literal_to_tensor;
        let wbuf = ctx.rt.upload(w).unwrap();
        let mut alpha = Tensor::zeros(w.shape().to_vec());
        let mut m = Tensor::zeros(w.shape().to_vec());
        let mut v = Tensor::zeros(w.shape().to_vec());
        let lr = ctx.rt.upload_scalar(1e-3).unwrap();
        let tau = ctx.rt.upload_scalar(0.5).unwrap();
        let s = ctx.rt.upload_scalar(0.01).unwrap();
        let lo = ctx.rt.upload_scalar(-8.0).unwrap();
        let hi = ctx.rt.upload_scalar(7.0).unwrap();
        let cb = ctx.manifest.dataset.calib_batch;
        for t in 0..scan_k {
            let idx: Vec<usize> = (0..cb).map(|_| rng.below(x.shape()[0])).collect();
            let xb = ctx.rt.upload(&x.gather_axis0(&idx).unwrap()).unwrap();
            let yb = ctx.rt.upload(&yref.gather_axis0(&idx).unwrap()).unwrap();
            let ab = ctx.rt.upload(&alpha).unwrap();
            let mb = ctx.rt.upload(&m).unwrap();
            let vb = ctx.rt.upload(&v).unwrap();
            let tb = ctx.rt.upload_scalar(t as f32).unwrap();
            let outs = exe
                .run_b(&[&wbuf, &xb, &yb, &ab, &mb, &vb, &tb, &lr, &tau, &s, &lo, &hi])
                .unwrap();
            alpha = literal_to_tensor(&outs[0]).unwrap();
            m = literal_to_tensor(&outs[1]).unwrap();
            v = literal_to_tensor(&outs[2]).unwrap();
        }
    });
    println!(
        "  -> scan fusion speedup: {:.2}x",
        stats1.mean_s / stats.mean_s
    );
}

fn main() {
    host_benches();
    device_benches();
}
