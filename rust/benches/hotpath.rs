//! Hot-path microbenchmarks — the §Perf profiling surface.
//!
//! Device-path benches:
//!   * calib scan throughput (steps/s) vs single-step (quantifies the
//!     K-step fusion win)
//!   * eval throughput (imgs/s)
//!   * executable compile latency
//! Host-path benches:
//!   * MSE scale search (fused kernel vs scalar reference), rounding
//!     kernels (allocating vs `_into`), coding length (pooled vs scalar),
//!     parallel bit allocation, percentile selection vs full sort,
//!     k-means, JSON/npy parsing, RNG, batch gather.
//!
//! Flags (after `--`):
//!   * `--quick`  — smoke profile (CI): short budget, host benches only
//!   * `--json P` — write the collected host stats to P (the committed
//!     `BENCH_host.json` baseline)
//!   * `--only S` — run only benches whose name contains S (host benches
//!     only; the CI tracing-overhead gate uses `--only serve_e2e`)

mod common;

use std::path::PathBuf;

use attention_round::backend::{Backend, HostBackend};
use attention_round::bench_harness::{artifacts_dir, write_json, Bencher, Stats};
use attention_round::coordinator::capture::{capture, reference_outputs};
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::data::{synth, Split};
use attention_round::deploy::{bitpack, fused, PackedModel};
use attention_round::io::manifest::{LayerInfo, Manifest};
use attention_round::linalg::Mat;
use attention_round::serve::{self, ServeConfig};
use attention_round::io::npy;
use attention_round::mixed::{self, kmeans};
use attention_round::quant::rounding;
use attention_round::quant::scale::{mse_optimal_scale, mse_optimal_scale_scalar};
use attention_round::quant::QGrid;
use attention_round::tensor::{ops, Tensor};
use attention_round::util::json;
use attention_round::util::rng::Rng;
use attention_round::util::threadpool;

struct Args {
    quick: bool,
    json_path: Option<PathBuf>,
    only: Option<String>,
}

fn parse_args() -> Args {
    let mut quick = false;
    let mut json_path = None;
    let mut only = None;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--json" => json_path = it.next().map(PathBuf::from),
            "--only" => only = it.next(),
            _ => {}
        }
    }
    Args {
        quick,
        json_path,
        only,
    }
}

fn host_benches(b: &Bencher) -> Vec<Stats> {
    let mut all = Vec::new();
    let mut rng = Rng::new(1);
    let pool = threadpool::global();
    println!("host pool: {} threads (AR_THREADS overrides)", pool.size());

    // RNG + gaussian fill
    let mut buf = vec![0.0f32; 1 << 16];
    all.push(b.run("host/rng_gaussian_64k", || {
        rng.fill_gaussian(&mut buf, 0.0, 1.0);
    }));

    // rounding kernels on a resnet-sized layer (3x3x128x128)
    let mut w = vec![0.0f32; 3 * 3 * 128 * 128];
    Rng::new(2).fill_gaussian(&mut w, 0.0, 0.05);
    let grid = QGrid::signed(4, 0.01).unwrap();
    all.push(b.run("host/nearest_147k", || rounding::nearest(&w, &grid)));
    let alpha = vec![0.1f32; w.len()];
    all.push(b.run("host/attention_finalize_147k", || {
        rounding::attention_finalize(&w, &alpha, &grid)
    }));

    // zero-alloc parallel kernel subsystem variants
    let mut qout = vec![0.0f32; w.len()];
    all.push(b.run("host/nearest_into_147k", || {
        rounding::nearest_into(pool, &w, &grid, &mut qout)
    }));
    all.push(b.run("host/attention_finalize_into_147k", || {
        rounding::attention_finalize_into(pool, &w, &alpha, &grid, &mut qout)
    }));
    // stochastic: sequential single-stream reference vs the seeded
    // per-chunk parallel kernel
    all.push(b.run("host/stochastic_147k", || {
        let mut r = Rng::new(11);
        rounding::stochastic(&w, &grid, &mut r)
    }));
    all.push(b.run("host/stochastic_into_147k", || {
        rounding::stochastic_into(pool, &w, &grid, 11, &mut qout)
    }));

    // MSE-optimal scale search (3 refinement rounds x 25 candidates):
    // fused one-pass kernel (the production entry point) vs the scalar
    // 25-passes-per-round reference
    all.push(b.run("host/mse_scale_search_147k", || {
        mse_optimal_scale(&w, 4).unwrap()
    }));
    all.push(b.run("host/mse_scale_search_147k_scalar", || {
        mse_optimal_scale_scalar(&w, 4).unwrap()
    }));

    // coding length on the largest zoo layer view (1152 x 128): pooled
    // blocked Gram (no transpose copy) vs the scalar reference
    let wt = Tensor::new(vec![1152, 128], w.clone()).unwrap();
    all.push(b.run("host/coding_length_1152x128", || {
        let m = mixed::coding_view(&wt, 1152, 128).unwrap();
        mixed::coding_length(&m, 1e-3).unwrap()
    }));
    all.push(b.run("host/coding_length_1152x128_scalar", || {
        let m = mixed::coding_view(&wt, 1152, 128).unwrap();
        mixed::coding_length_scalar(&m, 1e-3).unwrap()
    }));

    // Algorithm 1 with the per-layer coding lengths fanned across the
    // pool (8 synthetic resnet-top-sized layers)
    let alloc_layers: Vec<LayerInfo> =
        (0..8).map(|i| LayerInfo::synthetic(i, 1152, 128, false)).collect();
    let alloc_weights: Vec<Tensor> = (0..8)
        .map(|i| {
            let mut data = vec![0.0f32; 1152 * 128];
            Rng::new(40 + i).fill_gaussian(&mut data, 0.0, 0.03 + 0.01 * i as f32);
            Tensor::new(vec![1152, 128], data).unwrap()
        })
        .collect();
    all.push(b.run("host/allocate_parallel_8x1152x128", || {
        mixed::allocate_with(pool, &alloc_layers, &alloc_weights, &[3, 4, 5, 6], 1e-3).unwrap()
    }));

    // exact 1-D k-means over 24 layer lengths
    let lengths: Vec<f64> = (0..24).map(|i| (i as f64 * 7.3) % 97.0).collect();
    all.push(b.run("host/kmeans_dp_24x4", || {
        kmeans::cluster_1d(&lengths, 4).unwrap()
    }));

    // observer percentile: O(n) selection with scratch reuse vs the old
    // full copy + sort
    let mut scratch: Vec<f32> = Vec::new();
    all.push(b.run("host/percentile_select_147k", || {
        (
            ops::percentile_with(&w, 0.1, &mut scratch),
            ops::percentile_with(&w, 99.9, &mut scratch),
        )
    }));
    all.push(b.run("host/percentile_sort_147k", || {
        let mut v = w.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lo = v[((0.001) * (v.len() - 1) as f64).round() as usize];
        let hi = v[((0.999) * (v.len() - 1) as f64).round() as usize];
        (lo, hi)
    }));

    // synthetic workload generation (bench workload path)
    all.push(b.run("host/synth_generate_32", || synth::generate(32, 7)));

    // JSON manifest parse (if present)
    let dir = artifacts_dir();
    if let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) {
        all.push(b.run("host/json_parse_manifest", || json::parse(&text).unwrap()));
    }

    // npy read of a weight file (if present)
    if let Some(m) = json_first_weight(&dir) {
        all.push(b.run("host/npy_read_weight", || npy::read_f32(&m).unwrap()));
    }

    // batch gather (the calibration sampling path)
    let cache = Tensor::zeros(vec![1024, 16, 16, 16]);
    let mut r2 = Rng::new(3);
    all.push(b.run("host/gather_8x32_batches", || {
        let idx: Vec<usize> = (0..256).map(|_| r2.below(1024)).collect();
        cache.gather_axis0(&idx).unwrap()
    }));

    // batched serving: full load-generator runs (queue + micro-batcher +
    // hot prepared model) on the synthetic model — the serve path is
    // tracked in the baseline from day one. Verification off here: the
    // per-sample direct forwards would dominate the measurement (the
    // no-skip tests in rust/tests/serve.rs own bit-identity).
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let serve_cfg = ServeConfig {
        max_batch: 16,
        queue_depth: 64,
        verify: false,
        ..ServeConfig::default()
    };
    let mut last_report = None;
    all.push(b.run("host/serve_e2e_256req_b16", || {
        let r = serve::run_load_generator(&be, &manifest, "synthnet", &serve_cfg, 256, 4)
            .unwrap();
        assert_eq!(r.completed, 256);
        last_report = Some(r);
    }));
    if let Some(r) = last_report {
        // per-request latency distribution of the final run, as its own
        // baseline row next to the end-to-end wall time
        let lat = r.latency_stats("host/serve_request_latency_256req_b16");
        lat.print();
        println!(
            "  -> serve throughput ~{:.0} req/s (batch mean {:.1}, {} padded rows)",
            r.throughput_rps, r.batch_mean, r.padded_rows
        );
        all.push(lat);
    }

    // fault-tolerant fleet serving: 2 supervised workers off the same
    // bounded queue (the `serve::fleet` path — supervisors, per-worker
    // prepared handles, split width caps). Tracked next to the
    // single-worker row so supervision overhead shows up in the baseline.
    let fleet_cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 64,
        workers: 2,
        verify: false,
        ..ServeConfig::default()
    };
    let mut fleet_report = None;
    all.push(b.run("host/serve_fleet_e2e_256req_w2_b8", || {
        let r = serve::run_load_generator(&be, &manifest, "synthnet", &fleet_cfg, 256, 4)
            .unwrap();
        assert_eq!(r.workers, 2);
        assert_eq!(r.completed, 256);
        assert!(r.accounting_balanced());
        fleet_report = Some(r);
    }));
    if let Some(r) = fleet_report {
        let lat = r.latency_stats("host/serve_fleet_request_latency_256req_w2_b8");
        lat.print();
        println!(
            "  -> fleet throughput ~{:.0} req/s across {} workers (batches/worker {:?})",
            r.throughput_rps, r.workers, r.worker_batches
        );
        all.push(lat);
    }

    // deploy: bitstream pack/unpack of a resnet-layer-sized code vector
    // at 4 bits (the parallel byte-aligned-block kernels)
    let codes: Vec<u32> = {
        let mut r = Rng::new(21);
        (0..w.len()).map(|_| r.below(16) as u32).collect()
    };
    let mut packed_bytes = vec![0u8; bitpack::packed_len(codes.len(), 4)];
    all.push(b.run("host/pack_147k_4b", || {
        bitpack::pack_into_with(pool, &codes, 4, &mut packed_bytes).unwrap()
    }));
    let mut unpacked = vec![0u32; codes.len()];
    all.push(b.run("host/unpack_147k_4b", || {
        bitpack::unpack_into_with(pool, &packed_bytes, 4, &mut unpacked).unwrap()
    }));

    // fused dequant-matmul vs unfused dequantize-then-matmul on the same
    // packed 1152x128 4-bit layer (147456 codes = the vector above) — the
    // kernel-level half of the serving comparison. The unfused row is the
    // old path verbatim: unpack all codes, dequantize to f32, widen both
    // operands into Mats, matmul.
    let fused_act = {
        let mut a = vec![0.0f32; 64 * 1152];
        Rng::new(31).fill_gaussian(&mut a, 0.0, 0.5);
        a
    };
    let fpw = fused::PackedWeight {
        bytes: &packed_bytes,
        bits: 4,
        scale: 0.01,
        n: 1152,
        m: 128,
    };
    let mut fused_out: Vec<f64> = Vec::new();
    all.push(b.run("host/fused_dequant_matmul_64x1152x128_4b", || {
        fused::matmul_packed_with(pool, &fused_act, 64, &fpw, &mut fused_out).unwrap()
    }));
    all.push(b.run("host/unfused_dequant_matmul_64x1152x128_4b", || {
        bitpack::unpack_into_with(pool, &packed_bytes, 4, &mut unpacked).unwrap();
        let wf: Vec<f32> = unpacked.iter().map(|&c| 0.01 * ((c as i64 - 8) as f32)).collect();
        let am = Mat::from_rows_f32(64, 1152, &fused_act).unwrap();
        let wm = Mat::from_rows_f32(1152, 128, &wf).unwrap();
        am.matmul_with(pool, &wm).unwrap()
    }));

    // serving straight off a packed artifact: same load-generator
    // geometry as host/serve_e2e_256req_b16, but the worker multiplies
    // straight off the packed codes (deploy::fused via deploy::dequant)
    // — the pair quantifies the packed-vs-resident serving gap, which
    // the fused kernel is meant to close to ~1.0x.
    let q_out = {
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let spec = QuantSpec {
            model: "synthnet".into(),
            wbits: resolve_uniform_bits(&model, 4),
            abits: None,
        };
        let cfg = CalibConfig {
            method: rounding::Rounding::Nearest,
            calib_samples: 64,
            ..CalibConfig::quick()
        };
        let calib = synth::split(64, synth::CALIB_SEED);
        let eval = synth::split(64, synth::EVAL_SEED);
        quantize_and_eval(&be, &manifest, &spec, &cfg, &calib, &eval).unwrap()
    };
    let art = PackedModel::from_outcome(&q_out, None).unwrap();
    all.push(b.run("host/serve_from_artifact_256req_b16", || {
        let r = serve::run_artifact_load_generator(
            &be, &manifest, &art, &serve_cfg, 256, 4,
        )
        .unwrap();
        assert_eq!(r.completed, 256);
    }));

    // 2-worker fused artifact serving: the lock-free PackedHostForward
    // means fleet workers no longer serialize on a shared dequant
    // scratch — this row is the scaling witness.
    let fused_fleet_cfg = ServeConfig {
        max_batch: 16,
        queue_depth: 64,
        workers: 2,
        verify: false,
        ..ServeConfig::default()
    };
    all.push(b.run("host/serve_fused_from_artifact_256req_w2_b16", || {
        let r = serve::run_artifact_load_generator(
            &be, &manifest, &art, &fused_fleet_cfg, 256, 4,
        )
        .unwrap();
        assert_eq!(r.workers, 2);
        assert_eq!(r.completed, 256);
    }));

    all
}

fn json_first_weight(dir: &std::path::Path) -> Option<std::path::PathBuf> {
    let j = json::parse(&std::fs::read_to_string(dir.join("manifest.json")).ok()?).ok()?;
    let models = j.get("models").ok()?.as_obj().ok()?;
    let (_, m) = models.iter().next()?;
    let f = m.get("w_files").ok()?.as_arr().ok()?.first()?.as_str().ok()?;
    Some(dir.join(f))
}

fn device_benches() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    let b = Bencher::quick();

    // executable compile latency (raw Runtime: the one device-specific
    // surface the backend trait deliberately doesn't abstract)
    let model = ctx
        .backend
        .load_model(&ctx.manifest, "resnet18t")
        .expect("model");
    let layer = &model.info.layers[1];
    b.run("device/compile_calib_scan", || {
        // fresh runtime so the cache doesn't absorb the cost
        let rt = attention_round::runtime::Runtime::new(
            artifacts_dir().to_str().unwrap(),
        )
        .unwrap();
        rt.load(&layer.calib_scan).unwrap()
    });

    // eval throughput
    let eval_batch = ctx.manifest.dataset.eval_batch;
    let stats = b.run("device/eval_forward_batch128", || {
        use attention_round::coordinator::evaluate::evaluate;
        let small = Split {
            images: ctx.eval.images.slice_axis0(0, eval_batch).unwrap(),
            labels: ctx.eval.labels[..eval_batch].to_vec(),
        };
        evaluate(
            ctx.backend.as_ref(), &ctx.manifest, &model, &model.weights, &small,
        )
        .unwrap()
    });
    println!(
        "  -> eval throughput ~{:.0} imgs/s",
        stats.throughput(eval_batch as f64)
    );

    // calibration scan throughput: K fused steps per dispatch
    let cache = capture(
        ctx.backend.as_ref(), &ctx.manifest, &model, &model.weights, &ctx.calib, 256,
    )
    .expect("capture");
    let x = cache.peek(1).expect("layer1 acts").clone();
    let yref = reference_outputs(
        ctx.backend.as_ref(),
        layer,
        &x,
        &model.weights[1],
        ctx.manifest.dataset.calib_batch,
    )
    .expect("yref");
    let mut cfg = ctx.cfg.clone();
    let scan_k = ctx.manifest.scan_k;
    cfg.iters = scan_k; // exactly one scan call per bench iter
    let mut rng = Rng::new(5);
    let stats = b.run("device/calib_scan_K_steps", || {
        attention_round::coordinator::calibrate::calibrate_attention(
            ctx.backend.as_ref(),
            layer,
            &model.weights[1],
            &x,
            &yref,
            4,
            &cfg,
            scan_k,
            ctx.manifest.dataset.calib_batch,
            &mut rng,
        )
        .unwrap()
    });
    println!(
        "  -> calibration ~{:.0} Adam steps/s (scan_k={scan_k})",
        stats.throughput(scan_k as f64)
    );

    // single-step loop for the same K steps (the naive baseline the scan
    // replaces — quantifies the §Perf fusion win). Raw-buffer runtime
    // path on purpose: this measures dispatch overhead below the trait.
    let rt = attention_round::runtime::Runtime::new(
        artifacts_dir().to_str().unwrap(),
    )
    .unwrap();
    let exe = rt.load(&layer.calib_step).expect("calib_step");
    let w = &model.weights[1];
    let stats1 = b.run("device/calib_single_K_steps", || {
        use attention_round::runtime::literal_to_tensor;
        let wbuf = rt.upload(w).unwrap();
        let mut alpha = Tensor::zeros(w.shape().to_vec());
        let mut m = Tensor::zeros(w.shape().to_vec());
        let mut v = Tensor::zeros(w.shape().to_vec());
        let lr = rt.upload_scalar(1e-3).unwrap();
        let tau = rt.upload_scalar(0.5).unwrap();
        let s = rt.upload_scalar(0.01).unwrap();
        let lo = rt.upload_scalar(-8.0).unwrap();
        let hi = rt.upload_scalar(7.0).unwrap();
        let cb = ctx.manifest.dataset.calib_batch;
        for t in 0..scan_k {
            let idx: Vec<usize> = (0..cb).map(|_| rng.below(x.shape()[0])).collect();
            let xb = rt.upload(&x.gather_axis0(&idx).unwrap()).unwrap();
            let yb = rt.upload(&yref.gather_axis0(&idx).unwrap()).unwrap();
            let ab = rt.upload(&alpha).unwrap();
            let mb = rt.upload(&m).unwrap();
            let vb = rt.upload(&v).unwrap();
            let tb = rt.upload_scalar(t as f32).unwrap();
            let outs = exe
                .run_b(&[&wbuf, &xb, &yb, &ab, &mb, &vb, &tb, &lr, &tau, &s, &lo, &hi])
                .unwrap();
            alpha = literal_to_tensor(&outs[0]).unwrap();
            m = literal_to_tensor(&outs[1]).unwrap();
            v = literal_to_tensor(&outs[2]).unwrap();
        }
    });
    println!(
        "  -> scan fusion speedup: {:.2}x",
        stats1.mean_s / stats.mean_s
    );
}

fn main() {
    let args = parse_args();
    let mut b = if args.quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    b.only = args.only.clone();
    let mut stats = host_benches(&b);
    // filtered-out rows come back as iters==0 placeholders; drop them so
    // a --only run never pollutes the committed baseline
    stats.retain(|s| s.iters > 0);
    if let Some(p) = &args.json_path {
        write_json(p, &stats).expect("write bench json");
        println!("wrote {} host bench entries to {}", stats.len(), p.display());
    }
    if !args.quick && args.only.is_none() {
        device_benches();
    }
}
