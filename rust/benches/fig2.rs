//! Bench: regenerate Figure 2 (τ sweep) at bench scale.
//! Full-scale: `repro reproduce fig2 --taus 0,0.1,...,1.0`.

mod common;

use attention_round::coordinator::experiments;

fn main() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    // bench-scale: two τ points weights-only (full sweep incl. W+A via
    // `repro reproduce fig2`)
    use attention_round::coordinator::pipeline::{
        quantize_and_eval, resolve_uniform_bits, QuantSpec,
    };
    let loaded = ctx.backend.load_model(&ctx.manifest, "resnet18t").expect("model");
    for tau in [0.0f32, 0.5] {
        let mut cfg = ctx.cfg.clone();
        cfg.tau = tau;
        let spec = QuantSpec {
            model: "resnet18t".into(),
            wbits: resolve_uniform_bits(&loaded, 4),
            abits: None,
        };
        let out = quantize_and_eval(
            ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg, &ctx.calib, &ctx.eval,
        )
        .expect("run");
        println!("fig2 bench point: τ={tau} -> {:.2}%", out.acc * 100.0);
    }
    let _ = experiments::fig2 as usize;
}
