//! Shared setup for the bench binaries (criterion substitute — see
//! bench_harness).

use attention_round::bench_harness::artifacts_dir;
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::experiments::Ctx;

/// Build an experiment context with a bench-sized calibration budget, or
/// None (with a notice) when artifacts haven't been built yet — benches
/// must not fail a bare `cargo bench` on a fresh checkout.
pub fn bench_ctx(iters: usize) -> Option<Ctx> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!(
            "SKIP: no artifacts at {} (run `make artifacts` first)",
            dir.display()
        );
        return None;
    }
    let mut cfg = CalibConfig::quick();
    cfg.iters = iters;
    cfg.calib_samples = 256; // bench scale; full runs via `repro reproduce`
    let mut ctx = Ctx::new(
        dir.to_str().expect("utf8 artifacts path"),
        cfg,
        "target/bench_results",
    )
    .expect("bench ctx");
    // Shrink the eval split to two batches: benches measure pipeline
    // latency, not statistical accuracy.
    let eb = ctx.manifest.dataset.eval_batch;
    let n = (eb * 2).min(ctx.eval.images.shape()[0]);
    ctx.eval = attention_round::data::Split {
        images: ctx.eval.images.slice_axis0(0, n).expect("slice"),
        labels: ctx.eval.labels[..n].to_vec(),
    };
    Some(ctx)
}
