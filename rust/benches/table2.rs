//! Bench: regenerate Table 2 (weights + activations PTQ) at bench scale.
//! Full-scale: `repro reproduce table2`.

mod common;

use attention_round::coordinator::experiments;

fn main() {
    let Some(ctx) = common::bench_ctx(16) else { return };
    // bench-scale: one W+A row end-to-end (full table via `repro reproduce table2`)
    use attention_round::coordinator::pipeline::{
        quantize_and_eval, resolve_uniform_bits, QuantSpec,
    };
    let loaded = ctx.backend.load_model(&ctx.manifest, "resnet18t").expect("model");
    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&loaded, 4),
        abits: Some(4),
    };
    let out = quantize_and_eval(
        ctx.backend.as_ref(), &ctx.manifest, &spec, &ctx.cfg, &ctx.calib, &ctx.eval,
    )
    .expect("4/4 run");
    println!(
        "table2 bench row: resnet18t 4/4 -> {:.2}% (fp {:.2}%) in {:.1}s",
        out.acc * 100.0,
        out.fp_acc * 100.0,
        out.wall_s
    );
    let _ = experiments::table2 as usize; // full harness exercised by `repro reproduce`
}
