//! End-to-end tests for the packed-artifact deployment subsystem on the
//! synthetic host model. **No test here self-skips** — the host backend
//! needs zero artifacts, so every clause runs on a bare checkout.
//!
//! Covered, per the deployment contract:
//! * a model quantized by the pipeline at the paper's mixed-precision
//!   allocation packs to **< 50 %** of the f32 baseline, round-trips
//!   through `save`/`load` bit-identically, and serves via
//!   `run_artifact_load_generator` with every response verified
//!   bit-for-bit against direct quantize-then-forward;
//! * the activation-quant deployment config (act_params + act_bits)
//!   rides along and the artifact serve path runs `forward_actq`;
//! * legacy v1 directories load, `repack` migrates them to packed v2,
//!   and the migrated artifact still dequantizes to the same tensors.

use attention_round::backend::{Backend, HostBackend};
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::pipeline::{quantize_and_eval, QuantSpec};
use attention_round::coordinator::state;
use attention_round::data::synth;
use attention_round::deploy::{self, PackedModel};
use attention_round::io::manifest::Manifest;
use attention_round::io::npy;
use attention_round::mixed;
use attention_round::quant::rounding::Rounding;
use attention_round::serve::{self, ServeConfig};
use attention_round::tensor::Tensor;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ar_deploy_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Quantize the synthetic model at the paper's Algorithm-1 mixed
/// allocation ({3,4,5,6}-bit list) through the real pipeline.
fn mixed_outcome(
    be: &HostBackend,
    manifest: &Manifest,
    abits: Option<u8>,
) -> (
    attention_round::coordinator::pipeline::Outcome,
    Vec<f64>,
) {
    let model = be.load_model(manifest, "synthnet").unwrap();
    let alloc =
        mixed::allocate(&model.info.layers, &model.weights, &[3, 4, 5, 6], 1e-3)
            .unwrap();
    let spec = QuantSpec {
        model: "synthnet".into(),
        wbits: alloc.bits.clone(),
        abits,
    };
    let cfg = CalibConfig {
        method: Rounding::Nearest, // static rounding: fast, exact-grid
        calib_samples: 64,
        ..CalibConfig::quick()
    };
    let calib = synth::split(64, synth::CALIB_SEED);
    let eval = synth::split(64, synth::EVAL_SEED);
    let out = quantize_and_eval(be, manifest, &spec, &cfg, &calib, &eval).unwrap();
    (out, alloc.lengths)
}

#[test]
fn mixed_precision_pack_roundtrips_and_beats_half_size() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (out, lengths) = mixed_outcome(&be, &manifest, None);
    let art = PackedModel::from_outcome(&out, Some(&lengths)).unwrap();
    // acceptance: packed weight bytes < 50% of the f32 baseline at the
    // paper's mixed-precision allocation
    let c = deploy::summarize(&art);
    assert!(
        (c.packed_bytes as f64) < 0.5 * c.f32_bytes as f64,
        "ratio {} must be < 0.5",
        c.ratio
    );
    assert!(c.effective_bits <= 8.0 + 1e-9);
    // provenance recorded
    assert!(art.layers.iter().all(|l| l.coding_length.is_some()));
    // disk round-trip is bit-identical
    let dir = tmpdir("mixed");
    art.save(&dir).unwrap();
    let back = PackedModel::load(&dir).unwrap();
    assert_eq!(back.format_version, 2);
    for (li, qw) in out.qweights.iter().enumerate() {
        assert_eq!(
            back.dequantize(li).unwrap(),
            *qw,
            "layer {li} must dequantize bit-identically"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn serve_from_artifact_bit_identical_to_quantize_then_forward() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (out, lengths) = mixed_outcome(&be, &manifest, None);
    let art = PackedModel::from_outcome(&out, Some(&lengths)).unwrap();
    let dir = tmpdir("serve");
    art.save(&dir).unwrap();
    let art = PackedModel::load(&dir).unwrap(); // serve what disk has
    let cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 16,
        verify: true, // every response vs direct forward of dequantized weights
        ..ServeConfig::default()
    };
    let report =
        serve::run_artifact_load_generator(&be, &manifest, &art, &cfg, 48, 3)
            .unwrap();
    assert_eq!(report.completed, 48, "every request must complete");
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0);
    // and the dequantized weights really are the pipeline's qweights,
    // so "direct forward" above == quantize-then-forward
    for (li, qw) in out.qweights.iter().enumerate() {
        assert_eq!(art.dequantize(li).unwrap(), *qw);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn serve_from_artifact_carries_the_actq_deployment_config() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (out, _) = mixed_outcome(&be, &manifest, Some(8));
    assert!(out.act_params.is_some() && out.act_bits.is_some());
    let art = PackedModel::from_outcome(&out, None).unwrap();
    let dir = tmpdir("actq");
    art.save(&dir).unwrap();
    let art = PackedModel::load(&dir).unwrap();
    assert_eq!(
        art.act_bits.as_ref().unwrap(),
        out.act_bits.as_ref().unwrap(),
        "activation widths must survive the disk round-trip"
    );
    // verify=true compares against direct forward_actq with the same
    // config — a pass means the artifact path served the actq model
    let cfg = ServeConfig {
        max_batch: 4,
        queue_depth: 8,
        verify: true,
        ..ServeConfig::default()
    };
    let report =
        serve::run_artifact_load_generator(&be, &manifest, &art, &cfg, 24, 2)
            .unwrap();
    assert_eq!(report.completed, 24);
    assert_eq!(report.errors, 0);
}

#[test]
fn v1_dir_loads_repacks_and_migrates_to_v2() {
    // Hand-write a v1 directory the way the pre-deploy state store did:
    // full-f32 npy per layer, no act_bits — with on-grid values so the
    // migration can actually pack them.
    let dir = tmpdir("v1mig");
    std::fs::create_dir_all(&dir).unwrap();
    let s = 0.25f32;
    let q0 = Tensor::new(vec![2, 3], vec![0.25, -0.5, 0.0, 0.75, -1.0, 0.5]).unwrap();
    npy::write_f32(&dir.join("00_stem.q.npy"), &q0).unwrap();
    std::fs::write(
        dir.join("qmodel.json"),
        format!(
            r#"{{
              "format_version": 1,
              "model": "legacy", "method": "nearest",
              "acc": 0.5, "fp_acc": 0.9,
              "layers": [{{"name": "stem", "bits": 4, "scale": {s}}}],
              "weight_files": ["00_stem.q.npy"]
            }}"#
        ),
    )
    .unwrap();
    let mut art = PackedModel::load(&dir).unwrap();
    assert_eq!(art.format_version, 1);
    assert_eq!(art.dequantize(0).unwrap(), q0);
    // migrate: repack + save emits v2 with a packed payload
    let packed_layers = art.repack().unwrap();
    assert_eq!(packed_layers, 1, "on-grid v1 layer must repack");
    let dir2 = tmpdir("v1mig_out");
    art.save(&dir2).unwrap();
    let back = PackedModel::load(&dir2).unwrap();
    assert_eq!(back.format_version, 2);
    assert!(back.payload_bytes() < back.f32_bytes());
    assert_eq!(back.dequantize(0).unwrap(), q0, "migration must be lossless");
    // and the state-store veneer reads both generations
    let via_state = state::load(&dir).unwrap();
    assert_eq!(via_state.qweights[0], q0);
    let via_state2 = state::load(&dir2).unwrap();
    assert_eq!(via_state2.qweights[0], q0);
    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::remove_dir_all(&dir2).unwrap();
}

#[test]
fn artifact_for_the_wrong_model_shape_is_rejected_at_serve() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (out, _) = mixed_outcome(&be, &manifest, None);
    let mut bad = PackedModel::from_outcome(&out, None).unwrap();
    // claim a different shape for layer 0 than the synthnet model has
    bad.layers[0].shape = vec![4, 4];
    let cfg = ServeConfig::default();
    assert!(
        serve::run_artifact_load_generator(&be, &manifest, &bad, &cfg, 8, 1)
            .is_err(),
        "shape-mismatched artifact must be rejected before serving"
    );
}
