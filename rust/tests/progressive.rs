//! End-to-end tests for the chunked (v3) artifact + progressive
//! partial-depth serving subsystem on the synthetic host model. **No
//! test here self-skips** — the host backend needs zero artifacts, so
//! every clause runs on a bare checkout.
//!
//! Covered, per the progressive-serving contract:
//! * a partial-depth answer is **bit-for-bit** the truncated direct
//!   forward: features through the resident prefix (served off the
//!   packed codes), average-pooled, read out through the
//!   nearest-class-mean head calibrated at that depth — reconstructed
//!   here independently from public APIs only;
//! * once every chunk is resident, the progressive forward is
//!   **bit-identical** to the non-progressive packed artifact path;
//! * chunks must load in order, and forwards beyond residency are
//!   rejected rather than served with absent weights;
//! * a fleet run under the `slow-loader` chaos scenario hot-swaps
//!   chunks in while serving: accounting stays balanced, at least one
//!   row is answered below full depth, and the run converges to the
//!   full resident depth.

use attention_round::backend::{Backend, HostBackend};
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::model::LoadedModel;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, Outcome, QuantSpec,
};
use attention_round::data::synth;
use attention_round::deploy::artifact::load_v3_meta;
use attention_round::deploy::{PackedModel, ProgressiveModel};
use attention_round::io::manifest::{Manifest, ModelInfo};
use attention_round::quant::rounding::Rounding;
use attention_round::serve::{self, ServeConfig};
use attention_round::tensor::Tensor;

/// The synthetic-head prototype draw (`backend::host::PROTO_*`): the
/// progressive model calibrates its partial-depth readouts from the
/// same fixed generator draw, so the reference head here must too.
const PROTO_SAMPLES: usize = 384;
const PROTO_SEED: u64 = 0xFEED;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ar_progressive_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Quantize the synthetic model uniformly at 4 bits through the real
/// pipeline (static rounding: fast, exact-grid).
fn uniform_outcome(be: &HostBackend, manifest: &Manifest) -> Outcome {
    let loaded = be.load_model(manifest, "synthnet").unwrap();
    let spec = QuantSpec {
        model: "synthnet".into(),
        wbits: resolve_uniform_bits(&loaded, 4),
        abits: None,
    };
    let cfg = CalibConfig {
        method: Rounding::Nearest,
        calib_samples: 64,
        ..CalibConfig::quick()
    };
    let calib = synth::split(64, synth::CALIB_SEED);
    let eval = synth::split(64, synth::EVAL_SEED);
    quantize_and_eval(be, manifest, &spec, &cfg, &calib, &eval).unwrap()
}

/// Global average pool, replicating `backend::host::avg_pool` exactly
/// (sum rows, then scale by 1/hw); identity on 2-D features.
fn pooled(t: Tensor) -> Tensor {
    let sh = t.shape().to_vec();
    if sh.len() != 4 {
        return t;
    }
    let (b, hw, c) = (sh[0], sh[1] * sh[2], sh[3]);
    let inv = 1.0 / hw as f32;
    let mut out = vec![0.0f32; b * c];
    for bi in 0..b {
        let img = &t.data()[bi * hw * c..(bi + 1) * hw * c];
        let dst = &mut out[bi * c..(bi + 1) * c];
        for row in img.chunks_exact(c) {
            for (d, &v) in dst.iter_mut().zip(row) {
                *d += v;
            }
        }
        for d in dst.iter_mut() {
            *d *= inv;
        }
    }
    Tensor::new(vec![b, c], out).unwrap()
}

/// The truncated direct forward a partial-depth answer must match
/// bit-for-bit, built **independently** from public APIs: stage a
/// `d`-layer artifact through `Backend::prepare_artifact` (the packed
/// host path), pool its features, and read out through the
/// nearest-class-mean head (`W[:,c] = μ_c`, `b_c = −‖μ_c‖²/2`)
/// calibrated over the synthetic-head prototype draw at that depth.
fn truncated_reference(
    be: &HostBackend,
    manifest: &Manifest,
    out: &Outcome,
    d: usize,
    x: &Tensor,
) -> Tensor {
    let model = be.load_model(manifest, "synthnet").unwrap();
    let k = model.info.layers.len();
    let hm = model.info.layers[k - 1].wshape[1];
    let tm = LoadedModel {
        info: ModelInfo {
            layers: model.info.layers[..d].to_vec(),
            ..model.info.clone()
        },
        weights: model.weights[..d].to_vec(),
        biases: model.biases[..d].to_vec(),
    };
    let tout = Outcome {
        model: out.model.clone(),
        method: out.method,
        acc: out.acc,
        fp_acc: out.fp_acc,
        per_layer: out.per_layer[..d].to_vec(),
        qweights: out.qweights[..d].to_vec(),
        act_params: None,
        act_bits: None,
        wall_s: 0.0,
    };
    let tart = PackedModel::from_outcome(&tout, None).unwrap();
    let mut staged = Vec::new();
    let direct = be.prepare_artifact(&tm, &tart, &mut staged).unwrap();

    // class-mean head at this depth over the fixed prototype draw
    let (imgs, labels) = synth::generate(PROTO_SAMPLES, PROTO_SEED);
    let feats = pooled(direct.forward(&imgs).unwrap());
    let f = feats.shape()[1];
    let mut sums = vec![0.0f64; f * hm];
    let mut counts = vec![0usize; hm];
    for (bi, &lab) in labels.iter().enumerate() {
        let c = lab as usize % hm;
        counts[c] += 1;
        for (j, &v) in feats.data()[bi * f..(bi + 1) * f].iter().enumerate() {
            sums[j * hm + c] += v as f64;
        }
    }
    let mut wh = vec![0.0f32; f * hm];
    let mut bh = vec![0.0f32; hm];
    for c in 0..hm {
        if counts[c] == 0 {
            continue;
        }
        let inv = 1.0 / counts[c] as f64;
        let mut norm2 = 0.0f64;
        for j in 0..f {
            let mu = sums[j * hm + c] * inv;
            wh[j * hm + c] = mu as f32;
            norm2 += mu * mu;
        }
        bh[c] = (-0.5 * norm2) as f32;
    }

    // apply: logits = f·W + b, f64 accumulate in the same loop order
    let fx = pooled(direct.forward(x).unwrap());
    let (rows, fdim) = (fx.shape()[0], fx.shape()[1]);
    assert_eq!(fdim, f, "prefix feature width must match the head");
    let mut logits = vec![0.0f32; rows * hm];
    for i in 0..rows {
        let frow = &fx.data()[i * fdim..(i + 1) * fdim];
        for c in 0..hm {
            let mut acc = bh[c] as f64;
            for (j, &v) in frow.iter().enumerate() {
                acc += v as f64 * wh[j * hm + c] as f64;
            }
            logits[i * hm + c] = acc as f32;
        }
    }
    Tensor::new(vec![rows, hm], logits).unwrap()
}

#[test]
fn partial_depth_answers_match_truncated_direct_forward_bit_for_bit() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let out = uniform_outcome(&be, &manifest);
    let art = PackedModel::from_outcome(&out, None).unwrap();
    let dir = tmpdir("partial");
    let m = art.save_chunked(&dir, 3, 1).unwrap();
    assert_eq!(m.chunks.len(), 3);
    assert_eq!(m.min_runnable_depth, 1);
    assert!(dir.join("manifest.json").exists());
    assert!(dir.join("qmodel.qpak").exists());

    let model = be.load_model(&manifest, "synthnet").unwrap();
    let meta = load_v3_meta(&dir).unwrap();
    let pm = ProgressiveModel::open(&model, meta).unwrap();
    let x = synth::split(8, synth::EVAL_SEED).images;

    // nothing resident yet: forwards and out-of-order loads rejected
    assert!(pm.forward_at_chunks(&x, 0, None).is_err());
    assert!(pm.forward_at_chunks(&x, 1, None).is_err());
    assert!(pm.load_chunk(1).is_err(), "chunks must load in order");

    for rc in 1..=2usize {
        pm.load_chunk(rc - 1).unwrap();
        assert_eq!(pm.resident_chunks(), rc);
        // residency beyond what's loaded stays rejected
        assert!(pm.forward_at_chunks(&x, rc + 1, None).is_err());
        let (got, depth) = pm.forward_at_chunks(&x, rc, None).unwrap();
        assert_eq!(depth, rc, "one layer per chunk on the 3-layer model");
        let want = truncated_reference(&be, &manifest, &out, depth, &x);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(
            got.data(),
            want.data(),
            "partial answer at depth {depth} must be bit-for-bit the \
             truncated direct forward"
        );
    }
    assert!(pm.partial_rows() >= 16, "two partial forwards of 8 rows");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn converged_progressive_forward_is_bit_identical_to_packed_path() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let out = uniform_outcome(&be, &manifest);
    let art = PackedModel::from_outcome(&out, None).unwrap();
    let dir = tmpdir("full");
    art.save_chunked(&dir, 2, 1).unwrap();

    let model = be.load_model(&manifest, "synthnet").unwrap();
    let meta = load_v3_meta(&dir).unwrap();
    let pm = ProgressiveModel::open(&model, meta).unwrap();
    pm.load_chunk(0).unwrap();
    pm.load_chunk(1).unwrap();
    assert_eq!(pm.resident_chunks(), 2);
    assert_eq!(pm.resident_depth(), 3);

    // the non-progressive path: the v2 loader reads the chunked dir and
    // the backend stages it as usual
    let back = PackedModel::load(&dir).unwrap();
    let mut staged = Vec::new();
    let direct = be.prepare_artifact(&model, &back, &mut staged).unwrap();

    let x = synth::split(8, synth::EVAL_SEED).images;
    let (got, depth) = pm.forward_at_chunks(&x, 2, None).unwrap();
    assert_eq!(depth, 3, "full residency serves full depth");
    let want = direct.forward(&x).unwrap();
    assert_eq!(got.shape(), want.shape());
    assert_eq!(
        got.data(),
        want.data(),
        "converged progressive forward must be bit-identical to the \
         packed artifact path"
    );

    // the fleet-facing handle serves the same logits and reports depth
    let handle = pm.handle();
    use attention_round::backend::PreparedModel;
    let via_handle = handle.forward(&x).unwrap();
    assert_eq!(via_handle.data(), want.data());
    assert_eq!(handle.resident_depth(), Some(3));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fleet_serve_hot_swaps_chunks_under_slow_loader_chaos() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let out = uniform_outcome(&be, &manifest);
    let art = PackedModel::from_outcome(&out, None).unwrap();
    let dir = tmpdir("fleet");
    art.save_chunked(&dir, 3, 1).unwrap();

    let cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 256,
        workers: 2,
        verify: true, // post-convergence bit-identity probe
        chaos: Some(
            serve::ChaosSpec::scenario("slow-loader", serve::CHAOS_SEED).unwrap(),
        ),
        ..ServeConfig::default()
    };
    let report = serve::run_progressive_load_generator(
        &be,
        &manifest,
        &dir,
        &cfg,
        96,
        3,
    )
    .unwrap();
    assert_eq!(report.submitted, 96);
    assert_eq!(report.errors, 0, "slow-loader injects no faults");
    assert!(
        report.accounting_balanced(),
        "terminal-state accounting must balance under hot-swap"
    );
    assert_eq!(
        report.resident_depth, 3,
        "the run must converge to full depth"
    );
    assert!(
        report.depth_served_partial >= 1,
        "25ms/chunk loading under 600 rps traffic must answer some \
         rows below full depth"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
