//! Property suite for the fused dequant-matmul kernel and the explicit
//! SIMD paths: the fused forward must equal dequantize-then-matmul with
//! `assert_eq!` (no tolerance) for every width 2–8, ragged shape, and
//! pool width, and every `core::arch` path must equal its scalar
//! fallback bit for bit. Run with `--no-default-features` too — CI does
//! — to pin the scalar-only build to the same outputs.

use attention_round::deploy::bitpack;
use attention_round::deploy::fused::{matmul_packed_with, PackedWeight};
use attention_round::linalg::{simd, Mat};
use attention_round::quant::kernel::{
    quantize_attention_slice, quantize_attention_slice_scalar, quantize_nearest_slice,
    quantize_nearest_slice_scalar,
};
use attention_round::util::rng::Rng;
use attention_round::util::threadpool::ThreadPool;

fn random_codes(n: usize, bits: u8, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(1usize << bits) as u32).collect()
}

fn random_acts(len: usize, seed: u64) -> Vec<f32> {
    let mut a = vec![0.0f32; len];
    Rng::new(seed).fill_gaussian(&mut a, 0.0, 0.7);
    a
}

/// The unfused reference path: unpack every code, dequantize into a
/// full f32 layer with the artifact's `s · q` multiply, widen both
/// operands into `Mat`s, and run the dense matmul.
fn dequant_then_matmul(
    pool: &ThreadPool,
    act: &[f32],
    rows: usize,
    pw: &PackedWeight<'_>,
) -> Vec<f64> {
    let mut codes = vec![0u32; pw.n * pw.m];
    bitpack::unpack_into(pw.bytes, pw.bits, &mut codes).unwrap();
    let lo = -(1i64 << (pw.bits - 1));
    let w: Vec<f32> = codes
        .iter()
        .map(|&c| pw.scale * ((c as i64 + lo) as f32))
        .collect();
    let am = Mat::from_rows_f32(rows, pw.n, act).unwrap();
    let wm = Mat::from_rows_f32(pw.n, pw.m, &w).unwrap();
    am.matmul_with(pool, &wm).unwrap().data
}

#[test]
fn fused_equals_dequant_then_matmul_all_widths_shapes_pools() {
    let pools = [ThreadPool::seq(), ThreadPool::new(2), ThreadPool::new(8)];
    for bits in bitpack::MIN_BITS..=bitpack::MAX_BITS {
        for &(rows, n, m) in &[
            (1usize, 1usize, 1usize),
            (7, 5, 3),
            (16, 9, 4),
            (33, 17, 10),
            (8, 128, 16),
            (64, 31, 2),
            (5, 300, 40), // > PANEL_ELEMS per panel-row sweep at m=40
        ] {
            let codes = random_codes(n * m, bits, 1000 + n as u64 * 7 + bits as u64);
            let bytes = bitpack::pack(&codes, bits).unwrap();
            let pw = PackedWeight {
                bytes: &bytes,
                bits,
                scale: 0.004 * bits as f32,
                n,
                m,
            };
            let act = random_acts(rows * n, 31 + rows as u64);
            let want = dequant_then_matmul(&pools[0], &act, rows, &pw);
            for (pi, pool) in pools.iter().enumerate() {
                let mut got = Vec::new();
                matmul_packed_with(pool, &act, rows, &pw, &mut got).unwrap();
                assert_eq!(
                    got, want,
                    "fused != unfused at bits={bits} {rows}x{n}x{m} pool#{pi}"
                );
            }
        }
    }
}

#[test]
fn fused_handles_zero_weight_layers() {
    let seq = ThreadPool::seq();
    for bits in [2u8, 5, 8] {
        let (rows, n, m) = (6usize, 24usize, 9usize);
        // code 2^(b-1) sits at grid point 0 for every width
        let codes = vec![1u32 << (bits - 1); n * m];
        let bytes = bitpack::pack(&codes, bits).unwrap();
        let pw = PackedWeight { bytes: &bytes, bits, scale: 0.05, n, m };
        let act = random_acts(rows * n, 5);
        let mut got = Vec::new();
        matmul_packed_with(&seq, &act, rows, &pw, &mut got).unwrap();
        assert_eq!(got, dequant_then_matmul(&seq, &act, rows, &pw));
        assert!(got.iter().all(|&v| v == 0.0), "bits={bits}");
    }
}

#[test]
fn fused_parallel_equals_sequential_on_large_layer() {
    // crosses MIN_PAR_CHUNK so par_row_blocks really fans out, and the
    // 1152-row walk spans many panels
    let (rows, n, m) = (32usize, 1152usize, 128usize);
    let codes = random_codes(n * m, 4, 0xBEE);
    let bytes = bitpack::pack(&codes, 4).unwrap();
    let pw = PackedWeight { bytes: &bytes, bits: 4, scale: 0.01, n, m };
    let act = random_acts(rows * n, 0xACE);
    let mut seq_out = Vec::new();
    matmul_packed_with(&ThreadPool::seq(), &act, rows, &pw, &mut seq_out).unwrap();
    for width in [2usize, 8] {
        let mut par_out = Vec::new();
        matmul_packed_with(&ThreadPool::new(width), &act, rows, &pw, &mut par_out).unwrap();
        assert_eq!(seq_out, par_out, "pool width {width}");
    }
    assert_eq!(seq_out, dequant_then_matmul(&ThreadPool::seq(), &act, rows, &pw));
}

#[test]
fn axpy_simd_equals_scalar() {
    let mut rng = Rng::new(0xA0);
    for &n in &[0usize, 1, 2, 3, 5, 7, 8, 9, 13, 16, 17, 100, 1001] {
        let mut bf = vec![0.0f32; n];
        rng.fill_gaussian(&mut bf, 0.0, 1.0);
        let b: Vec<f64> = bf.iter().map(|&v| v as f64).collect();
        let mut c0: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 7.0).collect();
        let mut c1 = c0.clone();
        for a in [0.0f64, -0.0, 1.0, -2.75, 1e-8] {
            simd::axpy(&mut c0, a, &b);
            simd::axpy_scalar(&mut c1, a, &b);
            assert_eq!(c0, c1, "axpy diverged at n={n} a={a}");
        }
    }
}

#[test]
fn quantize_slices_simd_equal_scalar() {
    let mut rng = Rng::new(0x51DE);
    for &n in &[0usize, 1, 3, 4, 7, 8, 9, 15, 16, 31, 997] {
        let mut w = vec![0.0f32; n];
        let mut alpha = vec![0.0f32; n];
        rng.fill_gaussian(&mut w, 0.0, 0.4);
        rng.fill_gaussian(&mut alpha, 0.0, 0.5);
        for (s, lo, hi) in [(0.07f32, -8.0f32, 7.0f32), (0.013, -2.0, 1.0)] {
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            quantize_nearest_slice(&w, s, lo, hi, &mut got);
            quantize_nearest_slice_scalar(&w, s, lo, hi, &mut want);
            assert_eq!(got, want, "nearest n={n} s={s}");
            quantize_attention_slice(&w, &alpha, s, lo, hi, &mut got);
            quantize_attention_slice_scalar(&w, &alpha, s, lo, hi, &mut want);
            assert_eq!(got, want, "attention n={n} s={s}");
        }
    }
}

#[test]
fn dense_matmul_unconditional_axpy_matches_naive_with_zero_rich_input() {
    // the old inner loop skipped av == 0.0; the vectorized loop must
    // produce identical results on zero-rich activations (±0.0 products
    // from a +0.0 start never flip a bit for finite data)
    let mut rng = Rng::new(0x0);
    let (m, k, n) = (9usize, 14usize, 6usize);
    let mut a = vec![0.0f32; m * k];
    rng.fill_gaussian(&mut a, 0.0, 1.0);
    for (i, v) in a.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0; // a third of the entries exactly zero (post-ReLU shape)
        }
        if i % 7 == 0 {
            *v = -0.0;
        }
    }
    let mut b = vec![0.0f32; k * n];
    rng.fill_gaussian(&mut b, 0.0, 1.0);
    let am = Mat::from_rows_f32(m, k, &a).unwrap();
    let bm = Mat::from_rows_f32(k, n, &b).unwrap();
    let got = am.matmul_with(&ThreadPool::seq(), &bm).unwrap();
    // naive ascending-k reference with the skip, in f64
    let mut want = vec![0.0f64; m * n];
    for i in 0..m {
        for t in 0..k {
            let av = a[i * k + t] as f64;
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                want[i * n + j] += av * b[t * n + j] as f64;
            }
        }
    }
    assert_eq!(got.data, want);
}
