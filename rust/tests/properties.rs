//! Property-based tests (via the in-repo util::proptest driver) on the
//! quantizer and allocator invariants. No artifacts needed — pure host
//! math, so these run on any checkout.

use attention_round::mixed::kmeans;
use attention_round::quant::rounding;
use attention_round::quant::scale::mse_optimal_scale;
use attention_round::quant::{attention_probability, QGrid};
use attention_round::tensor::ops;
use attention_round::util::proptest::{check, shrink_vec, Config};
use attention_round::util::rng::Rng;

fn gen_weights(r: &mut Rng) -> Vec<f32> {
    let n = 1 + r.below(512);
    let std = 0.01 + r.next_f32() * 0.5;
    let mut w = vec![0.0f32; n];
    r.fill_gaussian(&mut w, 0.0, std);
    w
}

#[test]
fn prop_nearest_is_mse_optimal_rounding() {
    // Among all grid points, nearest-round picks the per-element argmin:
    // no other static rounding can have lower elementwise error.
    check(
        Config { cases: 64, ..Default::default() },
        gen_weights,
        |w| shrink_vec(w),
        |w| {
            let g = QGrid::signed(4, mse_optimal_scale(w, 4).unwrap()).unwrap();
            let n = rounding::nearest(w, &g);
            let f = rounding::floor(w, &g);
            let c = rounding::ceil(w, &g);
            let en = ops::mse(w, &n);
            if en > ops::mse(w, &f) + 1e-12 {
                return Err(format!("nearest {en} worse than floor"));
            }
            if en > ops::mse(w, &c) + 1e-12 {
                return Err(format!("nearest {en} worse than ceil"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_roundings_stay_on_grid() {
    check(
        Config { cases: 48, ..Default::default() },
        |r| (gen_weights(r), r.next_u64()),
        |_| vec![],
        |(w, seed)| {
            let g = QGrid::signed(3, mse_optimal_scale(w, 3).unwrap()).unwrap();
            let mut rng = Rng::new(*seed);
            let alpha: Vec<f32> = w.iter().map(|_| rng.gaussian_f32(0.0, 0.5)).collect();
            for (name, q) in [
                ("nearest", rounding::nearest(w, &g)),
                ("floor", rounding::floor(w, &g)),
                ("ceil", rounding::ceil(w, &g)),
                ("stochastic", rounding::stochastic(w, &g, &mut rng)),
                ("attention", rounding::attention_finalize(w, &alpha, &g)),
                ("adaround", rounding::adaround_finalize(w, &alpha, &g)),
            ] {
                for &v in &q {
                    if !g.contains(v) {
                        return Err(format!("{name} produced off-grid {v}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_error_bounded_by_grid_step() {
    // For values inside the clip range, |w - nearest(w)| <= s/2.
    check(
        Config { cases: 64, ..Default::default() },
        gen_weights,
        |w| shrink_vec(w),
        |w| {
            let s = mse_optimal_scale(w, 8).unwrap();
            let g = QGrid::signed(8, s).unwrap();
            let q = rounding::nearest(w, &g);
            for (&wv, &qv) in w.iter().zip(&q) {
                let clipped = wv.clamp(g.lo * s, g.hi * s);
                if (clipped - qv).abs() > s / 2.0 + 1e-5 {
                    return Err(format!("error {} > s/2 {}", (clipped - qv).abs(), s / 2.0));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_attention_probabilities_form_distribution() {
    check(
        Config { cases: 64, ..Default::default() },
        |r| {
            (
                r.gaussian_f32(0.0, 1.0),
                0.01 + r.next_f32() * 0.5,
                r.next_f32(),
            )
        },
        |_| vec![],
        |(w, step, tau)| {
            // cover w ± 10τ so the Gaussian mass is fully inside the grid
            let reach = ((w.abs() + 10.0 * tau) / step).ceil() as i64 + 2;
            let mut total = 0.0;
            let mut peak = (0.0f64, 0i64);
            for k in -reach..=reach {
                let p = attention_probability(*w, k as f32 * step, *step, *tau);
                if !(0.0..=1.0 + 1e-9).contains(&p) {
                    return Err(format!("p out of range: {p}"));
                }
                if p > peak.0 {
                    peak = (p, k);
                }
                total += p;
            }
            if (total - 1.0).abs() > 1e-3 {
                return Err(format!("probabilities sum to {total}"));
            }
            // the peak must be the nearest grid point
            let nearest_k = (w / step).round() as i64;
            if peak.1 != nearest_k {
                return Err(format!("peak at {} but nearest is {nearest_k}", peak.1));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_ids_ordered_by_value() {
    // Cluster ids are ordered: a value in a higher cluster is >= every
    // value in a lower cluster.
    check(
        Config { cases: 64, ..Default::default() },
        |r| {
            let n = 2 + r.below(40);
            (0..n).map(|_| r.next_f64() * 100.0).collect::<Vec<f64>>()
        },
        |v| shrink_vec(v),
        |values| {
            let k = 3.min(values.len());
            let ids = kmeans::cluster_1d(values, k).map_err(|e| e.to_string())?;
            for (i, &vi) in values.iter().enumerate() {
                for (j, &vj) in values.iter().enumerate() {
                    if ids[i] < ids[j] && vi > vj + 1e-12 {
                        return Err(format!(
                            "value {vi} in cluster {} above {vj} in cluster {}",
                            ids[i], ids[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stochastic_round_unbiased() {
    // Mean of repeated stochastic rounding converges to w in-range.
    check(
        Config { cases: 16, ..Default::default() },
        |r| (r.gaussian_f32(0.0, 0.3), r.next_u64()),
        |_| vec![],
        |(w, seed)| {
            let g = QGrid::signed(8, 0.05).unwrap();
            let mut rng = Rng::new(*seed);
            let trials = 4000;
            let mut acc = 0.0f64;
            for _ in 0..trials {
                acc += rounding::stochastic(&[*w], &g, &mut rng)[0] as f64;
            }
            let mean = acc / trials as f64;
            let clipped = (*w).clamp(g.lo * g.scale, g.hi * g.scale) as f64;
            if (mean - clipped).abs() > 0.004 {
                return Err(format!("biased: mean {mean} vs {clipped}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_more_bits_never_hurt_mse_scale() {
    check(
        Config { cases: 32, ..Default::default() },
        gen_weights,
        |w| shrink_vec(w),
        |w| {
            if w.iter().all(|&v| v == 0.0) {
                return Ok(());
            }
            let mut prev = f64::INFINITY;
            for bits in [2u8, 4, 6, 8] {
                let g = QGrid::signed(bits, mse_optimal_scale(w, bits).unwrap()).unwrap();
                let e = ops::mse(w, &rounding::nearest(w, &g));
                if e > prev * 1.05 + 1e-12 {
                    return Err(format!("{bits} bits worse than fewer: {e} > {prev}"));
                }
                prev = e;
            }
            Ok(())
        },
    );
}
