//! Trace-validity suite for the unified tracing subsystem
//! (`attention_round::trace`).
//!
//! The tracer is process-global (one enabled flag, one registry of
//! per-thread rings), so every test here serializes on one mutex and
//! calls `trace::reset()` first — they exercise *shared* state and must
//! not interleave. Cross-thread invariants pinned:
//!
//! * every thread's Begin/End stream is balanced — **including** when a
//!   span is dropped by a panic unwind (the chaos-injection path);
//! * timestamps are non-negative and monotonic non-decreasing per
//!   thread;
//! * a disabled tracer records nothing — instrumentation sites are inert
//!   branches, not buffered writes;
//! * the Chrome exporter round-trips through `util::json::parse` with
//!   per-thread `thread_name` metadata lanes;
//! * ring wraparound drops oldest-first and surfaces the drop count.
//!
//! Everything is gated on `trace::available()`: the
//! `--no-default-features` CI lane compiles the tracer out, and these
//! tests must pass (vacuously) there too.

use std::sync::Mutex;

use attention_round::trace::{self, Category, Kind};
use attention_round::util::json;

/// Global-tracer-state serialization: `cargo test` runs tests in
/// parallel threads within this binary.
static TRACER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a previous test panicking while holding the lock must not
    // cascade — the tracer state is re-reset by every test anyway
    TRACER_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn disabled_tracer_records_nothing() {
    let _g = lock();
    trace::reset();
    assert!(!trace::enabled());
    let span = trace::span(Category::Pipeline, "invisible");
    trace::instant(Category::Serve, "also-invisible");
    trace::counter(Category::Serve, "depth", 3.0);
    assert!(!span.is_armed());
    drop(span);
    for snap in trace::snapshot() {
        assert!(
            snap.events.is_empty(),
            "disabled tracer buffered {} events on tid {}",
            snap.events.len(),
            snap.tid
        );
    }
}

#[test]
fn spans_balance_per_thread_and_timestamps_are_monotonic() {
    let _g = lock();
    trace::reset();
    if !trace::available() {
        return;
    }
    trace::enable();
    {
        let _outer = trace::span(Category::Pipeline, "outer");
        for i in 0..4 {
            let _inner = trace::span(Category::Calib, format!("layer:{i}"));
            trace::instant(Category::Serve, "tick");
        }
    }
    std::thread::scope(|s| {
        for t in 0..3 {
            s.spawn(move || {
                trace::set_thread_label(&format!("worker-{t}"));
                let _span = trace::span(Category::Serve, "batch");
                trace::counter(Category::Serve, "queue_depth", t as f64);
            });
        }
    });
    trace::disable();

    let snapshots = trace::snapshot();
    assert!(snapshots.iter().any(|s| !s.events.is_empty()));
    let mut worker_lanes = 0usize;
    for snap in &snapshots {
        let mut depth = 0i64;
        let mut last_ts = 0u64;
        for ev in &snap.events {
            assert!(
                ev.ts_us >= last_ts,
                "tid {}: ts went backwards ({} after {})",
                snap.tid,
                ev.ts_us,
                last_ts
            );
            last_ts = ev.ts_us;
            match ev.kind {
                Kind::Begin => depth += 1,
                Kind::End => {
                    depth -= 1;
                    assert!(depth >= 0, "tid {}: End before Begin", snap.tid);
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0, "tid {}: unbalanced B/E stream", snap.tid);
        if snap.label.as_deref().is_some_and(|l| l.starts_with("worker-")) {
            worker_lanes += 1;
        }
    }
    assert_eq!(worker_lanes, 3, "every labeled worker thread gets a lane");
}

#[test]
fn panic_unwind_closes_open_spans() {
    let _g = lock();
    trace::reset();
    if !trace::available() {
        return;
    }
    trace::enable();
    // same thread all the way down: the span guard must emit its End
    // during the unwind, exactly like a chaos-injected worker panic
    let r = std::panic::catch_unwind(|| {
        let _span = trace::span(Category::Serve, "doomed-batch");
        trace::instant(Category::Chaos, "inject:panic@batch0");
        panic!("injected");
    });
    assert!(r.is_err());
    trace::disable();

    let snapshots = trace::snapshot();
    let snap = snapshots
        .iter()
        .find(|s| s.events.iter().any(|e| e.name.contains("doomed-batch")))
        .expect("the panicking thread's lane");
    let begins = snap
        .events
        .iter()
        .filter(|e| matches!(e.kind, Kind::Begin))
        .count();
    let ends = snap
        .events
        .iter()
        .filter(|e| matches!(e.kind, Kind::End))
        .count();
    assert_eq!(begins, ends, "unwind must balance the B/E stream");
    assert!(snap
        .events
        .iter()
        .any(|e| matches!(e.kind, Kind::Instant) && e.name.starts_with("inject:")));
}

#[test]
fn mid_span_disable_still_closes_the_span() {
    let _g = lock();
    trace::reset();
    if !trace::available() {
        return;
    }
    trace::enable();
    let span = trace::span(Category::Pipeline, "straddler");
    trace::disable();
    drop(span); // End must still be recorded — the Begin is in the ring
    let snapshots = trace::snapshot();
    let snap = snapshots
        .iter()
        .find(|s| s.events.iter().any(|e| e.name.contains("straddler")))
        .expect("the straddling span's lane");
    let opens = snap
        .events
        .iter()
        .filter(|e| e.name.contains("straddler") && matches!(e.kind, Kind::Begin))
        .count();
    let closes = snap
        .events
        .iter()
        .filter(|e| e.name.contains("straddler") && matches!(e.kind, Kind::End))
        .count();
    assert_eq!(opens, 1);
    assert_eq!(closes, 1, "disable between B and E must not orphan the B");
}

#[test]
fn chrome_export_roundtrips_with_thread_lanes() {
    let _g = lock();
    trace::reset();
    if !trace::available() {
        return;
    }
    trace::enable();
    trace::set_thread_label("main");
    {
        let _span = trace::span(Category::Pack, "pack:model");
        trace::instant(Category::Chaos, "inject:spike@batch3");
        trace::counter(Category::Serve, "queue_depth", 7.0);
    }
    trace::disable();

    let path = std::env::temp_dir().join(format!(
        "trace_export_test_{}.json",
        std::process::id()
    ));
    let count = trace::chrome::export(&path).expect("export");
    assert!(count >= 4, "M + B + i + C + E at minimum, got {count}");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let j = json::parse(&text).expect("exported trace must be valid JSON");
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), count);
    let mut saw_meta = false;
    let mut saw_begin = false;
    let mut saw_instant = false;
    let mut saw_counter = false;
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        match ph {
            "M" => {
                assert_eq!(ev.get("name").unwrap().as_str().unwrap(), "thread_name");
                saw_meta = true;
            }
            "B" | "E" => {
                assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                saw_begin = true;
            }
            "i" => saw_instant = true,
            "C" => {
                let v = ev
                    .get("args")
                    .unwrap()
                    .get("value")
                    .unwrap()
                    .as_f64()
                    .unwrap();
                assert_eq!(v, 7.0);
                saw_counter = true;
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(saw_meta && saw_begin && saw_instant && saw_counter);
}

#[test]
fn reset_clears_buffers_and_disables() {
    let _g = lock();
    trace::reset();
    if !trace::available() {
        return;
    }
    trace::enable();
    trace::instant(Category::Serve, "pre-reset");
    trace::reset();
    assert!(!trace::enabled());
    for snap in trace::snapshot() {
        assert!(snap.events.is_empty());
        assert!(snap.label.is_none());
    }
}
