//! Cross-module integration tests over real artifacts (PJRT backend).
//!
//! These need `make artifacts` to have run; they self-skip (with a
//! notice) otherwise, so `cargo test` stays green on a fresh checkout.
//! The artifact-free end-to-end path is covered by tests/host_backend.rs,
//! which never skips. Each test builds its own PJRT backend.

use attention_round::backend::{Backend, PjrtBackend};
use attention_round::coordinator::calibrate::calibrate_attention;
use attention_round::coordinator::capture::{capture, reference_outputs};
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::evaluate::{evaluate, evaluate_actq};
use attention_round::coordinator::model::LoadedModel;
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_act_bits, resolve_uniform_bits, QuantSpec,
};
use attention_round::data::Split;
use attention_round::io::manifest::Manifest;
use attention_round::quant::observer::{observe, ObserverKind};
use attention_round::quant::rounding::Rounding;
use attention_round::tensor::Tensor;
use attention_round::util::rng::Rng;

fn artifacts() -> Option<String> {
    let dir = std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP integration test: no artifacts at {dir} (run `make artifacts`)");
        None
    }
}

/// Tiny eval split (2 batches) to keep device tests fast.
fn small_eval(manifest: &Manifest) -> Split {
    let dir = manifest.path(&manifest.dataset.dir);
    let full = Split::load(&dir, "eval").expect("eval split");
    let n = manifest.dataset.eval_batch * 2;
    Split {
        images: full.images.slice_axis0(0, n).unwrap(),
        labels: full.labels[..n].to_vec(),
    }
}

#[test]
fn manifest_and_weights_agree() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    assert!(manifest.scan_k >= 1);
    for m in &manifest.models {
        let model = LoadedModel::load(&manifest, &m.name).expect("load model");
        assert_eq!(model.weights.len(), m.layers.len());
        // coding views must tile the weight exactly
        for (l, w) in m.layers.iter().zip(&model.weights) {
            assert_eq!(l.coding_n * l.coding_m, w.len(), "{}/{}", m.name, l.name);
        }
        // first/last pinned (paper §4.1)
        assert!(m.layers.first().unwrap().pinned_8bit);
        assert!(m.layers.last().unwrap().pinned_8bit);
    }
}

#[test]
fn fp_eval_matches_buildtime_accuracy() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let eval_dir = manifest.path(&manifest.dataset.dir);
    let eval = Split::load(&eval_dir, "eval").expect("eval");
    let acc = evaluate(&be, &manifest, &model, &model.weights, &eval).expect("eval");
    // Full-split PJRT evaluation must agree with the build-time JAX number.
    assert!(
        (acc - model.info.fp_acc).abs() < 0.005,
        "PJRT {acc} vs build-time {}",
        model.info.fp_acc
    );
}

#[test]
fn capture_reference_and_calibration_reduce_loss() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let calib_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&calib_dir, "calib").expect("calib");

    let mut cache = capture(&be, &manifest, &model, &model.weights, &calib, 64)
        .expect("capture");
    assert_eq!(cache.len(), model.num_layers());

    let li = 1; // first non-pinned conv
    let layer = &model.info.layers[li];
    let x = cache.take(li).expect("acts");
    assert_eq!(x.shape()[0], 64);
    assert_eq!(&x.shape()[1..], &layer.in_shape[1..]);
    // double-take must fail loudly
    assert!(cache.take(li).is_err());

    let yref = reference_outputs(
        &be,
        layer,
        &x,
        &model.weights[li],
        manifest.dataset.calib_batch,
    )
    .expect("yref");
    assert_eq!(&yref.shape()[1..], &layer.out_shape[1..]);

    let mut cfg = CalibConfig::quick();
    cfg.iters = 16;
    let mut rng = Rng::new(7);
    let cal = calibrate_attention(
        &be,
        layer,
        &model.weights[li],
        &x,
        &yref,
        3, // 3-bit: aggressive enough that calibration has work to do
        &cfg,
        manifest.scan_k,
        manifest.dataset.calib_batch,
        &mut rng,
    )
    .expect("calibrate");
    assert!(
        cal.last_loss < cal.first_loss,
        "loss should decrease: {} -> {}",
        cal.first_loss,
        cal.last_loss
    );
    // quantized weights live on the grid
    for &v in cal.qweight.data() {
        assert!(cal.grid.contains(v), "{v} off grid");
    }
}

#[test]
fn attention_beats_nearest_at_low_bits() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let calib_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&calib_dir, "calib").expect("calib");
    let eval = small_eval(&manifest);

    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&model, 3),
        abits: None,
    };
    let mut cfg = CalibConfig::quick();
    cfg.iters = 16;
    cfg.calib_samples = 128;

    cfg.method = Rounding::Nearest;
    let near = quantize_and_eval(&be, &manifest, &spec, &cfg, &calib, &eval)
        .expect("nearest");
    cfg.method = Rounding::Attention;
    let ours = quantize_and_eval(&be, &manifest, &spec, &cfg, &calib, &eval)
        .expect("attention");
    eprintln!(
        "3-bit: nearest {:.4} vs attention {:.4} (fp {:.4})",
        near.acc, ours.acc, ours.fp_acc
    );
    assert!(
        ours.acc >= near.acc,
        "attention ({}) must not lose to nearest ({}) at 3 bits",
        ours.acc,
        near.acc
    );
}

#[test]
fn actq_eval_runs_and_degrades_gracefully() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let calib_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&calib_dir, "calib").expect("calib");
    let eval = small_eval(&manifest);

    // observers from a small capture
    let mut cache = capture(&be, &manifest, &model, &model.weights, &calib, 64)
        .expect("capture");
    let mut params = Vec::new();
    for li in 0..model.num_layers() {
        let x = cache.take(li).unwrap();
        params.push(observe(x.data(), 8, ObserverKind::Mse).unwrap());
    }
    let bits8 = resolve_act_bits(&model, 8);
    let acc8 = evaluate_actq(
        &be, &manifest, &model, &model.weights, &params, &bits8, &eval,
    )
    .expect("actq 8");
    // 8-bit activations should track FP closely on this small split
    let fp = evaluate(&be, &manifest, &model, &model.weights, &eval).expect("fp");
    assert!(
        (acc8 - fp).abs() < 0.08,
        "8-bit act quant drifted: {acc8} vs fp {fp}"
    );
}

#[test]
fn rust_synth_generator_transfers_to_the_model() {
    // The Rust port of the dataset generator must produce samples the
    // JAX-trained model classifies far above chance — the cross-language
    // distribution contract.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let n = manifest.dataset.eval_batch * 2;
    let (images, labels) = attention_round::data::synth::generate(n, 999);
    let split = Split { images, labels };
    let acc = evaluate(&be, &manifest, &model, &model.weights, &split).expect("eval");
    eprintln!("rust-synth transfer accuracy: {acc:.4}");
    assert!(
        acc > 0.5,
        "model should transfer to rust-generated data (chance = 1/16), got {acc}"
    );
}

#[test]
fn quantized_weights_differ_from_fp_but_stay_close() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let be = PjrtBackend::new(dir.as_str()).expect("backend");
    let model = be.load_model(&manifest, "resnet18t").expect("model");
    let calib_dir = manifest.path(&manifest.dataset.dir);
    let calib = Split::load(&calib_dir, "calib").expect("calib");
    let eval = small_eval(&manifest);
    let mut cfg = CalibConfig::quick();
    cfg.iters = 16;
    cfg.calib_samples = 128;
    cfg.method = Rounding::Nearest; // static rounding: fast, same invariant
    let spec = QuantSpec {
        model: "resnet18t".into(),
        wbits: resolve_uniform_bits(&model, 4),
        abits: None,
    };
    let out = quantize_and_eval(&be, &manifest, &spec, &cfg, &calib, &eval)
        .expect("quantize");
    for (q, w) in out.qweights.iter().zip(&model.weights) {
        let d: f64 = crate_mse(q, w);
        assert!(d > 0.0, "quantization must change weights");
        let scale_sq = (out.per_layer[0].scale as f64).powi(2);
        let _ = scale_sq;
        // error bounded by one grid step RMS-wise (loose sanity bound)
        assert!(d.sqrt() < 0.2, "unreasonable quantization error {d}");
    }
}

fn crate_mse(a: &Tensor, b: &Tensor) -> f64 {
    attention_round::tensor::ops::mse(a.data(), b.data())
}
