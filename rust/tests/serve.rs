//! End-to-end tests for the batched serving subsystem on the synthetic
//! host model. **No test here self-skips** — the host backend needs zero
//! artifacts, so every clause runs on a bare checkout.
//!
//! Covered, per the serving contract:
//! * serve-path responses are **bit-identical** to a direct `forward` of
//!   the same samples (micro-batching + padding must never change what
//!   the model computes);
//! * admission control rejects with a typed error when the queue is
//!   full, and hands the request back intact;
//! * a padded final batch returns only real results — exactly one
//!   response per request, none for pad rows;
//! * a concurrent multi-producer run completes every request with a
//!   clean shutdown and non-zero throughput.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use attention_round::backend::{Backend, HostBackend};
use attention_round::io::manifest::Manifest;
use attention_round::serve::{
    self, run_worker, AdmissionError, RequestQueue, ServeConfig, ServeRequest,
    ServeResponse, WorkerConfig,
};
use attention_round::data::synth;
use attention_round::tensor::Tensor;

fn sample(x: &Tensor, i: usize) -> Tensor {
    let t = x.slice_axis0(i, 1).unwrap();
    let dims = t.shape()[1..].to_vec();
    t.reshape(dims).unwrap()
}

/// Drive `n` requests through a worker with the given batch geometry and
/// return the responses in id order.
fn serve_n(
    be: &HostBackend,
    manifest: &Manifest,
    n: usize,
    max_batch: usize,
) -> (Tensor, Vec<Tensor>) {
    let model = be.load_model(manifest, "synthnet").unwrap();
    let prepared = be.prepare_serving(&model, &model.weights).unwrap();
    let inputs = synth::generate(n, 555).0;
    let queue = RequestQueue::new(n.max(1));
    let metrics = serve::ServeMetrics::new();
    let wcfg = WorkerConfig {
        max_batch,
        max_wait: Duration::from_micros(100),
        width: 1, // tiny model: keep the worker's inner kernels inline
        actq: None,
    };
    let (rtx, rrx) = channel::<ServeResponse>();
    let mut out: Vec<Option<Tensor>> = vec![None; n];
    std::thread::scope(|s| {
        s.spawn(|| run_worker(prepared.as_ref(), &queue, &wcfg, &metrics));
        for i in 0..n {
            queue
                .push(ServeRequest {
                    id: i as u64,
                    input: sample(&inputs, i),
                    submitted: Instant::now(),
                    tx: rtx.clone(),
                })
                .unwrap();
        }
        drop(rtx);
        for _ in 0..n {
            let resp = rrx.recv().expect("one response per request");
            let t = resp.result.expect("forward should succeed");
            assert!(out[resp.id as usize].is_none(), "duplicate response");
            out[resp.id as usize] = Some(t);
        }
        // no extra responses for pad rows: the channel must now be empty
        // (give a stray sender a moment before asserting)
        std::thread::sleep(Duration::from_millis(20));
        assert!(
            rrx.try_recv().is_err(),
            "pad rows must not produce responses"
        );
        queue.close();
    });
    (inputs, out.into_iter().map(Option::unwrap).collect())
}

#[test]
fn serve_outputs_bit_identical_to_direct_forward() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (inputs, served) = serve_n(&be, &manifest, 12, 4);
    let model = be.load_model(&manifest, "synthnet").unwrap();
    let direct = be.prepare(&model, &model.weights).unwrap();
    for (i, got) in served.iter().enumerate() {
        let x = inputs.slice_axis0(i, 1).unwrap();
        let want = direct.forward(&x).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(
            got.data(),
            want.data(),
            "request {i}: serve row must be bit-identical to direct forward"
        );
    }
}

#[test]
fn padded_final_batch_returns_only_real_results() {
    // 5 requests, batch 4 -> one full batch + one padded (1 real + 3 pad
    // rows). serve_n already asserts exactly-one-response-per-request and
    // an empty channel afterwards; here we also pin the values.
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (inputs, served) = serve_n(&be, &manifest, 5, 4);
    assert_eq!(served.len(), 5);
    let model = be.load_model(&manifest, "synthnet").unwrap();
    let direct = be.prepare(&model, &model.weights).unwrap();
    let x4 = inputs.slice_axis0(4, 1).unwrap();
    let want = direct.forward(&x4).unwrap();
    assert_eq!(
        served[4].data(),
        want.data(),
        "the lone real row of the padded batch must be that sample's logits"
    );
}

#[test]
fn admission_control_rejects_when_queue_is_full() {
    let queue = RequestQueue::new(3);
    let (tx, _rx) = channel();
    let mk = |id: u64| ServeRequest {
        id,
        input: Tensor::zeros(vec![2, 2, 1]),
        submitted: Instant::now(),
        tx: tx.clone(),
    };
    for id in 0..3 {
        assert!(queue.push(mk(id)).is_ok());
    }
    let rej = queue.push(mk(3)).unwrap_err();
    assert_eq!(rej.error, AdmissionError::QueueFull { depth: 3 });
    assert_eq!(rej.request.id, 3, "rejected request handed back intact");
    // a typed Closed rejection after shutdown begins
    queue.close();
    let rej = queue.push(mk(4)).unwrap_err();
    assert_eq!(rej.error, AdmissionError::Closed);
}

#[test]
fn concurrent_multi_producer_smoke() {
    // Small queue + several producers forces real contention: admission
    // rejections with retry, coalesced batches, clean drain at close.
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_depth: 8,
        worker_width: 0,
        verify: true, // every response re-checked against direct forward
        actq: None,
    };
    let report =
        serve::run_load_generator(&be, &manifest, "synthnet", &cfg, 192, 4).unwrap();
    assert_eq!(report.completed, 192, "every request must complete");
    assert_eq!(report.errors, 0);
    assert!(report.throughput_rps > 0.0, "non-zero sustained throughput");
    assert!(report.batches >= 192 / 8, "batches actually coalesced");
    assert!(
        report.lat_p50_s <= report.lat_p95_s && report.lat_p95_s <= report.lat_p99_s,
        "latency percentiles must be monotone"
    );
    assert!(report.wall_s > 0.0);
    // the JSON report round-trips through the in-repo parser
    let parsed = attention_round::util::json::parse(&report.to_json()).unwrap();
    assert_eq!(
        parsed
            .get("serve")
            .unwrap()
            .get("completed")
            .unwrap()
            .as_f64()
            .unwrap(),
        192.0
    );
}
