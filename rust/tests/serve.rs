//! End-to-end tests for the batched serving subsystem on the synthetic
//! host model. **No test here self-skips** — the host backend needs zero
//! artifacts, so every clause runs on a bare checkout.
//!
//! Covered, per the serving contract:
//! * serve-path answers are **bit-identical** to a direct `forward` of
//!   the same samples (micro-batching + padding must never change what
//!   the model computes) — single worker and 2-worker fleet;
//! * admission control rejects with a typed error when the queue is
//!   full, and hands the request back intact;
//! * `close()` racing any number of mid-`push` producers resolves every
//!   push (admit or typed rejection) — never a deadlock;
//! * a padded final batch returns only real results — exactly one
//!   response per request, none for pad rows;
//! * expired requests are shed *before* forward compute (`batches == 0`
//!   for all-expired traffic) and answered with a typed `Expired`;
//! * with worker-crash chaos injection the fleet restarts the worker and
//!   every submitted request reaches exactly one terminal state
//!   (accounting balances);
//! * the full chaos scenario matrix runs no-skip with zero lost
//!   requests per scenario.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use attention_round::backend::{Backend, HostBackend};
use attention_round::data::synth;
use attention_round::io::manifest::Manifest;
use attention_round::serve::{
    self, run_worker, AdmissionError, ChaosSpec, RequestQueue, ServeConfig,
    ServeOutcome, ServeRequest, ServeResponse, WorkerConfig,
};
use attention_round::tensor::Tensor;

fn sample(x: &Tensor, i: usize) -> Tensor {
    let t = x.slice_axis0(i, 1).unwrap();
    let dims = t.shape()[1..].to_vec();
    t.reshape(dims).unwrap()
}

/// Drive `n` requests through a worker with the given batch geometry and
/// return the responses in id order.
fn serve_n(
    be: &HostBackend,
    manifest: &Manifest,
    n: usize,
    max_batch: usize,
) -> (Tensor, Vec<Tensor>) {
    let model = be.load_model(manifest, "synthnet").unwrap();
    let prepared = be.prepare_serving(&model, &model.weights).unwrap();
    let inputs = synth::generate(n, 555).0;
    let queue = RequestQueue::new(n.max(1));
    let metrics = serve::ServeMetrics::new();
    let wcfg = WorkerConfig {
        max_batch,
        max_wait: Duration::from_micros(100),
        width: 1, // tiny model: keep the worker's inner kernels inline
        actq: None,
        chaos: None,
    };
    let (rtx, rrx) = channel::<ServeResponse>();
    let mut out: Vec<Option<Tensor>> = vec![None; n];
    std::thread::scope(|s| {
        s.spawn(|| run_worker(0, prepared.as_ref(), &queue, &wcfg, &metrics));
        for i in 0..n {
            queue
                .push(ServeRequest {
                    id: i as u64,
                    input: sample(&inputs, i),
                    submitted: Instant::now(),
                    deadline: None,
                    tx: rtx.clone(),
                })
                .unwrap();
        }
        drop(rtx);
        for _ in 0..n {
            let resp = rrx.recv().expect("one response per request");
            let t = match resp.outcome {
                ServeOutcome::Answer(t) => t,
                other => panic!("request {} got {:?} kind", resp.id, other.kind()),
            };
            assert!(out[resp.id as usize].is_none(), "duplicate response");
            out[resp.id as usize] = Some(t);
        }
        // no extra responses for pad rows: the channel must now be empty
        // (give a stray sender a moment before asserting)
        std::thread::sleep(Duration::from_millis(20));
        assert!(
            rrx.try_recv().is_err(),
            "pad rows must not produce responses"
        );
        queue.close();
    });
    (inputs, out.into_iter().map(Option::unwrap).collect())
}

#[test]
fn serve_outputs_bit_identical_to_direct_forward() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (inputs, served) = serve_n(&be, &manifest, 12, 4);
    let model = be.load_model(&manifest, "synthnet").unwrap();
    let direct = be.prepare(&model, &model.weights).unwrap();
    for (i, got) in served.iter().enumerate() {
        let x = inputs.slice_axis0(i, 1).unwrap();
        let want = direct.forward(&x).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(
            got.data(),
            want.data(),
            "request {i}: serve row must be bit-identical to direct forward"
        );
    }
}

#[test]
fn padded_final_batch_returns_only_real_results() {
    // 5 requests, batch 4 -> one full batch + one padded (1 real + 3 pad
    // rows). serve_n already asserts exactly-one-response-per-request and
    // an empty channel afterwards; here we also pin the values.
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let (inputs, served) = serve_n(&be, &manifest, 5, 4);
    assert_eq!(served.len(), 5);
    let model = be.load_model(&manifest, "synthnet").unwrap();
    let direct = be.prepare(&model, &model.weights).unwrap();
    let x4 = inputs.slice_axis0(4, 1).unwrap();
    let want = direct.forward(&x4).unwrap();
    assert_eq!(
        served[4].data(),
        want.data(),
        "the lone real row of the padded batch must be that sample's logits"
    );
}

#[test]
fn admission_control_rejects_when_queue_is_full() {
    let queue = RequestQueue::new(3);
    let (tx, _rx) = channel();
    let mk = |id: u64| ServeRequest {
        id,
        input: Tensor::zeros(vec![2, 2, 1]),
        submitted: Instant::now(),
        deadline: None,
        tx: tx.clone(),
    };
    for id in 0..3 {
        assert!(queue.push(mk(id)).is_ok());
    }
    let rej = queue.push(mk(3)).unwrap_err();
    assert_eq!(rej.error, AdmissionError::QueueFull { depth: 3 });
    assert_eq!(rej.request.id, 3, "rejected request handed back intact");
    // a typed Closed rejection after shutdown begins
    queue.close();
    let rej = queue.push(mk(4)).unwrap_err();
    assert_eq!(rej.error, AdmissionError::Closed);
}

#[test]
fn close_racing_concurrent_pushers_never_deadlocks() {
    // The regression the bounded queue must hold: close() against any
    // number of mid-push producers resolves every push immediately —
    // admitted, QueueFull, or Closed with the request intact. A wedge
    // here hangs the scope join (and the test, which IS the detector).
    let queue = RequestQueue::new(4);
    let (tx, rx) = channel::<ServeResponse>();
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let queue = &queue;
            let tx = tx.clone();
            s.spawn(move || {
                for i in 0..500u64 {
                    let id = t * 1000 + i;
                    let req = ServeRequest {
                        id,
                        input: Tensor::zeros(vec![2]),
                        submitted: Instant::now(),
                        deadline: None,
                        tx: tx.clone(),
                    };
                    match queue.push(req) {
                        Ok(depth) => assert!(depth >= 1 && depth <= 4),
                        Err(rej) => {
                            assert_eq!(
                                rej.request.id, id,
                                "rejected request handed back intact"
                            );
                            assert!(matches!(
                                rej.error,
                                AdmissionError::QueueFull { .. }
                                    | AdmissionError::Closed
                            ));
                        }
                    }
                }
            });
        }
        // drain concurrently so pushers make progress, close mid-storm
        {
            let queue = &queue;
            s.spawn(move || {
                while queue.pop_batch(4, Duration::from_micros(10)).is_some() {}
            });
        }
        std::thread::sleep(Duration::from_millis(2));
        queue.close();
    });
    drop(tx);
    assert!(queue.is_closed());
    // post-close pushes still resolve to a typed Closed, request intact
    let (tx2, _rx2) = channel();
    let rej = queue
        .push(ServeRequest {
            id: 9999,
            input: Tensor::zeros(vec![2]),
            submitted: Instant::now(),
            deadline: None,
            tx: tx2,
        })
        .unwrap_err();
    assert_eq!(rej.error, AdmissionError::Closed);
    assert_eq!(rej.request.id, 9999);
    drop(rx);
}

#[test]
fn expired_requests_are_shed_before_any_forward() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let model = be.load_model(&manifest, "synthnet").unwrap();
    let prepared = be.prepare_serving(&model, &model.weights).unwrap();
    let inputs = synth::generate(4, 777).0;
    let queue = RequestQueue::new(8);
    let metrics = serve::ServeMetrics::new();
    let wcfg = WorkerConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(50),
        width: 1,
        actq: None,
        chaos: None,
    };
    let (rtx, rrx) = channel::<ServeResponse>();
    let past = Instant::now()
        .checked_sub(Duration::from_millis(5))
        .unwrap_or_else(Instant::now);
    std::thread::scope(|s| {
        s.spawn(|| run_worker(0, prepared.as_ref(), &queue, &wcfg, &metrics));
        for i in 0..4 {
            queue
                .push(ServeRequest {
                    id: i as u64,
                    input: sample(&inputs, i),
                    submitted: Instant::now(),
                    deadline: Some(past),
                    tx: rtx.clone(),
                })
                .unwrap();
        }
        drop(rtx);
        for _ in 0..4 {
            let resp = rrx.recv().expect("expired requests still get a response");
            assert!(
                matches!(resp.outcome, ServeOutcome::Expired),
                "past-deadline request must expire, got {:?}",
                resp.outcome.kind()
            );
        }
        queue.close();
    });
    let report = metrics.report("host", "synthnet", 4, 8, 1, 0.01);
    assert_eq!(report.completed, 0);
    assert_eq!(
        report.batches, 0,
        "expired requests must be shed BEFORE forward compute"
    );
}

#[test]
fn zero_deadline_expires_everything_end_to_end() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 64,
        workers: 2,
        deadline: Some(Duration::ZERO),
        ..ServeConfig::default()
    };
    let report =
        serve::run_load_generator(&be, &manifest, "synthnet", &cfg, 32, 2).unwrap();
    assert_eq!(report.submitted, 32);
    assert_eq!(report.completed, 0);
    assert_eq!(report.expired, 32, "every request expires under a 0ms deadline");
    assert_eq!(report.batches, 0, "no forward compute for expired traffic");
    assert!(report.accounting_balanced());
}

#[test]
fn two_worker_fleet_serves_bit_identical() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let cfg = ServeConfig {
        max_batch: 4,
        queue_depth: 16,
        workers: 2,
        verify: true, // every answer re-checked against direct forward
        ..ServeConfig::default()
    };
    let report =
        serve::run_load_generator(&be, &manifest, "synthnet", &cfg, 64, 4).unwrap();
    assert_eq!(report.workers, 2, "host topology must honor 2 workers");
    assert_eq!(report.completed, 64);
    assert_eq!(report.errors, 0);
    assert!(report.accounting_balanced());
    assert_eq!(report.worker_batches.len(), 2);
    assert_eq!(
        report.worker_batches.iter().sum::<u64>(),
        report.batches,
        "per-worker batch counts must roll up to the fleet total"
    );
}

#[test]
fn fleet_worker_crash_restarts_and_accounts_every_request() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let spec = ChaosSpec {
        name: "worker-crash-test".into(),
        panic_on_batches: vec![1, 3],
        ..ChaosSpec::quiet(serve::CHAOS_SEED)
    };
    let cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 32,
        workers: 2,
        chaos: Some(spec),
        ..ServeConfig::default()
    };
    let report =
        serve::run_load_generator(&be, &manifest, "synthnet", &cfg, 96, 4).unwrap();
    assert_eq!(report.submitted, 96);
    assert_eq!(report.workers, 2);
    // both injected panics fire (the global batch counter passes 1 and 3
    // on a 96-request run) and each is a supervised restart
    assert_eq!(report.restarts, 2, "each injected panic is one restart");
    assert!(
        report.errors >= 2,
        "the crashed batches' in-flight requests fail over (got {})",
        report.errors
    );
    assert!(
        report.completed >= 1,
        "restarted workers keep serving the queue"
    );
    assert!(
        report.accounting_balanced(),
        "every submitted request reaches exactly one terminal state \
         (submitted {} vs completed {} + rejected {} + expired {} + errors {})",
        report.submitted,
        report.completed,
        report.rejected_final,
        report.expired,
        report.errors
    );
}

#[test]
fn chaos_scenario_matrix_runs_no_skip() {
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let cfg = ServeConfig {
        max_batch: 8,
        queue_depth: 32,
        workers: 2,
        ..ServeConfig::default()
    };
    let results = serve::run_matrix(
        &be,
        &manifest,
        "synthnet",
        &cfg,
        64,
        4,
        serve::CHAOS_SEED,
    )
    .unwrap();
    assert_eq!(
        results.len(),
        serve::SCENARIOS.len(),
        "every named scenario must run — no skips"
    );
    for (spec, report, verdict) in &results {
        assert_eq!(report.submitted, 64, "{}: all requests submitted", spec.name);
        assert_eq!(
            verdict.lost, 0,
            "{}: zero lost requests (accounting must balance)",
            spec.name
        );
        assert!(verdict.accounting_balanced, "{}", spec.name);
        match spec.name.as_str() {
            "worker-crash" => assert!(
                report.restarts >= 1,
                "worker-crash must exercise a supervised restart"
            ),
            "mixed-size" => assert_eq!(
                report.errors, 0,
                "mixed sizes must be shape-grouped, never errored"
            ),
            "slow-consumer" => assert!(
                report.completed + report.expired > 0,
                "slow consumer still terminates every request"
            ),
            _ => {}
        }
    }
}

#[test]
fn concurrent_multi_producer_smoke() {
    // Small queue + several producers forces real contention: admission
    // rejections with retry, coalesced batches, clean drain at close.
    let be = HostBackend::new();
    let manifest = Manifest::synthetic();
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_depth: 8,
        verify: true, // every response re-checked against direct forward
        ..ServeConfig::default()
    };
    let report =
        serve::run_load_generator(&be, &manifest, "synthnet", &cfg, 192, 4).unwrap();
    assert_eq!(report.submitted, 192);
    assert_eq!(report.completed, 192, "every request must complete");
    assert_eq!(report.errors, 0);
    assert!(report.accounting_balanced());
    assert!(report.throughput_rps > 0.0, "non-zero sustained throughput");
    assert!(report.batches >= 192 / 8, "batches actually coalesced");
    assert!(
        report.lat_p50_s <= report.lat_p95_s && report.lat_p95_s <= report.lat_p99_s,
        "latency percentiles must be monotone"
    );
    assert!(report.wall_s > 0.0);
    // the JSON report round-trips through the in-repo parser
    let parsed = attention_round::util::json::parse(&report.to_json()).unwrap();
    let s = parsed.get("serve").unwrap();
    assert_eq!(s.get("completed").unwrap().as_f64().unwrap(), 192.0);
    assert!(s.get("accounting_balanced").unwrap().as_bool().unwrap());
}
