//! Miri-targeted soundness subset (CI `sanitizers` job).
//!
//! Run as `cargo miri test -p attention_round --no-default-features
//! --test miri_soundness`. The suite deliberately avoids file IO, large
//! inputs, and the SIMD intrinsics Miri cannot interpret; it covers the
//! crate's densest index arithmetic (bitpack shifting/masking), the
//! scalar quantization kernels, and the scoped thread-pool fan-in that
//! TSan exercises from the other side. Sizes are tiny: Miri runs ~100×
//! slower than native, and the point is UB detection, not coverage.

use attention_round::deploy::bitpack;
use attention_round::quant::kernel::{
    quant_sse_multi, quantize_attention_slice_scalar, quantize_nearest_slice_scalar,
    round_half_even_fast,
};
use attention_round::quant::{round_half_even, QGrid};
use attention_round::util::rng::Rng;
use attention_round::util::threadpool::ThreadPool;

/// Deterministic pseudo-weights without file IO.
fn synth(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.next_f32() - 0.5) * 4.0).collect()
}

#[test]
fn bitpack_roundtrips_every_width_on_ragged_lengths() {
    let mut rng = Rng::new(0xB17_5EED);
    for bits in 2u8..=8 {
        // ragged lengths around the u64-word and byte boundaries the
        // packer's carry logic has to get right
        for n in [1usize, 3, 7, 8, 9, 31, 32, 33, 65] {
            let levels = 1usize << bits;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(levels) as u32).collect();
            let bytes = bitpack::pack(&codes, bits).expect("pack");
            assert_eq!(bytes.len(), bitpack::packed_len(n, bits));
            let back = bitpack::unpack(&bytes, n, bits).expect("unpack");
            assert_eq!(back, codes, "width {bits}, n {n}");
            bitpack::validate_padding(&bytes, n, bits).expect("padding clean");
        }
    }
}

#[test]
fn unpack_range_mid_stream_matches_full_unpack() {
    let mut rng = Rng::new(0x0FF5E7);
    for bits in [3u8, 5, 7] {
        let n = 41usize;
        let levels = 1usize << bits;
        let codes: Vec<u32> = (0..n).map(|_| rng.below(levels) as u32).collect();
        let bytes = bitpack::pack(&codes, bits).expect("pack");
        for (start, len) in [(0usize, 5usize), (7, 11), (n - 3, 3), (13, 0)] {
            let mut out = vec![0u32; len];
            bitpack::unpack_range(&bytes, bits, start, &mut out);
            assert_eq!(out, codes[start..start + len], "bits {bits} start {start}");
        }
    }
}

#[test]
fn scalar_kernels_match_grid_reference() {
    let w = synth(57, 0x5CA1A7);
    let bits = 4u8;
    let s = 0.23f32;
    let g = QGrid::signed(bits, s).expect("grid");
    let half = 1i32 << (bits - 1);
    let (lo, hi) = (-(half as f32), (half - 1) as f32);

    let mut out = vec![0.0f32; w.len()];
    quantize_nearest_slice_scalar(&w, s, lo, hi, &mut out);
    for (&v, &q) in w.iter().zip(&out) {
        assert_eq!(q.to_bits(), g.nearest(v).to_bits(), "v={v}");
    }

    // zero offsets must reduce attention rounding to nearest rounding
    let alpha = vec![0.0f32; w.len()];
    let mut out_a = vec![0.0f32; w.len()];
    quantize_attention_slice_scalar(&w, &alpha, s, lo, hi, &mut out_a);
    for (&a, &b) in out.iter().zip(&out_a) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn fast_round_matches_reference_around_ties() {
    for i in -40..=40i32 {
        let x = i as f32 * 0.5;
        for off in [-0.25f32, 0.0, 0.25] {
            let v = x + off;
            assert_eq!(
                round_half_even_fast(v).to_bits(),
                round_half_even(v).to_bits(),
                "v={v}"
            );
        }
    }
}

#[test]
fn scope_map_fans_in_under_miri() {
    let pool = ThreadPool::new(4);
    let got = pool.scope_map(16, |i| i * i);
    let want: Vec<usize> = (0..16).map(|i| i * i).collect();
    assert_eq!(got, want);
}

#[test]
fn fused_sse_sweep_is_pool_size_invariant() {
    let w = synth(96, 0xF05E_D00D);
    let scales = [0.11f32, 0.2, 0.31];
    let mut seq = [0.0f64; 3];
    let mut par = [0.0f64; 3];
    quant_sse_multi(&ThreadPool::seq(), &w, 4, &scales, &mut seq);
    quant_sse_multi(&ThreadPool::new(3), &w, 4, &scales, &mut par);
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.to_bits(), b.to_bits(), "chunk merge must be order-fixed");
    }
}
