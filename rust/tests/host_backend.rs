//! End-to-end pipeline tests on the pure-host backend — **no artifacts,
//! no skips**. This is the CI-enforced proof that the full
//! capture → calibrate → evaluate path runs on a bare checkout:
//!
//! * `quantize_and_eval` for all five rounding modes on the synthetic
//!   3-layer model, each within tolerance of the FP accuracy;
//! * Attention Round's per-layer reconstruction losses monotone
//!   non-increasing (last ≤ first);
//! * the W+A (activation fake-quant) path;
//! * `experiments::table1` producing a full table through the
//!   backend-neutral harness (with parallel cell fan-out);
//! * host STE-QAT training reducing loss and evaluating.

use attention_round::backend::{Backend, HostBackend};
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::evaluate::evaluate;
use attention_round::coordinator::experiments::{self, Ctx};
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use attention_round::coordinator::qat::run_qat;
use attention_round::data::synth;
use attention_round::io::manifest::{Manifest, SYNTHETIC_MODEL};
use attention_round::quant::rounding::Rounding;

struct HostRig {
    be: HostBackend,
    manifest: Manifest,
    calib: attention_round::data::Split,
    eval: attention_round::data::Split,
}

fn rig() -> HostRig {
    HostRig {
        be: HostBackend::new(),
        manifest: Manifest::synthetic(),
        calib: synth::split(128, synth::CALIB_SEED),
        eval: synth::split(192, synth::EVAL_SEED),
    }
}

fn quick_cfg() -> CalibConfig {
    let mut cfg = CalibConfig::quick();
    cfg.iters = 24;
    cfg.calib_samples = 96;
    cfg
}

#[test]
fn full_pipeline_all_five_rounding_modes() {
    let r = rig();
    let model = r.be.load_model(&r.manifest, SYNTHETIC_MODEL).expect("model");
    let fp = evaluate(&r.be, &r.manifest, &model, &model.weights, &r.eval)
        .expect("fp eval");
    assert!(
        fp > 2.0 / 16.0,
        "synthetic model must beat chance before quantization, got {fp}"
    );

    let mut cfg = quick_cfg();
    for method in [
        Rounding::Nearest,
        Rounding::Floor,
        Rounding::Ceil,
        Rounding::Stochastic,
        Rounding::Attention,
    ] {
        cfg.method = method;
        let spec = QuantSpec {
            model: SYNTHETIC_MODEL.into(),
            wbits: resolve_uniform_bits(&model, 6),
            abits: None,
        };
        let out = quantize_and_eval(&r.be, &r.manifest, &spec, &cfg, &r.calib, &r.eval)
            .unwrap_or_else(|e| panic!("{method:?} failed: {e}"));
        assert!(out.acc.is_finite(), "{method:?} produced non-finite accuracy");
        assert_eq!(out.per_layer.len(), 3);
        assert!(
            (out.acc - fp).abs() < 0.2,
            "{method:?} at 6 bits drifted too far from FP: {} vs {fp}",
            out.acc
        );
        // quantization must actually change the mid (non-pinned) weights
        let d = attention_round::tensor::ops::mse(
            out.qweights[1].data(),
            model.weights[1].data(),
        );
        assert!(d > 0.0, "{method:?} left weights untouched");
    }
}

#[test]
fn attention_losses_monotone_non_increasing() {
    let r = rig();
    let model = r.be.load_model(&r.manifest, SYNTHETIC_MODEL).expect("model");
    let fp = evaluate(&r.be, &r.manifest, &model, &model.weights, &r.eval)
        .expect("fp eval");
    let mut cfg = quick_cfg();
    cfg.method = Rounding::Attention;
    // a real Adam budget so the improvement dominates batch-sampling
    // noise in the first-vs-last loss comparison
    cfg.iters = 64;
    cfg.lr = 0.02;
    let spec = QuantSpec {
        model: SYNTHETIC_MODEL.into(),
        wbits: resolve_uniform_bits(&model, 4),
        abits: None,
    };
    let out = quantize_and_eval(&r.be, &r.manifest, &spec, &cfg, &r.calib, &r.eval)
        .expect("attention 4-bit");
    for l in &out.per_layer {
        assert!(
            l.first_loss.is_finite() && l.last_loss.is_finite(),
            "{}: non-finite losses",
            l.name
        );
        assert!(
            l.last_loss <= l.first_loss * 1.001 + 1e-12,
            "{}: reconstruction loss increased {} -> {}",
            l.name,
            l.first_loss,
            l.last_loss
        );
    }
    assert!(
        out.acc > fp - 0.3,
        "attention 4-bit collapsed: {} vs fp {fp}",
        out.acc
    );
}

#[test]
fn adaround_runs_on_host() {
    let r = rig();
    let model = r.be.load_model(&r.manifest, SYNTHETIC_MODEL).expect("model");
    let mut cfg = quick_cfg();
    cfg.iters = 12;
    cfg.method = Rounding::AdaRound;
    let spec = QuantSpec {
        model: SYNTHETIC_MODEL.into(),
        wbits: resolve_uniform_bits(&model, 4),
        abits: None,
    };
    let out = quantize_and_eval(&r.be, &r.manifest, &spec, &cfg, &r.calib, &r.eval)
        .expect("adaround");
    assert!(out.acc.is_finite());
    assert!(out.per_layer.iter().all(|l| l.last_loss.is_finite()));
}

#[test]
fn weights_plus_activations_path() {
    let r = rig();
    let model = r.be.load_model(&r.manifest, SYNTHETIC_MODEL).expect("model");
    let fp = evaluate(&r.be, &r.manifest, &model, &model.weights, &r.eval)
        .expect("fp eval");
    let mut cfg = quick_cfg();
    cfg.method = Rounding::Nearest; // static: the actq path is what's under test
    let spec = QuantSpec {
        model: SYNTHETIC_MODEL.into(),
        wbits: resolve_uniform_bits(&model, 8),
        abits: Some(8),
    };
    let out = quantize_and_eval(&r.be, &r.manifest, &spec, &cfg, &r.calib, &r.eval)
        .expect("8/8");
    let params = out.act_params.expect("act params recorded");
    assert_eq!(params.len(), 3);
    assert!(params.iter().all(|p| p.scale > 0.0));
    assert!(
        (out.acc - fp).abs() < 0.1,
        "8/8 should track FP closely: {} vs {fp}",
        out.acc
    );
}

#[test]
fn table1_runs_on_host_backend() {
    let mut cfg = CalibConfig::quick();
    cfg.iters = 8;
    cfg.calib_samples = 48;
    let out_dir = std::env::temp_dir().join(format!("ar_host_t1_{}", std::process::id()));
    let ctx = Ctx::synthetic(cfg, out_dir.to_str().unwrap()).expect("ctx");
    assert_eq!(ctx.backend.name(), "host");
    assert!(ctx.manifest.is_synthetic());
    // Ctx::synthetic measures fp_acc instead of trusting a placeholder
    assert!(ctx.manifest.models[0].fp_acc > 2.0 / 16.0);

    let t = experiments::table1(&ctx, &[SYNTHETIC_MODEL]).expect("table1");
    // 1 FP row + 2 "ours" high-bit rows + 2 bit-widths × 4 methods
    assert_eq!(t.num_rows(), 11, "table1 row count");
    let csv = t.to_csv();
    assert!(csv.contains(SYNTHETIC_MODEL) || csv.contains("Ours"));
    assert!(
        out_dir.join("table1.md").exists() && out_dir.join("table1.csv").exists(),
        "table artifacts written"
    );
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn host_qat_trains_and_evaluates() {
    let r = rig();
    let train = synth::split(128, synth::TRAIN_SEED);
    let out = run_qat(
        &r.be, &r.manifest, SYNTHETIC_MODEL, 4, 4, 8, 1e-3, &train, &r.eval, 7,
    )
    .expect("qat");
    assert!(out.final_loss.is_finite() && out.final_loss > 0.0);
    assert!(out.acc.is_finite() && out.acc > 0.0);
    assert_eq!(out.train_samples_seen, 8 * r.manifest.dataset.qat_batch);
}
