//! Property tests for the `quant::kernel` subsystem (via the in-repo
//! util::proptest driver): every fused / in-place / parallel kernel must
//! reproduce its scalar reference —
//!
//! * `_into` rounding kernels and the sequential fused scale search:
//!   **bit-identical** (value-equal per element / per result);
//! * cross-chunk parallel reductions (multi-chunk scale search, pooled
//!   coding length): equal up to f64 reassociation, checked against
//!   tolerances far above the reassociation bound;
//! * parallel allocation: exactly the same bits and lengths as the
//!   sequential pool (per-layer math is scheduled, not changed).
//!
//! Pure host math, no artifacts needed.

use attention_round::io::manifest::LayerInfo;
use attention_round::linalg::Mat;
use attention_round::mixed;
use attention_round::quant::rounding;
use attention_round::quant::scale::{
    mse_optimal_scale_scalar, mse_optimal_scale_with, quant_mse,
};
use attention_round::quant::QGrid;
use attention_round::tensor::ops;
use attention_round::tensor::Tensor;
use attention_round::util::proptest::{check, shrink_vec, Config};
use attention_round::util::rng::Rng;
use attention_round::util::threadpool::ThreadPool;

fn gen_weights_sized(r: &mut Rng, max_n: usize) -> Vec<f32> {
    let n = 1 + r.below(max_n);
    let std = 0.01 + r.next_f32() * 0.5;
    let mut w = vec![0.0f32; n];
    r.fill_gaussian(&mut w, 0.0, std);
    w
}

#[test]
fn prop_into_kernels_bit_identical_to_scalar() {
    // sizes cross MIN_PAR_CHUNK so real multi-chunk splits are exercised
    check(
        Config { cases: 24, ..Default::default() },
        |r| (gen_weights_sized(r, 50_000), r.next_u64()),
        |(w, seed)| shrink_vec(w).into_iter().map(|v| (v, *seed)).collect(),
        |(w, seed)| {
            let bits = 2 + (seed % 7) as u8; // 2..=8
            let s = 0.002 + (*seed % 1000) as f32 * 1e-4;
            let g = QGrid::signed(bits, s).map_err(|e| e.to_string())?;
            let mut arng = Rng::new(seed ^ 0xA1FA);
            let mut alpha = vec![0.0f32; w.len()];
            arng.fill_gaussian(&mut alpha, 0.0, 0.5);
            let mut out = vec![0.0f32; w.len()];
            for pool in [ThreadPool::seq(), ThreadPool::new(3)] {
                rounding::nearest_into(&pool, w, &g, &mut out);
                if out != rounding::nearest(w, &g) {
                    return Err(format!("nearest_into diverged (pool {})", pool.size()));
                }
                rounding::floor_into(&pool, w, &g, &mut out);
                if out != rounding::floor(w, &g) {
                    return Err(format!("floor_into diverged (pool {})", pool.size()));
                }
                rounding::ceil_into(&pool, w, &g, &mut out);
                if out != rounding::ceil(w, &g) {
                    return Err(format!("ceil_into diverged (pool {})", pool.size()));
                }
                rounding::attention_finalize_into(&pool, w, &alpha, &g, &mut out);
                if out != rounding::attention_finalize(w, &alpha, &g) {
                    return Err(format!(
                        "attention_finalize_into diverged (pool {})",
                        pool.size()
                    ));
                }
                rounding::adaround_finalize_into(&pool, w, &alpha, &g, &mut out);
                if out != rounding::adaround_finalize(w, &alpha, &g) {
                    return Err(format!(
                        "adaround_finalize_into diverged (pool {})",
                        pool.size()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stochastic_into_bit_identical_across_thread_counts() {
    // The satellite contract of the parallel stochastic kernel: for a
    // fixed seed the output is a pure function of (w, grid, seed) —
    // chunk boundaries are fixed-size, per-chunk RNG streams are seeded
    // seed ⊕ mix(chunk), so pool size must never change a single bit.
    check(
        Config { cases: 16, ..Default::default() },
        |r| (gen_weights_sized(r, 60_000), r.next_u64()),
        |(w, seed)| shrink_vec(w).into_iter().map(|v| (v, *seed)).collect(),
        |(w, seed)| {
            let bits = 2 + (seed % 7) as u8;
            let s = 0.002 + (*seed % 1000) as f32 * 1e-4;
            let g = QGrid::signed(bits, s).map_err(|e| e.to_string())?;
            let mut reference = vec![0.0f32; w.len()];
            rounding::stochastic_into(&ThreadPool::seq(), w, &g, *seed, &mut reference);
            for threads in [2usize, 3, 8] {
                let mut out = vec![0.0f32; w.len()];
                rounding::stochastic_into(&ThreadPool::new(threads), w, &g, *seed, &mut out);
                if out != reference {
                    return Err(format!(
                        "stochastic_into diverged at {threads} threads (n={})",
                        w.len()
                    ));
                }
            }
            // determinism: repeat with the same seed
            let mut again = vec![0.0f32; w.len()];
            rounding::stochastic_into(&ThreadPool::new(3), w, &g, *seed, &mut again);
            if again != reference {
                return Err("stochastic_into not deterministic for fixed seed".into());
            }
            for &v in &reference {
                if !g.contains(v) {
                    return Err(format!("{v} off grid"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_scale_search_bit_identical_sequentially() {
    // One chunk -> the fused kernel accumulates in scalar element order
    // -> the selected scale must be bit-identical.
    check(
        Config { cases: 24, ..Default::default() },
        |r| gen_weights_sized(r, 6_000),
        |w| shrink_vec(w),
        |w| {
            let pool = ThreadPool::seq();
            for bits in [3u8, 4, 8] {
                let fused = mse_optimal_scale_with(&pool, w, bits).map_err(|e| e.to_string())?;
                let scalar = mse_optimal_scale_scalar(w, bits).map_err(|e| e.to_string())?;
                if fused != scalar {
                    return Err(format!("bits {bits}: fused {fused} != scalar {scalar}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_scale_search_parallel_quality_equal() {
    // Across chunks the f64 merge order differs; the selected scale must
    // quantize exactly as well as the scalar search's choice.
    check(
        Config { cases: 6, ..Default::default() },
        |r| {
            let std = 0.02 + r.next_f32() * 0.2;
            let mut w = vec![0.0f32; 60_000];
            r.fill_gaussian(&mut w, 0.0, std);
            w
        },
        |w| shrink_vec(w),
        |w| {
            let pool = ThreadPool::new(4);
            for bits in [3u8, 4] {
                let fused = mse_optimal_scale_with(&pool, w, bits).map_err(|e| e.to_string())?;
                let scalar = mse_optimal_scale_scalar(w, bits).map_err(|e| e.to_string())?;
                let ef = quant_mse(w, bits, fused);
                let es = quant_mse(w, bits, scalar);
                if !(ef <= es * (1.0 + 1e-9) && es <= ef * (1.0 + 1e-9)) {
                    return Err(format!("bits {bits}: fused mse {ef} vs scalar {es}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pooled_coding_length_matches_scalar() {
    check(
        Config { cases: 24, ..Default::default() },
        |r| {
            let n = 1 + r.below(40);
            let m = 1 + r.below(40);
            let mut data = vec![0.0f32; n * m];
            r.fill_gaussian(&mut data, 0.0, 0.3);
            (n, m, data)
        },
        |_| vec![],
        |(n, m, data)| {
            let mat = Mat::from_rows_f32(*n, *m, data).map_err(|e| e.to_string())?;
            let want = mixed::coding_length_scalar(&mat, 0.01).map_err(|e| e.to_string())?;
            for pool in [ThreadPool::seq(), ThreadPool::new(3)] {
                let got =
                    mixed::coding_length_with(&pool, &mat, 0.01).map_err(|e| e.to_string())?;
                let tol = 1e-8 * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!("pool {}: {got} vs {want}", pool.size()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_gram_and_matmul_bit_identical() {
    check(
        Config { cases: 24, ..Default::default() },
        |r| {
            let rows = 1 + r.below(30);
            let cols = 1 + r.below(30);
            let mut data = vec![0.0f32; rows * cols];
            r.fill_gaussian(&mut data, 0.0, 1.0);
            (rows, cols, data)
        },
        |_| vec![],
        |(rows, cols, data)| {
            let a = Mat::from_rows_f32(*rows, *cols, data).map_err(|e| e.to_string())?;
            let pool = ThreadPool::new(3);
            if a.gram().data != a.gram_with(&pool).data {
                return Err("parallel gram diverged".into());
            }
            let b = Mat::eye(*cols);
            let seq = a.matmul(&b).map_err(|e| e.to_string())?;
            let par = a.matmul_with(&pool, &b).map_err(|e| e.to_string())?;
            if seq.data != par.data {
                return Err("parallel matmul diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_allocate_matches_sequential() {
    check(
        Config { cases: 16, ..Default::default() },
        |r| {
            let k = 3 + r.below(6); // 3..=8 layers
            let dims: Vec<(usize, usize)> = (0..k)
                .map(|_| (1 + r.below(24), 1 + r.below(24)))
                .collect();
            let seeds: Vec<u64> = (0..k).map(|_| r.next_u64()).collect();
            (dims, seeds)
        },
        |_| vec![],
        |(dims, seeds)| {
            let k = dims.len();
            let layers: Vec<LayerInfo> = dims
                .iter()
                .enumerate()
                .map(|(i, &(n, m))| LayerInfo::synthetic(i, n, m, i == 0 || i == k - 1))
                .collect();
            let weights: Vec<Tensor> = dims
                .iter()
                .zip(seeds)
                .map(|(&(n, m), &seed)| {
                    let mut rng = Rng::new(seed);
                    let mut data = vec![0.0f32; n * m];
                    rng.fill_gaussian(&mut data, 0.0, 0.2);
                    Tensor::new(vec![n, m], data).unwrap()
                })
                .collect();
            let seq =
                mixed::allocate_with(&ThreadPool::seq(), &layers, &weights, &[3, 4, 5], 0.01)
                    .map_err(|e| e.to_string())?;
            let par =
                mixed::allocate_with(&ThreadPool::new(3), &layers, &weights, &[3, 4, 5], 0.01)
                    .map_err(|e| e.to_string())?;
            if seq.bits != par.bits {
                return Err(format!("bits diverged: {:?} vs {:?}", seq.bits, par.bits));
            }
            if seq.lengths != par.lengths {
                return Err("coding lengths diverged".into());
            }
            if seq.size_bytes != par.size_bytes {
                return Err("size accounting diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_percentile_select_matches_sort_reference() {
    check(
        Config { cases: 48, ..Default::default() },
        |r| {
            let xs = gen_weights_sized(r, 3_000);
            let p = r.next_f64() * 100.0;
            (xs, p)
        },
        |(xs, p)| shrink_vec(xs).into_iter().map(|v| (v, *p)).collect(),
        |(xs, p)| {
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
            let want = sorted[idx.min(xs.len() - 1)];
            let mut scratch = Vec::new();
            let got = ops::percentile_with(xs, *p, &mut scratch);
            if got != want {
                return Err(format!("p={p}: select {got} != sort {want}"));
            }
            Ok(())
        },
    );
}
