//! PJRT execution backend — the original device path, repackaged behind
//! the [`Backend`] trait.
//!
//! All `xla::PjRtBuffer` plumbing that used to live inside the
//! coordinator (capture / calibrate / evaluate / qat) is concentrated
//! here. The backend-neutral handles preserve the upload discipline the
//! runtime docs promise: [`Backend::prepare`] uploads a weight set once
//! per phase and reuses it across every batch; [`Backend::begin_scan`]
//! uploads the layer weight and scalar hyperparameters once per layer
//! and streams only the per-call batch stacks + optimizer state.

use std::path::PathBuf;
use std::sync::Arc;

use crate::backend::{
    Backend, CalibScan, PreparedLayer, PreparedModel, QatState, ScanKind, ScanSetup,
    ScanState,
};
use crate::coordinator::model::LoadedModel;
use crate::io::manifest::{LayerInfo, Manifest};
use crate::quant::observer::ActQuantParams;
use crate::runtime::{convert::literal_scalar, literal_to_tensor, Executable, Runtime};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::timer::Metrics;

pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn new(artifacts_root: impl Into<PathBuf>) -> Result<Self> {
        Ok(PjrtBackend {
            rt: Runtime::new(artifacts_root)?,
        })
    }

    /// Direct access to the PJRT runtime (compile-latency benches and
    /// device-specific tooling; coordinator code must not need this).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Upload a weight set once — the shared body of `prepare` and
    /// `prepare_serving`.
    fn stage<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<PjrtPrepared<'a>> {
        if weights.len() != model.num_layers() {
            return Err(Error::shape(format!(
                "{}: {} weight tensors for {} layers",
                model.info.name,
                weights.len(),
                model.num_layers()
            )));
        }
        Ok(PjrtPrepared {
            rt: &self.rt,
            model,
            wbufs: self.rt.upload_all(weights)?,
            bbufs: self.rt.upload_all(&model.biases)?,
            actq: std::sync::Mutex::new(None),
        })
    }
}

/// Uploaded activation-quant parameter vectors, keyed by their host
/// values so repeated `forward_actq` batches with the same observer
/// parameters reuse one upload (the common case: one eval pass).
struct ActqBufs {
    key: (Vec<f32>, Vec<f32>, Vec<u8>),
    scales: xla::PjRtBuffer,
    zeros: xla::PjRtBuffer,
    his: xla::PjRtBuffer,
}

struct PjrtPrepared<'a> {
    rt: &'a Runtime,
    model: &'a LoadedModel,
    wbufs: Vec<xla::PjRtBuffer>,
    bbufs: Vec<xla::PjRtBuffer>,
    actq: std::sync::Mutex<Option<ActqBufs>>,
}

impl PjrtPrepared<'_> {
    fn run_model(
        &self,
        exe: &Executable,
        x: &Tensor,
        extra: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let xbuf = self.rt.upload(x)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(1 + self.wbufs.len() + self.bbufs.len() + extra.len());
        args.push(&xbuf);
        args.extend(self.wbufs.iter());
        args.extend(self.bbufs.iter());
        args.extend(extra.iter().copied());
        exe.run_b(&args)
    }
}

impl PreparedModel for PjrtPrepared<'_> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let exe = self.rt.load(&self.model.info.forward)?;
        let outs = self.run_model(&exe, x, &[])?;
        literal_to_tensor(&outs[0])
    }

    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor> {
        let k = self.model.num_layers();
        if act_params.len() != k || act_bits.len() != k {
            return Err(Error::shape(format!(
                "expected {k} activation params/bits, got {}/{}",
                act_params.len(),
                act_bits.len()
            )));
        }
        let exe = self.rt.load(&self.model.info.forward_actq)?;
        let key = (
            act_params.iter().map(|p| p.scale).collect::<Vec<f32>>(),
            act_params.iter().map(|p| p.zero).collect::<Vec<f32>>(),
            act_bits.to_vec(),
        );
        let mut cached = self.actq.lock().unwrap();
        if cached.as_ref().map(|c| c.key != key).unwrap_or(true) {
            let his: Vec<f32> =
                act_bits.iter().map(|&b| ((1u32 << b) - 1) as f32).collect();
            *cached = Some(ActqBufs {
                scales: self.rt.upload(&Tensor::from_vec(key.0.clone()))?,
                zeros: self.rt.upload(&Tensor::from_vec(key.1.clone()))?,
                his: self.rt.upload(&Tensor::from_vec(his))?,
                key,
            });
        }
        let bufs = cached.as_ref().expect("just populated");
        let outs =
            self.run_model(&exe, x, &[&bufs.scales, &bufs.zeros, &bufs.his])?;
        literal_to_tensor(&outs[0])
    }

    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        let k = self.model.num_layers();
        let exe = self.rt.load(&self.model.info.collect)?;
        let outs = self.run_model(&exe, x, &[])?;
        if outs.len() != k + 1 {
            return Err(Error::runtime(format!(
                "collect returned {} outputs, expected {} layers + logits",
                outs.len(),
                k
            )));
        }
        let mut ins = Vec::with_capacity(k);
        for lit in &outs[..k] {
            ins.push(literal_to_tensor(lit)?);
        }
        let logits = literal_to_tensor(&outs[k])?;
        Ok((ins, logits))
    }
}

/// The serving handle: a [`PjrtPrepared`] plus the forward executable
/// resolved **once** at staging time, so the worker's per-batch path is
/// upload → execute with no runtime-cache lock (`Runtime::load` takes a
/// mutex + hash lookup per call; a hot serve loop would pay it per
/// batch).
struct PjrtServing<'a> {
    inner: PjrtPrepared<'a>,
    fwd: Arc<Executable>,
}

impl PreparedModel for PjrtServing<'_> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let outs = self.inner.run_model(&self.fwd, x, &[])?;
        literal_to_tensor(&outs[0])
    }

    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor> {
        self.inner.forward_actq(x, act_params, act_bits)
    }

    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        self.inner.collect(x)
    }
}

struct PjrtLayer<'a> {
    rt: &'a Runtime,
    exe: Arc<Executable>,
    wbuf: xla::PjRtBuffer,
}

impl PreparedLayer for PjrtLayer<'_> {
    fn fwd(&self, x: &Tensor) -> Result<Tensor> {
        let xbuf = self.rt.upload(x)?;
        let outs = self.exe.run_b(&[&xbuf, &self.wbuf])?;
        literal_to_tensor(&outs[0])
    }
}

struct PjrtScan<'a> {
    rt: &'a Runtime,
    exe: Arc<Executable>,
    kind: ScanKind,
    wbuf: xla::PjRtBuffer,
    lr: xla::PjRtBuffer,
    /// τ (Attention) or λ (AdaRound) — the per-kind scalar hyperparameter.
    knob: xla::PjRtBuffer,
    s: xla::PjRtBuffer,
    lo: xla::PjRtBuffer,
    hi: xla::PjRtBuffer,
    state: ScanState,
}

impl CalibScan for PjrtScan<'_> {
    fn scan(&mut self, xs: &Tensor, ys: &Tensor, beta: f32) -> Result<f32> {
        let steps = xs.shape().first().copied().unwrap_or(1);
        let xbuf = self.rt.upload(xs)?;
        let ybuf = self.rt.upload(ys)?;
        let vbuf = self.rt.upload(&self.state.var)?;
        let mbuf = self.rt.upload(&self.state.m)?;
        let vvbuf = self.rt.upload(&self.state.v)?;
        let tbuf = self.rt.upload_scalar(self.state.t)?;
        let outs = match self.kind {
            ScanKind::Attention { .. } => self.exe.run_b(&[
                &self.wbuf, &xbuf, &ybuf, &vbuf, &mbuf, &vvbuf, &tbuf, &self.lr,
                &self.knob, &self.s, &self.lo, &self.hi,
            ])?,
            ScanKind::AdaRound { .. } => {
                let bbuf = self.rt.upload_scalar(beta)?;
                self.exe.run_b(&[
                    &self.wbuf, &xbuf, &ybuf, &vbuf, &mbuf, &vvbuf, &tbuf, &self.lr,
                    &bbuf, &self.knob, &self.s, &self.lo, &self.hi,
                ])?
            }
        };
        if outs.len() != 4 {
            return Err(Error::runtime(format!(
                "calibration scan returned {} outputs, expected 4",
                outs.len()
            )));
        }
        self.state.var = literal_to_tensor(&outs[0])?;
        self.state.m = literal_to_tensor(&outs[1])?;
        self.state.v = literal_to_tensor(&outs[2])?;
        self.state.t += steps as f32;
        self.rt.metrics.incr("pipeline.calib_steps", steps as u64);
        literal_scalar(&outs[3])
    }

    fn state(&self) -> &ScanState {
        &self.state
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.rt.platform()
    }

    fn metrics(&self) -> &Metrics {
        &self.rt.metrics
    }

    fn worker_topology(&self, requested: usize) -> crate::backend::WorkerTopology {
        // One worker per device is the right fleet shape here, but the
        // vendored PJRT surface exposes a single client with no device
        // enumeration — so the honest answer today is one worker. A
        // real client would enumerate addressable devices and stage one
        // prepared handle (executable + resident weights) per device.
        if requested > 1 {
            log::warn!(
                "serve: pjrt backend runs 1 worker (no device enumeration \
                 in the vendored PJRT client); requested {requested}"
            );
        }
        crate::backend::WorkerTopology {
            workers: 1,
            worker_width: 0,
            detail: "pjrt: single device client".into(),
        }
    }

    fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        LoadedModel::load(manifest, name)
    }

    fn prepare<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        Ok(Box::new(self.stage(model, weights)?))
    }

    fn prepare_serving<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        let inner = self.stage(model, weights)?;
        let fwd = self.rt.load(&model.info.forward)?;
        Ok(Box::new(PjrtServing { inner, fwd }))
    }

    fn prepare_layer<'a>(
        &'a self,
        layer: &'a LayerInfo,
        w: &'a Tensor,
    ) -> Result<Box<dyn PreparedLayer + 'a>> {
        Ok(Box::new(PjrtLayer {
            rt: &self.rt,
            exe: self.rt.load(&layer.layer_fwd)?,
            wbuf: self.rt.upload(w)?,
        }))
    }

    fn begin_scan<'a>(
        &'a self,
        setup: ScanSetup<'a>,
        init: ScanState,
    ) -> Result<Box<dyn CalibScan + 'a>> {
        let (path, knob) = match setup.kind {
            ScanKind::Attention { tau } => (&setup.layer.calib_scan, tau),
            ScanKind::AdaRound { lambda } => (&setup.layer.adaround_scan, lambda),
        };
        Ok(Box::new(PjrtScan {
            rt: &self.rt,
            exe: self.rt.load(path)?,
            kind: setup.kind,
            wbuf: self.rt.upload(setup.w_fp)?,
            lr: self.rt.upload_scalar(setup.lr)?,
            knob: self.rt.upload_scalar(knob)?,
            s: self.rt.upload_scalar(setup.grid.scale)?,
            lo: self.rt.upload_scalar(setup.grid.lo)?,
            hi: self.rt.upload_scalar(setup.grid.hi)?,
            state: init,
        }))
    }

    fn qat_step(
        &self,
        model: &LoadedModel,
        state: &mut QatState,
        x: &Tensor,
        y: &[i32],
        lr: f32,
        wbits: u8,
        abits: u8,
    ) -> Result<f32> {
        let qat_path = model.info.qat_step.as_deref().ok_or_else(|| {
            Error::config(format!("{} has no qat_step artifact", model.info.name))
        })?;
        let exe = self.rt.load(qat_path)?;
        let k = model.num_layers();
        let batch = x.shape()[0];
        let xbuf = self.rt.upload(x)?;
        let ybuf = self.rt.upload_i32(y, &[batch])?;
        let lrbuf = self.rt.upload_scalar(lr)?;
        let whi = self.rt.upload_scalar(((1i64 << (wbits - 1)) - 1) as f32)?;
        let ahi = self.rt.upload_scalar(((1i64 << abits) - 1) as f32)?;
        let mut bufs = Vec::with_capacity(4 * k);
        for t in state
            .ws
            .iter()
            .chain(state.bs.iter())
            .chain(state.mws.iter())
            .chain(state.mbs.iter())
        {
            bufs.push(self.rt.upload(t)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * k + 5);
        args.push(&xbuf);
        args.push(&ybuf);
        args.extend(bufs.iter());
        args.push(&lrbuf);
        args.push(&whi);
        args.push(&ahi);
        let outs = exe.run_b(&args)?;
        if outs.len() != 4 * k + 1 {
            return Err(Error::runtime(format!(
                "qat_step returned {} outputs, expected {}",
                outs.len(),
                4 * k + 1
            )));
        }
        for i in 0..k {
            state.ws[i] = literal_to_tensor(&outs[i])?;
            state.bs[i] = literal_to_tensor(&outs[k + i])?;
            state.mws[i] = literal_to_tensor(&outs[2 * k + i])?;
            state.mbs[i] = literal_to_tensor(&outs[3 * k + i])?;
        }
        self.rt.metrics.incr("qat.steps", 1);
        literal_scalar(&outs[4 * k])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_boots_on_stub_and_errors_cleanly_on_artifacts() {
        let be = PjrtBackend::new("/nonexistent-artifacts").unwrap();
        assert_eq!(be.name(), "pjrt");
        assert!(be.platform().to_lowercase().contains("cpu"));
        // device execution is unavailable without artifacts: staging a
        // layer must fail at load, not mis-execute later
        let layer = LayerInfo::synthetic(0, 2, 2, false);
        let w = Tensor::zeros(vec![2, 2]);
        assert!(be.prepare_layer(&layer, &w).is_err());
    }
}
