//! Pluggable execution backends.
//!
//! The coordinator (capture → calibrate → evaluate → QAT) used to be
//! hard-welded to the PJRT [`crate::runtime::Runtime`]: every phase held
//! `xla::PjRtBuffer`s and drove AOT executables directly, so without a
//! compiled `artifacts/` directory no end-to-end path could run at all.
//! This module extracts the execution surface behind the [`Backend`]
//! trait so the same coordinator code drives either:
//!
//! * [`pjrt::PjrtBackend`] — the original device path: AOT HLO artifacts
//!   executed through the PJRT C API, weights resident on device.
//! * [`host::HostBackend`] — a pure-host executor that runs the
//!   manifest's layer graph natively (conv-as-matmul + linear via
//!   [`crate::linalg`], relu/identity activations, per-layer fake-quant
//!   for the activation-quantized path) on the process-wide
//!   [`crate::util::threadpool::global`] pool. Combined with the
//!   synthetic-model constructor ([`Manifest::synthetic`]) it runs the
//!   full PTQ pipeline with **zero artifacts** — every paper experiment
//!   is reproducible on a bare CPU checkout.
//!
//! Device-resident state (uploaded weight sets, per-layer calibration
//! sessions) is expressed through backend-neutral handles —
//! [`PreparedModel`], [`PreparedLayer`], [`CalibScan`] — so the PJRT
//! implementation keeps its upload-once-per-phase buffer reuse while the
//! host implementation simply borrows tensors.
//!
//! The trait requires `Send + Sync` so the experiment harness can fan
//! table cells out across the thread pool (see
//! `coordinator::experiments::Ctx::run_many`).

pub mod host;
pub mod pjrt;

use crate::coordinator::model::LoadedModel;
use crate::io::manifest::{LayerInfo, Manifest};
use crate::quant::observer::ActQuantParams;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::timer::Metrics;

pub use host::HostBackend;
pub use pjrt::PjrtBackend;

/// Which trained-rounding objective a calibration session optimizes.
#[derive(Debug, Clone, Copy)]
pub enum ScanKind {
    /// Attention Round (paper §3.3): α on the integer grid,
    /// ŵ = s·clip(w/s + α, lo, hi) relaxed during training.
    Attention { tau: f32 },
    /// AdaRound (Nagel et al. 2020): rectified-sigmoid h(V) with the
    /// β-annealed regularizer, weight λ.
    AdaRound { lambda: f32 },
}

/// Per-layer calibration setup shared by both backends. The fused step
/// count and per-step batch are carried by the stacked batches
/// themselves (the leading dimensions of [`CalibScan::scan`]'s inputs).
pub struct ScanSetup<'a> {
    pub layer: &'a LayerInfo,
    pub w_fp: &'a Tensor,
    pub grid: QGrid,
    /// Adam learning rate.
    pub lr: f32,
    pub kind: ScanKind,
}

/// Optimizer state for one layer's rounding variable (α or V) — plain
/// host tensors; backends upload/download as needed.
#[derive(Debug, Clone)]
pub struct ScanState {
    /// The trained rounding variable: α (Attention) or V (AdaRound).
    pub var: Tensor,
    /// Adam first moment.
    pub m: Tensor,
    /// Adam second moment.
    pub v: Tensor,
    /// Adam step count.
    pub t: f32,
}

impl ScanState {
    pub fn new(var: Tensor) -> Self {
        let shape = var.shape().to_vec();
        ScanState {
            var,
            m: Tensor::zeros(shape.clone()),
            v: Tensor::zeros(shape),
            t: 0.0,
        }
    }
}

/// STE-QAT training state: weights, biases and their SGD momenta.
#[derive(Debug, Clone)]
pub struct QatState {
    pub ws: Vec<Tensor>,
    pub bs: Vec<Tensor>,
    pub mws: Vec<Tensor>,
    pub mbs: Vec<Tensor>,
}

impl QatState {
    pub fn from_model(model: &LoadedModel) -> Self {
        QatState {
            ws: model.weights.clone(),
            bs: model.biases.clone(),
            mws: model
                .weights
                .iter()
                .map(|w| Tensor::zeros(w.shape().to_vec()))
                .collect(),
            mbs: model
                .biases
                .iter()
                .map(|b| Tensor::zeros(b.shape().to_vec()))
                .collect(),
        }
    }
}

/// A weight set staged for repeated model-level execution (device
/// buffers for PJRT, borrowed host tensors for the host backend).
///
/// `Send + Sync` so a serve worker thread can drive the handle while
/// producers live on other threads (both implementations are plain data
/// behind `&`-refs and mutexes; see `serve::worker`).
pub trait PreparedModel: Send + Sync {
    /// Logits for one image batch.
    fn forward(&self, x: &Tensor) -> Result<Tensor>;

    /// Logits with per-layer activation fake-quant (Tables 2/3/5).
    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor>;

    /// Every quantizable layer's input tensor plus the logits for one
    /// batch — the capture phase's unit of work.
    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)>;

    /// How many layers of the model are currently resident and
    /// servable. `None` (the default) means the handle is fully
    /// materialized and depth never changes; progressive handles
    /// (`deploy::progressive::ProgressiveHandle`) report the live
    /// resident prefix so serve workers can tag answers and metrics
    /// with `depth_served`.
    fn resident_depth(&self) -> Option<usize> {
        None
    }
}

/// One layer's pre-activation map `y = layer(x, w)` staged for repeated
/// calls (reference outputs for the reconstruction loss).
pub trait PreparedLayer {
    fn fwd(&self, x: &Tensor) -> Result<Tensor>;
}

/// A per-layer calibration session: repeated fused-Adam scan calls over
/// stacked sample batches, with the optimizer state retrievable for
/// host-side finalization.
pub trait CalibScan {
    /// Run one fused-Adam call over stacked batches `xs`/`ys` (leading
    /// dim = number of steps). `beta` is the AdaRound annealing knob
    /// (ignored by Attention). Returns the call's reconstruction loss.
    fn scan(&mut self, xs: &Tensor, ys: &Tensor, beta: f32) -> Result<f32>;

    /// Current optimizer state (read after the last scan to finalize).
    fn state(&self) -> &ScanState;
}

/// How a backend maps a requested serve-fleet size onto its execution
/// resources (see `serve::fleet`): how many supervised workers it will
/// actually run and how wide each worker's inner kernel fan-out should
/// be. The host backend splits the global thread pool across workers;
/// a device backend runs one worker per device.
#[derive(Debug, Clone)]
pub struct WorkerTopology {
    /// Workers the backend supports for this request (≥ 1).
    pub workers: usize,
    /// Per-worker kernel width cap; 0 = no split (full pool).
    pub worker_width: usize,
    /// Human-readable explanation for the serve banner/logs.
    pub detail: String,
}

/// An execution backend: everything the coordinator needs to run the
/// capture → calibrate → evaluate pipeline and the QAT comparator.
pub trait Backend: Send + Sync {
    /// Short identifier: "host" or "pjrt".
    fn name(&self) -> &'static str;

    /// Human-readable platform string for banners.
    fn platform(&self) -> String;

    /// Phase timing + counters for this backend's executions.
    fn metrics(&self) -> &Metrics;

    /// Materialize a model's weights/biases. The PJRT backend reads the
    /// manifest's npy checkpoints; the host backend additionally
    /// constructs synthetic models (empty `w_files`) in memory.
    fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel>;

    /// Whether this backend can serve a chunked (v3) artifact
    /// progressively — answering truncated-depth forwards while chunks
    /// stream in (`deploy::progressive`). Defaults to `false`;
    /// only backends whose layer execution path the progressive model
    /// reuses bit-for-bit should claim support.
    fn supports_progressive(&self) -> bool {
        false
    }

    /// Map a requested serve-fleet size onto this backend's resources.
    /// The default is the conservative single-worker topology; backends
    /// that can run a real fleet override it (`host` splits the thread
    /// pool, `pjrt` would run one worker per device).
    fn worker_topology(&self, requested: usize) -> WorkerTopology {
        let _ = requested;
        WorkerTopology {
            workers: 1,
            worker_width: 0,
            detail: "default single-worker topology".into(),
        }
    }

    /// Stage a weight set for forward / forward_actq / collect calls.
    fn prepare<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>>;

    /// Stage a weight set for the serving hot path: identical handle
    /// contract to [`Backend::prepare`], but the backend additionally
    /// pre-resolves everything a repeated `forward` needs — the PJRT
    /// implementation loads the forward executable here, once, instead
    /// of taking the runtime-cache lock per batch — so the serve
    /// worker's steady state is execution only, no per-call
    /// re-preparation.
    fn prepare_serving<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>>;

    /// Stage a **packed quantized artifact** (`deploy::artifact`) for
    /// serving. The default implementation dequantizes every layer into
    /// `staged` — a caller-owned buffer, so the tensors outlive the
    /// returned handle — and stages them via
    /// [`Backend::prepare_serving`]; that is the right shape for PJRT,
    /// which must upload resident f32 device buffers anyway. The host
    /// backend overrides this with a streaming dequant-on-the-fly
    /// handle (`deploy::dequant::PackedHostForward`) that keeps the
    /// codes packed and never materializes a second full-f32 copy of
    /// the model.
    fn prepare_artifact<'a>(
        &'a self,
        model: &'a LoadedModel,
        artifact: &'a crate::deploy::artifact::PackedModel,
        staged: &'a mut Vec<Tensor>,
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        artifact.check_matches(model)?;
        *staged = artifact.dequantize_all()?;
        let staged: &'a [Tensor] = staged;
        self.prepare_serving(model, staged)
    }

    /// Stage one layer's forward map for reference-output batching.
    fn prepare_layer<'a>(
        &'a self,
        layer: &'a LayerInfo,
        w: &'a Tensor,
    ) -> Result<Box<dyn PreparedLayer + 'a>>;

    /// Open a calibration session for one layer.
    fn begin_scan<'a>(
        &'a self,
        setup: ScanSetup<'a>,
        init: ScanState,
    ) -> Result<Box<dyn CalibScan + 'a>>;

    /// One STE-QAT step (fwd + bwd + SGD-momentum update) at the given
    /// fake-quant widths; returns the batch loss.
    fn qat_step(
        &self,
        model: &LoadedModel,
        state: &mut QatState,
        x: &Tensor,
        y: &[i32],
        lr: f32,
        wbits: u8,
        abits: u8,
    ) -> Result<f32>;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn backends_are_send_sync() {
        // `Backend: Send + Sync` is what lets experiments fan table rows
        // out across the pool; check the concrete types, not just the
        // trait bound.
        assert_send_sync::<HostBackend>();
        assert_send_sync::<PjrtBackend>();
    }

    #[test]
    fn scan_state_shapes() {
        let s = ScanState::new(Tensor::zeros(vec![2, 3]));
        assert_eq!(s.m.shape(), &[2, 3]);
        assert_eq!(s.v.shape(), &[2, 3]);
        assert_eq!(s.t, 0.0);
    }
}
