//! Pure-host execution backend: runs the manifest's layer graph natively
//! on the process-wide thread pool — no PJRT, no AOT artifacts.
//!
//! ## Graph convention
//!
//! The host executor interprets [`LayerInfo`] chains with 2-D weights
//! (the conv-as-matmul view the coding length already uses):
//!
//! * kind `"conv"` — a 1×1 convolution over NHWC input: every spatial
//!   position is a row of an `[B·H·W, Cin] @ [Cin, Cout]` matmul.
//! * kind `"linear"` / `"fc"` — a dense layer; 4-D input is first
//!   global-average-pooled to `[B, C]`.
//! * act `"relu"` — rectification after the bias add; anything else is
//!   identity. The last layer's output is the logits.
//!
//! The captured "layer input" (capture phase, activation observers,
//! `forward_actq`) is the **matmul input**: post-pool for linear layers,
//! the NHWC tensor for convs — so calibration reconstructs exactly the
//! map the layer applies, and activation fake-quant hits the same tensor
//! the observers saw.
//!
//! ## Calibration and QAT
//!
//! Trained rounding runs the same fused-K-step Adam loop the PJRT scan
//! executables implement, mirroring the device kernels
//! (python/compile/kernels/attention_round.py): Attention Round's
//! forward is the paper's Eq. (3) — ŵ = s·clip(⌊w/s + α⌉, lo, hi),
//! rounded exactly as at finalization — and the backward routes the
//! cotangent through the Gaussian-attention decay rule of Eq. (6),
//! dL/dα = g·(0.5 ± 0.5·erf(α/(√2·τ))) with g = s·dL/dŵ, using the same
//! erf polynomial as the Pallas kernel ([`crate::quant::erf`]). AdaRound
//! trains V through the standard soft rectified sigmoid with the
//! β-annealed regularizer. The reported per-call loss is the
//! reconstruction term only, so first→last comparisons are not
//! confounded by β annealing. STE-QAT is a full native forward/backward
//! (softmax-CE, SGD momentum) with max-abs fake-quant on weights and
//! post-ReLU activations.
//!
//! ## Synthetic models
//!
//! A manifest model with **no weight files** is a host-native synthetic
//! model ([`Manifest::synthetic`]): feature layers get deterministic
//! He-scaled Gaussian weights ([`synth::synthetic_weights`]) and the
//! head is closed-form calibrated as a nearest-class-mean readout over
//! generator samples — so the toy network classifies far above chance
//! with zero training and zero artifacts, giving quantization quality
//! something real to degrade. Construction is cached per model name.

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::Mutex;

use crate::backend::{
    Backend, CalibScan, PreparedLayer, PreparedModel, QatState, ScanKind, ScanSetup,
    ScanState,
};
use crate::coordinator::model::LoadedModel;
use crate::data::synth;
use crate::deploy::fused;
use crate::io::manifest::{LayerInfo, Manifest, ModelInfo};
use crate::linalg::Mat;
use crate::quant::observer::ActQuantParams;
use crate::quant::rounding::nearest;
use crate::quant::round_half_even;
use crate::quant::scale::absmax_scale;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::threadpool::{self, ThreadPool};
use crate::util::timer::Metrics;

/// Seed for the synthetic feature weights (fixed: the model IS its seed).
const SYNTH_WEIGHT_SEED: u64 = 0xBEEF;
/// Seed + sample count for the closed-form head calibration.
/// `pub(crate)`: the progressive server (`deploy::progressive`) builds
/// its truncated-depth readout heads from the same prototype draw, so a
/// partial-depth answer is the nearest-class-mean readout this backend
/// would have calibrated at that depth.
pub(crate) const PROTO_SEED: u64 = 0xFEED;
pub(crate) const PROTO_SAMPLES: usize = 384;

pub struct HostBackend {
    pool: &'static ThreadPool,
    metrics: Metrics,
    /// Synthetic models are deterministic but not free to build (the
    /// head calibration runs a few hundred forward passes) — cache the
    /// weights/biases. `ModelInfo` is always taken fresh from the
    /// manifest so metadata updates (e.g. a measured `fp_acc`) are seen.
    synth_cache: Mutex<HashMap<String, (Vec<Tensor>, Vec<Tensor>)>>,
}

impl Default for HostBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl HostBackend {
    pub fn new() -> Self {
        HostBackend {
            pool: threadpool::global(),
            metrics: Metrics::new(),
            synth_cache: Mutex::new(HashMap::new()),
        }
    }
}

// ---- graph primitives ----------------------------------------------------

fn is_linear(kind: &str) -> bool {
    matches!(kind, "linear" | "fc" | "dense")
}

/// Global average pool NHWC -> NC. `pub(crate)`: the progressive
/// server pools truncated-depth features exactly like the head
/// calibration does.
pub(crate) fn avg_pool(x: &Tensor) -> Result<Tensor> {
    let sh = x.shape();
    if sh.len() != 4 {
        return Err(Error::shape(format!("avg_pool wants 4-D, got {sh:?}")));
    }
    let (b, hw, c) = (sh[0], sh[1] * sh[2], sh[3]);
    let mut out = vec![0.0f32; b * c];
    let inv = 1.0 / hw as f32;
    for bi in 0..b {
        let img = &x.data()[bi * hw * c..(bi + 1) * hw * c];
        let dst = &mut out[bi * c..(bi + 1) * c];
        for row in img.chunks_exact(c) {
            for (d, &v) in dst.iter_mut().zip(row) {
                *d += v;
            }
        }
        for d in dst.iter_mut() {
            *d *= inv;
        }
    }
    Tensor::new(vec![b, c], out)
}

/// Per-tensor affine fake-quant (in place) on the activation grid the
/// observers picked: x' = clip(⌊(x − z)/s⌉, 0, 2^b − 1)·s + z.
/// `pub(crate)`: the packed-artifact forward (`deploy::dequant`) applies
/// the same transform so its actq path matches `run_graph` bit-for-bit.
pub(crate) fn fake_quant_act(xs: &mut [f32], p: &ActQuantParams, bits: u8) {
    // u64 shift: callers validate bits <= 16, but a u8 up to 63 must
    // degrade to a huge grid, not a shift-overflow panic
    let levels = ((1u64 << bits.min(63)) - 1) as f32;
    let s = p.scale.max(1e-12);
    for v in xs.iter_mut() {
        let q = round_half_even((*v - p.zero) / s).clamp(0.0, levels);
        *v = q * s + p.zero;
    }
}

/// The 2-D matmul view of a layer's weight; errors on non-2-D weights
/// (real conv checkpoints need the PJRT backend).
fn weight_dims(layer: &LayerInfo, w: &Tensor) -> Result<(usize, usize)> {
    match w.shape() {
        [n, m] => Ok((*n, *m)),
        other => Err(Error::shape(format!(
            "{}: host backend executes 2-D (conv-as-matmul) weights, got {other:?} — \
             use the PJRT backend for real checkpoints",
            layer.name
        ))),
    }
}

/// Aᵀ as a [`Mat`] from row-major f32 storage (rows × cols).
fn mat_transposed_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
    debug_assert_eq!(rows * cols, data.len());
    let mut t = Mat::zeros(cols, rows);
    for r in 0..rows {
        for (c, &v) in data[r * cols..(r + 1) * cols].iter().enumerate() {
            t.data[c * rows + r] = v as f64;
        }
    }
    t
}

/// Weight provider for [`layer_pass`]: either a resident f32 matrix
/// (the classic path) or a packed bitstream consumed in place by the
/// fused dequant-matmul kernel (`deploy::fused`) — a whole-f32 layer is
/// never materialized for packed weights. Both variants produce
/// bit-identical pre-activations for the same underlying weights
/// (property-tested in rust/tests/fused_kernel.rs).
pub(crate) enum HostWeights<'w> {
    Dense(&'w [f32]),
    Packed {
        bytes: &'w [u8],
        bits: u8,
        scale: f32,
        /// Per-output-channel scales (last axis) for per-channel-
        /// quantized layers; `None` applies `scale` uniformly.
        scales: Option<&'w [f32]>,
    },
}

/// Everything one layer application produces under the host execution
/// convention. Eval (`run_graph`), the QAT forward, the packed-artifact
/// forward (`deploy::dequant`), and (through `run_graph`) the serve
/// worker all consume the same pass, so the convention — pool 4-D input
/// for linear layers, matmul, bias add in f64, relu/identity — has
/// exactly one home.
pub(crate) struct LayerPass<'x> {
    /// Matmul input (post pool / input transform), row-major rows × n.
    /// Borrows the caller's tensor when no pooling or transform touched
    /// it — the common serve-path case, saving one full activation copy
    /// per layer per batch.
    pub(crate) a: Cow<'x, [f32]>,
    /// Shape of the matmul-input view (NHWC for conv, [rows, n] linear).
    pub(crate) in_shape: Vec<usize>,
    pub(crate) rows: usize,
    pub(crate) n: usize,
    pub(crate) m: usize,
    /// Some((batch, hw)) when the layer pooled its 4-D input.
    pub(crate) pooled: Option<(usize, usize)>,
    /// Pre-activation with bias, rows × m (f64 — the QAT backward masks
    /// ReLU against it).
    pub(crate) z: Vec<f64>,
    /// Activated output; only built when `want_out` was set (the
    /// bias-free reference path reads `z` instead).
    pub(crate) out: Option<Tensor>,
}

/// Apply one layer: validate the kind, pool 4-D input for linear layers,
/// run the caller's input transform (activation fake-quant) in place,
/// matmul `a @ w`, add `bias` (f64 accumulate), and activate.
/// `pub(crate)`: also the per-layer forward behind the packed-artifact
/// path (`deploy::dequant`), which hands it [`HostWeights::Packed`]
/// views straight off the artifact bytes.
pub(crate) fn layer_pass<'x>(
    pool: &ThreadPool,
    layer: &LayerInfo,
    weights: HostWeights<'_>,
    (n, m): (usize, usize),
    bias: &[f32],
    x: &'x Tensor,
    transform: Option<&dyn Fn(&mut [f32])>,
    want_out: bool,
) -> Result<LayerPass<'x>> {
    let (mut a, in_shape): (Cow<'x, [f32]>, Vec<usize>);
    let mut pooled = None;
    if is_linear(&layer.kind) && x.shape().len() == 4 {
        let sh = x.shape();
        pooled = Some((sh[0], sh[1] * sh[2]));
        let p = avg_pool(x)?;
        in_shape = p.shape().to_vec();
        a = Cow::Owned(p.into_data());
    } else if !is_linear(&layer.kind) && layer.kind != "conv" {
        return Err(Error::config(format!(
            "{}: host backend supports conv(1x1)/linear layers, got {:?}",
            layer.name, layer.kind
        )));
    } else {
        in_shape = x.shape().to_vec();
        a = Cow::Borrowed(x.data());
    }
    if let Some(f) = transform {
        f(a.to_mut());
    }
    if a.len() % n != 0 {
        return Err(Error::shape(format!(
            "{}: input {in_shape:?} not divisible by in-features {n}",
            layer.name
        )));
    }
    let rows = a.len() / n;
    let mut z = match weights {
        HostWeights::Dense(w_data) => {
            let xm = Mat::from_rows_f32(rows, n, a.as_ref())?;
            let wm = Mat::from_rows_f32(n, m, w_data)?;
            xm.matmul_with(pool, &wm)?.data
        }
        HostWeights::Packed {
            bytes,
            bits,
            scale,
            scales,
        } => {
            let pw = fused::PackedWeight {
                bytes,
                bits,
                scale,
                scales,
                n,
                m,
            };
            let mut z = Vec::new();
            fused::matmul_packed_with(pool, a.as_ref(), rows, &pw, &mut z)?;
            z
        }
    };
    for zrow in z.chunks_mut(m) {
        for (zv, &b) in zrow.iter_mut().zip(bias) {
            *zv += b as f64;
        }
    }
    let relu = layer.act == "relu";
    let out = if want_out {
        let mut outd = vec![0.0f32; rows * m];
        for (o, &zv) in outd.iter_mut().zip(&z) {
            let v = zv as f32;
            *o = if relu { v.max(0.0) } else { v };
        }
        let shape = if in_shape.len() == 4 {
            vec![in_shape[0], in_shape[1], in_shape[2], m]
        } else {
            vec![rows, m]
        };
        Some(Tensor::new(shape, outd)?)
    } else {
        None
    };
    Ok(LayerPass {
        a,
        in_shape,
        rows,
        n,
        m,
        pooled,
        z,
        out,
    })
}

/// Run the layer chain; optionally record each layer's matmul input and
/// optionally fake-quant it first (the forward_actq path). Returns the
/// logits.
fn run_graph(
    pool: &ThreadPool,
    layers: &[LayerInfo],
    weights: &[Tensor],
    biases: &[Tensor],
    x: &Tensor,
    mut record: Option<&mut Vec<Tensor>>,
    actq: Option<(&[ActQuantParams], &[u8])>,
) -> Result<Tensor> {
    let mut cur = x.clone();
    for (li, layer) in layers.iter().enumerate() {
        let w = &weights[li];
        let nm = weight_dims(layer, w)?;
        let bias = biases.get(li).map(|b| b.data()).unwrap_or(&[]);
        let tf: Option<Box<dyn Fn(&mut [f32])>> = actq.map(|(params, bits)| {
            let (p, b) = (params[li], bits[li]);
            Box::new(move |a: &mut [f32]| fake_quant_act(a, &p, b))
                as Box<dyn Fn(&mut [f32])>
        });
        // scope the pass so its borrow of `cur` ends before reassignment
        let next = {
            let pass = layer_pass(
                pool,
                layer,
                HostWeights::Dense(w.data()),
                nm,
                bias,
                &cur,
                tf.as_deref(),
                true,
            )?;
            if let Some(rec) = record.as_mut() {
                rec.push(Tensor::new(pass.in_shape.clone(), pass.a.to_vec())?);
            }
            pass.out.expect("want_out set")
        };
        cur = next;
    }
    Ok(cur)
}

/// Pre-activation, bias-free layer map (the reconstruction target
/// `layer_fwd` computes on the PJRT side).
fn layer_forward(
    pool: &ThreadPool,
    layer: &LayerInfo,
    x: &Tensor,
    w: &Tensor,
) -> Result<Tensor> {
    let nm = weight_dims(layer, w)?;
    let pass = layer_pass(pool, layer, HostWeights::Dense(w.data()), nm, &[], x, None, false)?;
    let out: Vec<f32> = pass.z.iter().map(|&v| v as f32).collect();
    let shape = if pass.in_shape.len() == 4 {
        vec![pass.in_shape[0], pass.in_shape[1], pass.in_shape[2], pass.m]
    } else {
        vec![pass.rows, pass.m]
    };
    Tensor::new(shape, out)
}

// ---- synthetic model construction ----------------------------------------

fn build_synthetic(pool: &ThreadPool, info: ModelInfo) -> Result<LoadedModel> {
    let k = info.layers.len();
    if k == 0 {
        return Err(Error::config(format!("{}: synthetic model with no layers", info.name)));
    }
    let (mut weights, mut biases) = synth::synthetic_weights(&info, SYNTH_WEIGHT_SEED)?;
    // Closed-form nearest-class-mean head: feature prototypes over a
    // fixed generator draw, W[:,c] = μ_c, b_c = −‖μ_c‖²/2 — so
    // argmax_c(f·μ_c + b_c) is the min-distance class.
    let (imgs, labels) = synth::generate(PROTO_SAMPLES, PROTO_SEED);
    let mut feats = run_graph(
        pool,
        &info.layers[..k - 1],
        &weights[..k - 1],
        &biases[..k - 1],
        &imgs,
        None,
        None,
    )?;
    if feats.shape().len() == 4 {
        feats = avg_pool(&feats)?;
    }
    let f = feats.shape()[1];
    let head = &info.layers[k - 1];
    let (hn, hm) = (head.wshape[0], head.wshape[1]);
    if hn != f {
        return Err(Error::shape(format!(
            "{}: head expects {hn} features, feature stack produces {f}",
            info.name
        )));
    }
    let mut sums = vec![0.0f64; f * hm];
    let mut counts = vec![0usize; hm];
    for (bi, &lab) in labels.iter().enumerate() {
        let c = lab as usize % hm;
        counts[c] += 1;
        for (j, &v) in feats.data()[bi * f..(bi + 1) * f].iter().enumerate() {
            sums[j * hm + c] += v as f64;
        }
    }
    let mut wh = vec![0.0f32; f * hm];
    let mut bh = vec![0.0f32; hm];
    for c in 0..hm {
        if counts[c] == 0 {
            continue;
        }
        let inv = 1.0 / counts[c] as f64;
        let mut norm2 = 0.0f64;
        for j in 0..f {
            let mu = sums[j * hm + c] * inv;
            wh[j * hm + c] = mu as f32;
            norm2 += mu * mu;
        }
        bh[c] = (-0.5 * norm2) as f32;
    }
    weights[k - 1] = Tensor::new(vec![f, hm], wh)?;
    biases[k - 1] = Tensor::from_vec(bh);
    Ok(LoadedModel {
        info,
        weights,
        biases,
    })
}

// ---- backend-neutral handle impls ----------------------------------------

struct HostPrepared<'a> {
    be: &'a HostBackend,
    model: &'a LoadedModel,
    weights: &'a [Tensor],
}

impl PreparedModel for HostPrepared<'_> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        run_graph(
            self.be.pool,
            &self.model.info.layers,
            self.weights,
            &self.model.biases,
            x,
            None,
            None,
        )
    }

    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor> {
        let k = self.model.num_layers();
        if act_params.len() != k || act_bits.len() != k {
            return Err(Error::shape(format!(
                "expected {k} activation params/bits, got {}/{}",
                act_params.len(),
                act_bits.len()
            )));
        }
        run_graph(
            self.be.pool,
            &self.model.info.layers,
            self.weights,
            &self.model.biases,
            x,
            None,
            Some((act_params, act_bits)),
        )
    }

    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        let mut rec = Vec::with_capacity(self.model.num_layers());
        let logits = run_graph(
            self.be.pool,
            &self.model.info.layers,
            self.weights,
            &self.model.biases,
            x,
            Some(&mut rec),
            None,
        )?;
        Ok((rec, logits))
    }
}

struct HostLayer<'a> {
    be: &'a HostBackend,
    layer: &'a LayerInfo,
    w: &'a Tensor,
}

impl PreparedLayer for HostLayer<'_> {
    fn fwd(&self, x: &Tensor) -> Result<Tensor> {
        layer_forward(self.be.pool, self.layer, x, self.w)
    }
}

struct HostScan<'a> {
    be: &'a HostBackend,
    setup: ScanSetup<'a>,
    state: ScanState,
}

impl CalibScan for HostScan<'_> {
    fn scan(&mut self, xs: &Tensor, ys: &Tensor, beta: f32) -> Result<f32> {
        let k = xs.shape().first().copied().unwrap_or(0);
        if k == 0 || ys.shape().first() != Some(&k) {
            return Err(Error::shape(format!(
                "scan stacks disagree: {:?} vs {:?}",
                xs.shape(),
                ys.shape()
            )));
        }
        let w = self.setup.w_fp.data();
        let (n, m) = weight_dims(self.setup.layer, self.setup.w_fp)?;
        let per_x = xs.len() / k;
        let per_y = ys.len() / k;
        if per_x % n != 0 || per_y != (per_x / n) * m {
            return Err(Error::shape(format!(
                "scan stack geometry: {per_x} x-elems, {per_y} y-elems, w {n}x{m}"
            )));
        }
        let rows = per_x / n;
        let g = self.setup.grid;
        let (s, lo, hi) = (g.scale, g.lo, g.hi);
        let lr = self.setup.lr;
        let is_attention = matches!(self.setup.kind, ScanKind::Attention { .. });
        let denom = (rows * m) as f64;
        let mut wq = vec![0.0f64; n * m];
        // Per-element gradient factor, meaning depends on the kind:
        // Attention — erf(α/(√2·τ)) for the Eq.-6 decay rule;
        // AdaRound — dŵ/dV (0 where the clip or rectifier saturates).
        let mut factor = vec![0.0f32; n * m];
        // AdaRound regularizer gradient dReg/dV (zero for Attention).
        let mut reg = vec![0.0f32; n * m];
        let mut loss_sum = 0.0f64;
        for step in 0..k {
            let var = self.state.var.data();
            match self.setup.kind {
                ScanKind::Attention { tau } => {
                    // Forward Eq. (3): rounded, exactly as the device
                    // fakequant kernel and attention_finalize.
                    let inv_sqrt2_tau =
                        1.0 / (std::f64::consts::SQRT_2 * tau.max(1e-8) as f64);
                    for i in 0..n * m {
                        let q = round_half_even(w[i] / s + var[i]);
                        wq[i] = (s * q.clamp(lo, hi)) as f64;
                        factor[i] =
                            crate::quant::erf(var[i] as f64 * inv_sqrt2_tau) as f32;
                    }
                }
                ScanKind::AdaRound { lambda } => {
                    for i in 0..n * m {
                        let sig = 1.0 / (1.0 + (-var[i]).exp());
                        let h = (1.2 * sig - 0.1).clamp(0.0, 1.0);
                        let u = (w[i] / s).floor() + h;
                        wq[i] = (s * u.clamp(lo, hi)) as f64;
                        let hp = if h > 0.0 && h < 1.0 {
                            1.2 * sig * (1.0 - sig)
                        } else {
                            0.0
                        };
                        factor[i] = if u > lo && u < hi { s * hp } else { 0.0 };
                        let d = 2.0 * h - 1.0;
                        // d/dV of λ(1 − |2h−1|^β)
                        reg[i] = -lambda * beta * d.abs().powf(beta - 1.0)
                            * 2.0 * d.signum() * hp;
                    }
                }
            }
            let xd = &xs.data()[step * per_x..(step + 1) * per_x];
            let yd = &ys.data()[step * per_y..(step + 1) * per_y];
            let xm = Mat::from_rows_f32(rows, n, xd)?;
            let wqm = Mat {
                rows: n,
                cols: m,
                data: std::mem::take(&mut wq),
            };
            let ym = xm.matmul_with(self.be.pool, &wqm)?;
            wq = wqm.data; // reclaim the buffer for the next step
            let mut d = ym;
            let mut acc = 0.0f64;
            for (dv, &yv) in d.data.iter_mut().zip(yd) {
                *dv -= yv as f64;
                acc += *dv * *dv;
            }
            loss_sum += acc / denom;
            // G = Xᵀ·D -> dL/dŵ = 2G/denom
            let xt = mat_transposed_f32(rows, n, xd);
            let gm = xt.matmul_with(self.be.pool, &d)?;
            // Adam on var
            self.state.t += 1.0;
            let t = self.state.t;
            let c1 = 1.0 - 0.9f32.powf(t);
            let c2 = 1.0 - 0.999f32.powf(t);
            let var = self.state.var.data_mut();
            let mm = self.state.m.data_mut();
            let vv = self.state.v.data_mut();
            for i in 0..n * m {
                let gup = (2.0 * gm.data[i] / denom) as f32;
                let grad = if is_attention {
                    // Eq. (6): dL/dα = g·(0.5 ± 0.5·erf(α/(√2·τ))) with
                    // g = s·dL/dŵ (mirrors _aq_bwd in the Pallas wrapper).
                    let gz = gup * s;
                    let dz = if gz > 0.0 {
                        0.5 + 0.5 * factor[i]
                    } else {
                        0.5 - 0.5 * factor[i]
                    };
                    gz * dz
                } else {
                    gup * factor[i] + reg[i]
                };
                mm[i] = 0.9 * mm[i] + 0.1 * grad;
                vv[i] = 0.999 * vv[i] + 0.001 * grad * grad;
                let mh = mm[i] / c1;
                let vh = vv[i] / c2;
                var[i] -= lr * mh / (vh.sqrt() + 1e-8);
            }
        }
        self.be
            .metrics
            .incr("pipeline.calib_steps", k as u64);
        Ok((loss_sum / k as f64) as f32)
    }

    fn state(&self) -> &ScanState {
        &self.state
    }
}

// ---- STE-QAT -------------------------------------------------------------

struct QatLayerCtx {
    /// Matmul input (post pool / act-fq), row-major rows × n.
    a: Vec<f32>,
    rows: usize,
    n: usize,
    m: usize,
    /// Fake-quantized weight actually multiplied.
    wq: Vec<f32>,
    /// Pre-activation output (rows × m) for the ReLU mask.
    z: Vec<f64>,
    /// Some((batch, hw)) when this layer pooled its 4-D input.
    pooled: Option<(usize, usize)>,
    relu: bool,
}

/// Max-abs weight fake-quant on the same grid the deploy-time
/// quantization in `coordinator::qat` finalizes with (absmax_scale +
/// QGrid + nearest), so training and deployment never drift apart.
fn fake_quant_weight(w: &[f32], wbits: u8) -> Result<Vec<f32>> {
    let s = absmax_scale(w, wbits);
    if !(s.is_finite() && s > 0.0) {
        return Ok(w.to_vec()); // all-zero tensor: nothing to quantize
    }
    let grid = QGrid::signed(wbits, s)?;
    Ok(nearest(w, &grid))
}

fn fake_quant_relu_acts(a: &mut [f32], abits: u8) {
    let hi = ((1u32 << abits) - 1) as f32;
    let amax = a.iter().fold(0.0f32, |acc, &v| acc.max(v));
    if amax <= 0.0 {
        return;
    }
    let s = amax / hi;
    for v in a.iter_mut() {
        *v = s * round_half_even(*v / s).clamp(0.0, hi);
    }
}

fn host_qat_step(
    pool: &ThreadPool,
    model: &LoadedModel,
    state: &mut QatState,
    x: &Tensor,
    y: &[i32],
    lr: f32,
    wbits: u8,
    abits: u8,
) -> Result<f32> {
    let layers = &model.info.layers;
    let k = layers.len();
    let batch = x.shape()[0];
    if y.len() != batch {
        return Err(Error::shape("qat labels/batch mismatch"));
    }
    // The CE loss below reads the head's pre-activation as the logits
    // and the backward applies no final-layer activation mask, so a
    // rectified head would silently train a different function than
    // evaluate() scores. Reject it instead.
    if layers[k - 1].act == "relu" {
        return Err(Error::config(format!(
            "{}: host QAT expects an identity (logit) head, got relu",
            model.info.name
        )));
    }
    // ---- forward, recording per-layer context (shared layer_pass) ----
    let mut ctxs: Vec<QatLayerCtx> = Vec::with_capacity(k);
    let mut cur = x.clone();
    for (li, layer) in layers.iter().enumerate() {
        let nm = weight_dims(layer, &state.ws[li])?;
        let wq = fake_quant_weight(state.ws[li].data(), wbits)?;
        // post-ReLU activations carry the fake-quant grid; the raw
        // image input stays FP (matches the device qat_step graphs).
        let tf = |a: &mut [f32]| fake_quant_relu_acts(a, abits);
        let tfopt: Option<&dyn Fn(&mut [f32])> =
            if li > 0 { Some(&tf) } else { None };
        // scope the pass so its borrow of `cur` ends before reassignment
        let next = {
            let pass = layer_pass(
                pool,
                layer,
                HostWeights::Dense(&wq),
                nm,
                state.bs[li].data(),
                &cur,
                tfopt,
                true,
            )?;
            let next = pass.out.expect("want_out set");
            ctxs.push(QatLayerCtx {
                a: pass.a.into_owned(),
                rows: pass.rows,
                n: pass.n,
                m: pass.m,
                wq,
                z: pass.z,
                pooled: pass.pooled,
                relu: layer.act == "relu",
            });
            next
        };
        cur = next;
    }
    // ---- softmax cross-entropy ----
    let classes = ctxs[k - 1].m;
    let logits = &ctxs[k - 1].z;
    let mut dz = Mat::zeros(batch, classes);
    let mut loss = 0.0f64;
    for bi in 0..batch {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0f64;
        for &v in row {
            denom += (v - mx).exp();
        }
        let lab = y[bi] as usize % classes;
        loss -= (row[lab] - mx) - denom.ln();
        for c in 0..classes {
            let p = (row[c] - mx).exp() / denom;
            dz.data[bi * classes + c] =
                (p - if c == lab { 1.0 } else { 0.0 }) / batch as f64;
        }
    }
    loss /= batch as f64;
    // ---- backward + SGD momentum (STE through both fake-quants) ----
    let mut dz = dz; // gradient w.r.t. the current layer's pre-activation
    for li in (0..k).rev() {
        let c = &ctxs[li];
        // dW = aᵀ·dz, db = colsum(dz)
        let at = mat_transposed_f32(c.rows, c.n, &c.a);
        let dw = at.matmul_with(pool, &dz)?;
        let mut db = vec![0.0f64; c.m];
        for row in dz.data.chunks(c.m) {
            for (d, &v) in db.iter_mut().zip(row) {
                *d += v;
            }
        }
        if li > 0 {
            // da = dz·wqᵀ (rows × n)
            let wqt = mat_transposed_f32(c.n, c.m, &c.wq);
            let mut da = dz.matmul_with(pool, &wqt)?;
            if let Some((b, hw)) = c.pooled {
                // undo the average pool: broadcast /hw to every position
                let mut full = Mat::zeros(b * hw, c.n);
                let inv = 1.0 / hw as f64;
                for bi in 0..b {
                    let src = &da.data[bi * c.n..(bi + 1) * c.n];
                    for p in 0..hw {
                        let dst =
                            &mut full.data[(bi * hw + p) * c.n..(bi * hw + p + 1) * c.n];
                        for (dv, &sv) in dst.iter_mut().zip(src) {
                            *dv = sv * inv;
                        }
                    }
                }
                da = full;
            }
            // ReLU mask of the previous layer's pre-activation; act
            // fake-quant is a straight-through pass.
            let prev = &ctxs[li - 1];
            debug_assert_eq!(da.data.len(), prev.z.len());
            for (dv, &zv) in da.data.iter_mut().zip(&prev.z) {
                if prev.relu && zv <= 0.0 {
                    *dv = 0.0;
                }
            }
            dz = da;
        }
        // SGD momentum on the FP master weights (STE).
        let w = state.ws[li].data_mut();
        let mw = state.mws[li].data_mut();
        for i in 0..w.len() {
            mw[i] = 0.9 * mw[i] + dw.data[i] as f32;
            w[i] -= lr * mw[i];
        }
        let b = state.bs[li].data_mut();
        let mb = state.mbs[li].data_mut();
        for i in 0..b.len() {
            mb[i] = 0.9 * mb[i] + db[i] as f32;
            b[i] -= lr * mb[i];
        }
    }
    Ok(loss as f32)
}

// ---- Backend impl --------------------------------------------------------

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn platform(&self) -> String {
        format!("host cpu ({} threads)", self.pool.size())
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn worker_topology(&self, requested: usize) -> crate::backend::WorkerTopology {
        // Serve workers block on the queue between batches, so running
        // more workers than cores is fine (and what the fleet tests
        // rely on for a deterministic worker count regardless of the
        // machine); each worker's inner kernel fan-out is capped to its
        // share of the pool so the fleet never oversubscribes *compute*.
        // 32 bounds thread creation against absurd --workers values.
        let workers = requested.clamp(1, 32);
        let width = (self.pool.size() / workers).max(1);
        crate::backend::WorkerTopology {
            workers,
            worker_width: width,
            detail: format!(
                "host pool of {} threads split {workers} × width {width}",
                self.pool.size()
            ),
        }
    }

    fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        let info = manifest.model(name)?;
        if !info.w_files.is_empty() {
            return LoadedModel::load(manifest, name);
        }
        if let Some((w, b)) = self.synth_cache.lock().unwrap().get(name) {
            return Ok(LoadedModel {
                info: info.clone(),
                weights: w.clone(),
                biases: b.clone(),
            });
        }
        let built = self.metrics.time("host.build_synthetic", || {
            build_synthetic(self.pool, info.clone())
        })?;
        self.synth_cache.lock().unwrap().insert(
            name.to_string(),
            (built.weights.clone(), built.biases.clone()),
        );
        Ok(built)
    }

    fn prepare<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        if weights.len() != model.num_layers() {
            return Err(Error::shape(format!(
                "{}: {} weight tensors for {} layers",
                model.info.name,
                weights.len(),
                model.num_layers()
            )));
        }
        Ok(Box::new(HostPrepared {
            be: self,
            model,
            weights,
        }))
    }

    fn prepare_serving<'a>(
        &'a self,
        model: &'a LoadedModel,
        weights: &'a [Tensor],
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        // Host tensors are already resident; the plain prepared handle
        // IS the serving handle (Send + Sync, zero per-call staging).
        self.prepare(model, weights)
    }

    fn prepare_artifact<'a>(
        &'a self,
        model: &'a LoadedModel,
        artifact: &'a crate::deploy::artifact::PackedModel,
        _staged: &'a mut Vec<Tensor>,
    ) -> Result<Box<dyn PreparedModel + 'a>> {
        // Streaming override: codes stay packed, weights exist in f32
        // one layer at a time (reusable scratch feeding layer_pass) —
        // no second full-f32 copy of the model.
        Ok(Box::new(crate::deploy::dequant::PackedHostForward::new(
            model, artifact,
        )?))
    }

    fn supports_progressive(&self) -> bool {
        // deploy::progressive executes through this backend's
        // layer_pass, so partial- and full-depth forwards are
        // bit-identical to the packed host path.
        true
    }

    fn prepare_layer<'a>(
        &'a self,
        layer: &'a LayerInfo,
        w: &'a Tensor,
    ) -> Result<Box<dyn PreparedLayer + 'a>> {
        weight_dims(layer, w)?;
        Ok(Box::new(HostLayer { be: self, layer, w }))
    }

    fn begin_scan<'a>(
        &'a self,
        setup: ScanSetup<'a>,
        init: ScanState,
    ) -> Result<Box<dyn CalibScan + 'a>> {
        if init.var.shape() != setup.w_fp.shape() {
            return Err(Error::shape(format!(
                "scan var {:?} vs weight {:?}",
                init.var.shape(),
                setup.w_fp.shape()
            )));
        }
        Ok(Box::new(HostScan {
            be: self,
            setup,
            state: init,
        }))
    }

    fn qat_step(
        &self,
        model: &LoadedModel,
        state: &mut QatState,
        x: &Tensor,
        y: &[i32],
        lr: f32,
        wbits: u8,
        abits: u8,
    ) -> Result<f32> {
        let loss = host_qat_step(self.pool, model, state, x, y, lr, wbits, abits)?;
        self.metrics.incr("qat.steps", 1);
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QGrid;
    use crate::util::rng::Rng;

    fn conv_layer(i: usize, n: usize, m: usize) -> LayerInfo {
        LayerInfo::host(i, &format!("c{i}"), "conv", "relu", [n, m], false)
    }

    fn lin_layer(i: usize, n: usize, m: usize) -> LayerInfo {
        LayerInfo::host(i, &format!("l{i}"), "linear", "identity", [n, m], true)
    }

    fn w(shape: [usize; 2], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut d = vec![0.0f32; shape[0] * shape[1]];
        rng.fill_gaussian(&mut d, 0.0, 0.5);
        Tensor::new(shape.to_vec(), d).unwrap()
    }

    #[test]
    fn avg_pool_means() {
        let x = Tensor::new(
            vec![1, 2, 2, 2],
            vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0],
        )
        .unwrap();
        let p = avg_pool(&x).unwrap();
        assert_eq!(p.shape(), &[1, 2]);
        assert_eq!(p.data(), &[2.5, 25.0]);
    }

    #[test]
    fn graph_shapes_conv_then_linear() {
        let layers = vec![conv_layer(0, 3, 4), lin_layer(1, 4, 5)];
        let weights = vec![w([3, 4], 1), w([4, 5], 2)];
        let biases = vec![Tensor::zeros(vec![4]), Tensor::zeros(vec![5])];
        let x = Tensor::zeros(vec![2, 4, 4, 3]);
        let pool = ThreadPool::seq();
        let mut rec = Vec::new();
        let logits =
            run_graph(&pool, &layers, &weights, &biases, &x, Some(&mut rec), None)
                .unwrap();
        assert_eq!(logits.shape(), &[2, 5]);
        assert_eq!(rec.len(), 2);
        assert_eq!(rec[0].shape(), &[2, 4, 4, 3]); // conv input = NHWC
        assert_eq!(rec[1].shape(), &[2, 4]); // linear input = pooled
    }

    #[test]
    fn layer_forward_is_bias_free_preactivation() {
        let layer = conv_layer(0, 2, 2);
        let wt = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, -1.0]).unwrap();
        let x = Tensor::new(vec![1, 1, 1, 2], vec![3.0, 2.0]).unwrap();
        let pool = ThreadPool::seq();
        let y = layer_forward(&pool, &layer, &x, &wt).unwrap();
        // no relu even though act = relu; no bias
        assert_eq!(y.data(), &[3.0, -2.0]);
    }

    #[test]
    fn fake_quant_act_roundtrips_grid_points() {
        let p = ActQuantParams { scale: 0.5, zero: -1.0 };
        let mut x = vec![-1.0, -0.76, 0.0, 100.0];
        fake_quant_act(&mut x, &p, 2); // levels 0..3 -> values -1..0.5
        assert_eq!(x, vec![-1.0, -1.0, 0.0, 0.5]);
    }

    #[test]
    fn host_scan_reduces_reconstruction_loss() {
        let be = HostBackend::new();
        // 8×8: with α ~ N(0, 0.5) a meaningful fraction of the 64 cells
        // start flipped away from nearest, so the rounded-forward loss
        // has real headroom to recover.
        let layer = conv_layer(0, 8, 8);
        let w_fp = w([8, 8], 3);
        let grid = QGrid::signed(3, 0.11).unwrap();
        // batch of random inputs; reference = exact FP map
        let mut rng = Rng::new(9);
        let mut xd = vec![0.0f32; 8 * 64 * 8];
        rng.fill_gaussian(&mut xd, 0.0, 1.0);
        let xs = Tensor::new(vec![8, 64, 8], xd).unwrap();
        let xm = Mat::from_rows_f32(8 * 64, 8, xs.data()).unwrap();
        let wm = Mat::from_rows_f32(8, 8, w_fp.data()).unwrap();
        let ym = xm.matmul(&wm).unwrap();
        let ys = Tensor::new(
            vec![8, 64, 8],
            ym.data.iter().map(|&v| v as f32).collect(),
        )
        .unwrap();
        let mut alpha = Tensor::zeros(vec![8, 8]);
        Rng::new(4).fill_gaussian(alpha.data_mut(), 0.0, 0.5);
        let setup = ScanSetup {
            layer: &layer,
            w_fp: &w_fp,
            grid,
            lr: 0.02,
            kind: ScanKind::Attention { tau: 0.5 },
        };
        let mut scan = be.begin_scan(setup, ScanState::new(alpha)).unwrap();
        let first = scan.scan(&xs, &ys, 0.0).unwrap();
        let mut last = first;
        for _ in 0..6 {
            last = scan.scan(&xs, &ys, 0.0).unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(
            last < first,
            "Adam should reduce the reconstruction loss: {first} -> {last}"
        );
    }

    #[test]
    fn qat_step_updates_weights_and_loss_is_finite() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let mut state = QatState::from_model(&model);
        let (x, y) = synth::generate(8, 77);
        let w0 = state.ws[1].clone();
        let loss = be
            .qat_step(&model, &mut state, &x, &y, 1e-3, 4, 4)
            .unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        assert_ne!(state.ws[1], w0, "gradient step must move the weights");
    }

    #[test]
    fn forward_rows_independent_of_batch_composition() {
        // The serve micro-batcher stacks requests into one batch and
        // slices rows back out; that is only sound because every row of
        // the host forward is computed independently (per-row matmul
        // accumulation, per-sample pooling, elementwise activations).
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let prep = be.prepare(&model, &model.weights).unwrap();
        let (x, _) = synth::generate(6, 99);
        let batch = prep.forward(&x).unwrap();
        for i in 0..6 {
            let xi = x.slice_axis0(i, 1).unwrap();
            let yi = prep.forward(&xi).unwrap();
            assert_eq!(
                yi.data(),
                &batch.data()[i * yi.len()..(i + 1) * yi.len()],
                "row {i} must be bit-identical to its single-sample forward"
            );
        }
    }

    #[test]
    fn synthetic_model_beats_chance() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let (x, y) = synth::generate(128, 4242);
        let prep = be.prepare(&model, &model.weights).unwrap();
        let logits = prep.forward(&x).unwrap();
        let acc = crate::tensor::ops::top1_accuracy(&logits, &y);
        assert!(
            acc > 2.0 / 16.0,
            "nearest-class-mean head should beat chance, got {acc}"
        );
    }
}
