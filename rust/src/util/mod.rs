//! General-purpose substrates.
//!
//! The offline crate registry only carries the `xla` crate's dependency
//! closure, so the conveniences a production service would pull from
//! crates.io — JSON, CLI parsing, RNG, structured logging, a thread pool,
//! a property-test driver — are implemented here as small, fully-tested
//! modules.

pub mod args;
pub mod error;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod timer;
