//! Deterministic RNG substrate: xorshift64* with Box–Muller Gaussians.
//!
//! `rand`/`rand_distr` are not in the offline registry; this generator is
//! small, fast, and — importantly — specified well enough to port (the
//! Python dataset generator and this one are cross-checked by recorded
//! moments in tests). Used for Stochastic-Round coin flips, batch
//! sampling, and the α initialization (paper §3.3:
//! α ~ N(0, (τ/s)²)).

/// xorshift64* (Vigna 2016) — 64-bit state, period 2^64 − 1.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller deviate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero state; splitmix the seed so small seeds
        // don't produce correlated low bits.
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        s ^= s >> 30;
        s = s.wrapping_mul(0xBF58476D1CE4E5B9);
        s ^= s >> 27;
        s = s.wrapping_mul(0x94D049BB133111EB);
        s ^= s >> 31;
        Rng {
            state: if s == 0 { 0xDEADBEEFCAFEF00D } else { s },
            spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high-quality bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free for our n << 2^64 use (bias < 2^-40 for n < 2^24).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.gaussian() as f32) * std + mean
    }

    /// Fill a slice with N(mean, std²) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32(mean, std);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n; Floyd's algorithm
    /// would be fancier — simple retry is fine at our sizes).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k == n {
            return (0..n).collect();
        }
        let mut picked = vec![false; n];
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if !picked[i] {
                picked[i] = true;
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 32);
        assert_eq!(idx.len(), 32);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 32);
    }
}
