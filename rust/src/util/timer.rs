//! Scoped timing + a tiny metrics registry for the pipeline.
//!
//! The coordinator reports per-phase wall-clock (capture / scale-search /
//! calibrate / evaluate) in EXPERIMENTS.md; this is the source of those
//! numbers.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Accumulates named durations and counters across a run.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Default)]
struct MetricsInner {
    durations_s: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_duration(&self, name: &str, seconds: f64) {
        let mut m = self.inner.lock().unwrap();
        *m.durations_s.entry(name.to_string()).or_default() += seconds;
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add_duration(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn snapshot(&self) -> (BTreeMap<String, f64>, BTreeMap<String, u64>) {
        let m = self.inner.lock().unwrap();
        (m.durations_s.clone(), m.counters.clone())
    }

    pub fn report(&self) -> String {
        let (durs, counts) = self.snapshot();
        let mut s = String::new();
        for (k, v) in durs {
            s.push_str(&format!("  {k:<32} {v:10.3}s\n"));
        }
        for (k, v) in counts {
            s.push_str(&format!("  {k:<32} {v:>10}\n"));
        }
        s
    }
}

/// RAII scope timer logging at debug level.
pub struct Scope<'a> {
    name: &'a str,
    start: Instant,
}

impl<'a> Scope<'a> {
    pub fn new(name: &'a str) -> Self {
        Scope {
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for Scope<'_> {
    fn drop(&mut self) {
        log::debug!("{} took {:.3}s", self.name, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::new();
        m.add_duration("phase", 1.0);
        m.add_duration("phase", 0.5);
        m.incr("steps", 10);
        m.incr("steps", 5);
        let (d, c) = m.snapshot();
        assert!((d["phase"] - 1.5).abs() < 1e-12);
        assert_eq!(c["steps"], 15);
    }

    #[test]
    fn time_returns_value() {
        let m = Metrics::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.snapshot().0.contains_key("work"));
    }
}
