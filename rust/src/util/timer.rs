//! Scoped timing + a tiny metrics registry for the pipeline.
//!
//! The coordinator reports per-phase wall-clock (capture / scale-search /
//! calibrate / evaluate) in EXPERIMENTS.md; this is the source of those
//! numbers.
//!
//! Since the trace PR this module is a *view* over the tracer's clock:
//! every duration is measured as a [`crate::trace::clock_us`] pair (the
//! same epoch every exported span timestamp uses), and both [`Metrics::
//! time`] and [`Scope`] additionally open a `pipeline`-category span so
//! timed phases show up in `--trace` output for free. One clock, one
//! registry — no second `Instant` plumbing next to the tracer.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::trace::{self, Category};

/// Accumulates named durations and counters across a run.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Default)]
struct MetricsInner {
    durations_s: BTreeMap<String, f64>,
    counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_duration(&self, name: &str, seconds: f64) {
        let mut m = self.inner.lock().unwrap();
        *m.durations_s.entry(name.to_string()).or_default() += seconds;
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.counters.entry(name.to_string()).or_default() += by;
    }

    /// Time `f` under `name`: accumulate the duration in the registry
    /// and emit a `pipeline` span (visible in `--trace` exports when
    /// tracing is enabled; one relaxed atomic load when it isn't).
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let span = trace::span(Category::Pipeline, name.to_string());
        let t0_us = trace::clock_us();
        let out = f();
        let dt_us = trace::clock_us().saturating_sub(t0_us);
        drop(span);
        self.add_duration(name, dt_us as f64 / 1e6);
        out
    }

    pub fn snapshot(&self) -> (BTreeMap<String, f64>, BTreeMap<String, u64>) {
        let m = self.inner.lock().unwrap();
        (m.durations_s.clone(), m.counters.clone())
    }

    pub fn report(&self) -> String {
        let (durs, counts) = self.snapshot();
        let mut s = String::new();
        for (k, v) in durs {
            s.push_str(&format!("  {k:<32} {v:10.3}s\n"));
        }
        for (k, v) in counts {
            s.push_str(&format!("  {k:<32} {v:>10}\n"));
        }
        s
    }
}

/// RAII scope timer: logs at debug level on drop and doubles as a
/// `pipeline`-category trace span over its lifetime.
pub struct Scope<'a> {
    name: &'a str,
    start_us: u64,
    _span: trace::SpanGuard,
}

impl<'a> Scope<'a> {
    pub fn new(name: &'a str) -> Self {
        Scope {
            name,
            start_us: trace::clock_us(),
            _span: trace::span(Category::Pipeline, name.to_string()),
        }
    }
}

impl Drop for Scope<'_> {
    fn drop(&mut self) {
        let dt_us = trace::clock_us().saturating_sub(self.start_us);
        log::debug!("{} took {:.3}s", self.name, dt_us as f64 / 1e6);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let m = Metrics::new();
        m.add_duration("phase", 1.0);
        m.add_duration("phase", 0.5);
        m.incr("steps", 10);
        m.incr("steps", 5);
        let (d, c) = m.snapshot();
        assert!((d["phase"] - 1.5).abs() < 1e-12);
        assert_eq!(c["steps"], 15);
    }

    #[test]
    fn time_returns_value() {
        let m = Metrics::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert!(m.snapshot().0.contains_key("work"));
    }

    #[test]
    fn scope_drops_cleanly_without_tracing() {
        // Scope must be safe to use whether or not the tracer is on
        // (and whether or not the `trace` feature is compiled in).
        let s = Scope::new("scoped");
        drop(s);
    }
}
