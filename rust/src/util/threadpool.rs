//! Host-side worker pool (tokio/rayon are not offline-available).
//!
//! Two execution styles, both bounded by the pool's `size`:
//!
//! * **Scoped fork-join** — [`ThreadPool::par_chunks`],
//!   [`ThreadPool::par_chunk_map`], [`ThreadPool::scope_map`] and the raw
//!   [`ThreadPool::scope`] escape hatch. Built on [`std::thread::scope`],
//!   so closures may borrow slices from the caller's stack frame — no
//!   `'static` boxing, no `Arc` shuffling, zero `unsafe`. Threads are
//!   spawned per call and joined before return; a panicking worker
//!   propagates the panic to the caller after every sibling has joined,
//!   and the pool stays usable afterwards. Spawn cost is tens of
//!   microseconds per worker, noise for the ≥100µs-per-chunk workloads
//!   these methods are used for (rounding kernels, fused MSE scale
//!   search, Gram blocks, per-layer coding lengths).
//! * **Persistent queue** — [`ThreadPool::spawn`] / [`ThreadPool::map`]
//!   for `'static` jobs (npy decoding, background CSV writes). Workers
//!   are created lazily on first use, so pools that only ever run scoped
//!   work never park idle threads.
//!
//! The coordinator pipeline shares one process-wide pool via [`global`],
//! sized by the `AR_THREADS` env var (default: all cores). Hot paths in
//! `quant::kernel`, `quant::scale`, `linalg`, and `mixed` take a
//! `&ThreadPool` so callers control sharing; [`ThreadPool::seq`] gives a
//! free sequential pool for contexts that are already parallel (e.g.
//! per-layer coding lengths inside `mixed::allocate`).
//!
//! Nested fan-outs are bounded by a thread-local **width cap**
//! ([`with_width_cap`]): an outer fan-out (experiment table cells in
//! `Ctx::run_many`, the serve worker) wraps each task so its inner
//! kernels see a width-reduced view of the same shared pool instead of
//! each spawning a full pool's worth of scoped workers.
//!
//! Two of this module's claims are machine-enforced by the repo analyzer
//! (`cargo run -p analyze`): the pool stays `unsafe`-free (AR001 would
//! demand a SAFETY comment the moment one appears), and it is the only
//! non-test site in the crate allowed to call `thread::spawn` — every
//! other module must fan out through the width-capped pool (AR003).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// Per-thread fan-out cap for the scoped APIs (see [`with_width_cap`]).
    static WIDTH_CAP: Cell<usize> = Cell::new(usize::MAX);
}

/// Run `f` with every scoped fan-out on **this thread** capped at `cap`
/// workers (min 1), restoring the previous cap afterwards — also on
/// panic, so a poisoned cell can't leak a narrow cap into unrelated work.
///
/// This is the nested-parallelism bound: when N independent tasks are
/// already fanned out across the global pool (experiment table cells via
/// `Ctx::run_many`, the serve worker next to live producers), each task's
/// *inner* matmuls/kernels would otherwise each spawn a full pool's worth
/// of scoped workers — transient oversubscription ≈ tasks × pool size.
/// The outer fan-out hands each task `with_width_cap(size / tasks, ..)`
/// instead, so the whole tree stays within one pool's width. Caps nest
/// narrowing-only (`min` with the ambient cap — an inner scope can
/// tighten but never widen its parent's bound) and are thread-local, so
/// sibling tasks never see each other's cap.
pub fn with_width_cap<T>(cap: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            WIDTH_CAP.with(|c| c.set(self.0));
        }
    }
    let prev = WIDTH_CAP.with(|c| c.replace(cap.max(1).min(c.get())));
    let _restore = Restore(prev);
    f()
}

/// The ambient fan-out cap on this thread (`usize::MAX` when uncapped).
pub fn current_width_cap() -> usize {
    WIDTH_CAP.with(|c| c.get())
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

struct Inner {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
}

impl Inner {
    fn start(size: usize) -> Inner {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ar-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        Inner { tx, handles }
    }
}

pub struct ThreadPool {
    size: usize,
    /// Persistent workers for the `'static` queue API; `None` until the
    /// first `spawn`/`map` call so scoped-only pools stay threadless.
    inner: Mutex<Option<Inner>>,
}

/// Smallest per-chunk element count worth forking a scoped worker for.
/// Below this, chunked methods run inline on the caller's thread.
pub const MIN_PAR_CHUNK: usize = 16 * 1024;

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        ThreadPool {
            size: size.max(1),
            inner: Mutex::new(None),
        }
    }

    /// A sequential pool (size 1): every scoped method runs inline with
    /// zero thread traffic. Useful inside already-parallel regions.
    pub fn seq() -> Self {
        Self::new(1)
    }

    /// Pool sized to the machine: `AR_THREADS` env override first, then
    /// `available_parallelism`, min 1.
    pub fn default_for_host() -> Self {
        Self::new(host_threads())
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The fan-out width scoped methods use from **this thread**: the
    /// configured size, reduced by any ambient [`with_width_cap`]. All
    /// width decisions in the scoped API (and in `linalg`/`quant`
    /// kernels that take a pool) go through this, so an outer fan-out
    /// can bound its children without plumbing a second pool around.
    pub fn width(&self) -> usize {
        self.size.min(current_width_cap())
    }

    // ---- scoped fork-join API -------------------------------------------

    /// Raw scoped escape hatch: exactly [`std::thread::scope`]. Present so
    /// pool users don't also reach for `std::thread` directly; note the
    /// spawned-thread count is the caller's responsibility here.
    pub fn scope<'env, F, T>(&self, f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope std::thread::Scope<'scope, 'env>) -> T,
    {
        std::thread::scope(f)
    }

    /// How many chunks to split `n` elements into: at most [`Self::width`],
    /// at least one, and never chunks smaller than [`MIN_PAR_CHUNK`].
    fn chunk_count(&self, n: usize) -> usize {
        if n == 0 {
            return 1;
        }
        self.width().min((n / MIN_PAR_CHUNK).max(1))
    }

    /// Elementwise kernel driver: split `input`/`output` into aligned
    /// chunks and run `f(first_index, in_chunk, out_chunk)` on scoped
    /// workers. Chunk boundaries depend only on lengths and pool size, so
    /// results are deterministic; elementwise kernels are bit-identical
    /// to their sequential form by construction.
    pub fn par_chunks<I, O, F>(&self, input: &[I], output: &mut [O], f: F)
    where
        I: Sync,
        O: Send,
        F: Fn(usize, &[I], &mut [O]) + Sync,
    {
        assert_eq!(input.len(), output.len(), "par_chunks length mismatch");
        let n = output.len();
        let chunks = self.chunk_count(n);
        if chunks <= 1 {
            f(0, input, output);
            return;
        }
        let chunk = (n + chunks - 1) / chunks;
        std::thread::scope(|s| {
            for (ci, (ic, oc)) in input
                .chunks(chunk)
                .zip(output.chunks_mut(chunk))
                .enumerate()
            {
                let f = &f;
                s.spawn(move || f(ci * chunk, ic, oc));
            }
        });
    }

    /// Reduction driver: run `f(first_index, chunk)` over parallel chunks
    /// of `input`, returning the per-chunk results in chunk order (merge
    /// order is therefore deterministic for a given pool size).
    pub fn par_chunk_map<I, R, F>(&self, input: &[I], f: F) -> Vec<R>
    where
        I: Sync,
        R: Send,
        F: Fn(usize, &[I]) -> R + Sync,
    {
        let n = input.len();
        let chunks = self.chunk_count(n);
        if chunks <= 1 {
            return vec![f(0, input)];
        }
        let chunk = (n + chunks - 1) / chunks;
        std::thread::scope(|s| {
            let handles: Vec<_> = input
                .chunks(chunk)
                .enumerate()
                .map(|(ci, c)| {
                    let f = &f;
                    s.spawn(move || f(ci * chunk, c))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("par_chunk_map worker panicked"))
                .collect()
        })
    }

    /// Task-list driver with dynamic load balancing: run `f(i)` for every
    /// `i in 0..n`, stealing indices from a shared counter (per-item cost
    /// may vary wildly, e.g. per-layer coding lengths). Results come back
    /// in index order.
    pub fn scope_map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let threads = self.width().min(n);
        if threads <= 1 {
            return (0..n).map(|i| f(i)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..threads {
                let next = &next;
                let slots = &slots;
                let f = &f;
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("scope_map slot lock")
                    .expect("scope_map slot filled")
            })
            .collect()
    }

    /// Split a row-major buffer into contiguous row blocks (at most
    /// `size`) and run `f(first_row, block)` on scoped workers. No
    /// minimum-work gate: callers decide when the rows are worth the
    /// spawns (see `Mat::matmul_with`).
    pub fn par_row_blocks<T, F>(&self, out: &mut [T], row_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(row_len > 0, "par_row_blocks needs row_len > 0");
        debug_assert_eq!(out.len() % row_len, 0);
        let rows = out.len() / row_len;
        let blocks = self.width().min(rows).max(1);
        if blocks <= 1 {
            f(0, out);
            return;
        }
        let rows_per = (rows + blocks - 1) / blocks;
        std::thread::scope(|s| {
            for (bi, block) in out.chunks_mut(rows_per * row_len).enumerate() {
                let f = &f;
                s.spawn(move || f(bi * rows_per, block));
            }
        });
    }

    // ---- persistent 'static queue API -----------------------------------

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        let mut guard = self.inner.lock().unwrap();
        let inner = guard.get_or_insert_with(|| Inner::start(self.size));
        inner.tx.send(Msg::Run(Box::new(job))).expect("pool alive");
    }

    /// Run `jobs` to completion, returning results in submission order.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.spawn(move || {
                let out = job();
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("worker result");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => job(),
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        let inner = self.inner.get_mut().ok().and_then(|o| o.take());
        if let Some(inner) = inner {
            for _ in &inner.handles {
                let _ = inner.tx.send(Msg::Shutdown);
            }
            for h in inner.handles {
                let _ = h.join();
            }
        }
    }
}

/// Host thread budget: `AR_THREADS` override, else all cores, min 1.
pub fn host_threads() -> usize {
    if let Ok(v) = std::env::var("AR_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide shared pool used by the coordinator pipeline and by
/// the pool-less convenience entry points (`mse_optimal_scale`,
/// `coding_length`, `mixed::allocate`). Sized by `AR_THREADS` at first
/// use.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(ThreadPool::default_for_host)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_everything() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join on drop
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn zero_size_clamped() {
        assert_eq!(ThreadPool::new(0).size(), 1);
        assert_eq!(ThreadPool::seq().size(), 1);
    }

    #[test]
    fn par_chunks_matches_serial() {
        let pool = ThreadPool::new(3);
        let input: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        let mut par = vec![0.0f32; input.len()];
        pool.par_chunks(&input, &mut par, |_, ic, oc| {
            for (o, &v) in oc.iter_mut().zip(ic) {
                *o = v * 2.0 + 1.0;
            }
        });
        let serial: Vec<f32> = input.iter().map(|&v| v * 2.0 + 1.0).collect();
        assert_eq!(par, serial);
    }

    #[test]
    fn par_chunks_small_input_runs_inline() {
        // Below MIN_PAR_CHUNK everything runs on the caller thread.
        let pool = ThreadPool::new(8);
        let input = vec![1.0f32; 100];
        let mut out = vec![0.0f32; 100];
        let calls = AtomicUsize::new(0);
        pool.par_chunks(&input, &mut out, |off, ic, oc| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!(off, 0);
            oc.copy_from_slice(ic);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        assert_eq!(out, input);
    }

    #[test]
    fn par_chunk_map_offsets_cover_input() {
        let pool = ThreadPool::new(4);
        let input: Vec<f64> = (0..80_000).map(|i| i as f64).collect();
        let partials = pool.par_chunk_map(&input, |off, chunk| {
            // each worker proves it got the right window
            assert_eq!(chunk[0], off as f64);
            chunk.iter().sum::<f64>()
        });
        let total: f64 = partials.iter().sum();
        assert_eq!(total, input.iter().sum::<f64>());
    }

    #[test]
    fn scope_map_returns_in_index_order() {
        let pool = ThreadPool::new(4);
        let out = pool.scope_map(37, |i| i * 3);
        assert_eq!(out, (0..37).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn par_row_blocks_cover_all_rows() {
        let pool = ThreadPool::new(3);
        let (rows, cols) = (10, 7);
        let mut buf = vec![0.0f64; rows * cols];
        pool.par_row_blocks(&mut buf, cols, |first_row, block| {
            for (r, row) in block.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v = (first_row + r) as f64;
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(buf[r * cols + c], r as f64);
            }
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let input: Vec<f32> = vec![1.0; 4 * MIN_PAR_CHUNK];
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.par_chunk_map(&input, |off, _chunk| {
                if off >= MIN_PAR_CHUNK {
                    panic!("worker bang");
                }
                0usize
            })
        }));
        assert!(caught.is_err(), "worker panic must propagate to the caller");
        // The pool is scoped, so a poisoned worker cannot wedge it.
        let ok = pool.par_chunk_map(&input, |_, chunk| chunk.len());
        assert_eq!(ok.iter().sum::<usize>(), input.len());
    }

    #[test]
    fn scope_spawns_borrowing_threads() {
        let pool = ThreadPool::new(2);
        let data = vec![1u64, 2, 3, 4];
        let sum = pool.scope(|s| {
            let h1 = s.spawn(|| data[..2].iter().sum::<u64>());
            let h2 = s.spawn(|| data[2..].iter().sum::<u64>());
            h1.join().unwrap() + h2.join().unwrap()
        });
        assert_eq!(sum, 10);
    }

    #[test]
    fn host_threads_positive() {
        assert!(host_threads() >= 1);
        assert!(global().size() >= 1);
    }

    #[test]
    fn width_cap_bounds_scoped_fanout() {
        let pool = ThreadPool::new(8);
        let input = vec![1.0f32; 8 * MIN_PAR_CHUNK];
        let uncapped = pool.par_chunk_map(&input, |_, c| c.len());
        assert!(uncapped.len() > 1, "uncapped pool should split the input");
        let capped = with_width_cap(1, || pool.par_chunk_map(&input, |_, c| c.len()));
        assert_eq!(capped.len(), 1, "cap 1 must run inline");
        assert_eq!(current_width_cap(), usize::MAX, "cap restored after scope");
        // caps nest via min: widening inside a narrow cap has no effect
        let nested = with_width_cap(2, || with_width_cap(8, || pool.width()));
        assert_eq!(nested, 2);
        // a capped fan-out still covers the whole input
        let total: usize =
            with_width_cap(2, || pool.par_chunk_map(&input, |_, c| c.len()))
                .iter()
                .sum();
        assert_eq!(total, input.len());
    }

    #[test]
    fn width_cap_restored_on_panic() {
        let caught =
            std::panic::catch_unwind(|| with_width_cap(1, || panic!("bang")));
        assert!(caught.is_err());
        assert_eq!(current_width_cap(), usize::MAX);
    }

    #[test]
    fn width_cap_is_thread_local() {
        with_width_cap(1, || {
            let other = std::thread::spawn(current_width_cap);
            assert_eq!(other.join().unwrap(), usize::MAX);
            assert_eq!(current_width_cap(), 1);
        });
    }

    #[test]
    fn pool_is_send_and_sync() {
        // Compile-time assertion: the pool is shared by reference across
        // scoped workers and stashed in lazily-initialised globals, both
        // of which silently stop compiling if an inner refactor (e.g. an
        // `Rc` or raw pointer in the queue) costs these auto-traits.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ThreadPool>();
        assert_send_sync::<&ThreadPool>();
    }
}
