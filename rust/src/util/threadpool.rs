//! Fixed-size worker pool (tokio/rayon are not offline-available).
//!
//! The coordinator uses it for host-side parallel work that doesn't touch
//! the (single) PJRT device stream: npy decoding, per-layer coding-length
//! computation, observer statistics. Scoped API: `scope` blocks until all
//! spawned closures finish, so borrows of the enclosing stack frame are
//! sound to move in via `'static` workarounds are unnecessary — we only
//! accept `'static` jobs and let callers move owned shards in, which keeps
//! the implementation small and the unsafe count at zero.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ar-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    /// Pool sized to the machine (cores, min 1).
    pub fn default_for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool alive");
    }

    /// Run `jobs` to completion, returning results in submission order.
    pub fn map<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.spawn(move || {
                let out = job();
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("worker result");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => job(),
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = pool.map(jobs);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_everything() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join on drop
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn zero_size_clamped() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }
}
