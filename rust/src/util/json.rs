//! Minimal JSON codec (RFC 8259 subset sufficient for the artifact
//! manifest and result files).
//!
//! Supports the full JSON value model; numbers are kept as f64 (the
//! manifest only carries shapes, counts and accuracies). Serialization is
//! deterministic (object keys keep insertion order via a Vec-backed map)
//! so result files diff cleanly between runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::parse(format!("missing key {key:?}"))),
            _ => Err(Error::parse(format!("not an object (looking up {key:?})"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::parse("expected string")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::parse("expected number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::parse(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::parse("expected bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::parse("expected array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::parse("expected object")),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_str().map(str::to_string))
            .collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::parse(format!("trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::parse(format!("reading {}: {e}", path.display())))?;
    parse(&text)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::parse("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            return Err(Error::parse(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char, self.pos, self.bytes[self.pos] as char
            )));
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::parse(format!(
                        "expected ',' or '}}', found {:?}",
                        c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(Error::parse(format!(
                        "expected ',' or ']', found {:?}",
                        c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| Error::parse("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::parse("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| Error::parse("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: manifests are ASCII; accept
                            // BMP and replace surrogates.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => {
                            return Err(Error::parse(format!(
                                "bad escape \\{:?}",
                                c as char
                            )))
                        }
                    }
                }
                // raw UTF-8 passthrough
                b => {
                    // Find the full UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .ok_or_else(|| Error::parse("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| Error::parse("invalid utf-8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::parse(format!("bad number {text:?} at byte {start}")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"resnet18t","layers":[{"wshape":[3,3,3,16],"acc":0.8994}],"ok":true}"#;
        let j = parse(src).unwrap();
        let back = parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
        let pretty = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(32.0).to_string_compact(), "32");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn accessor_errors() {
        let j = parse(r#"{"n": 1.5}"#).unwrap();
        assert!(j.get("n").unwrap().as_usize().is_err());
        assert!(j.get("missing").is_err());
        assert!(j.as_str().is_err());
    }
}
