//! Property-test driver substrate (the `proptest` crate is not
//! offline-available).
//!
//! `check` runs a property over N randomly generated cases; on failure it
//! performs greedy input shrinking via the caller-provided `shrink`
//! closure and reports the minimal failing case with its seed, so a CI
//! failure is reproducible by construction.

use crate::util::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0x5EED,
            max_shrink_iters: 200,
        }
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the minimal
/// failing input (via `shrink` candidates) on property violation.
pub fn check<T: std::fmt::Debug + Clone>(
    cfg: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut cur = input;
            let mut cur_msg = msg;
            let mut iters = 0;
            'outer: while iters < cfg.max_shrink_iters {
                for cand in shrink(&cur) {
                    iters += 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={:#x}, case {}): {}\nminimal input: {:?}",
                cfg.seed, case, cur_msg, cur
            );
        }
    }
}

/// Common shrinker: halve a vector (front half, back half, drop one elem).
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        out.push(v[1..].to_vec());
        out.push(v[..v.len() - 1].to_vec());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config::default(),
            |r| r.below(100) as i64,
            |_| vec![],
            |x| {
                if *x >= 0 {
                    Ok(())
                } else {
                    Err("negative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 50, ..Default::default() },
            |r| r.below(100) as i64,
            |x| if *x > 0 { vec![x / 2] } else { vec![] },
            |x| {
                if *x < 90 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn shrink_vec_candidates() {
        let cands = shrink_vec(&[1, 2, 3, 4]);
        assert!(cands.contains(&vec![1, 2]));
        assert!(cands.contains(&vec![2, 3, 4]));
    }
}
