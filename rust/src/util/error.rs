//! Crate-wide error type.
//!
//! One enum covering every failure domain (I/O, parsing, runtime/PJRT,
//! shape mismatches, config errors) so the coordinator's pipeline code can
//! use `?` throughout and still report precise causes at the CLI boundary.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    /// Malformed JSON, npy, or manifest content.
    Parse(String),
    /// PJRT / XLA failures (compile, execute, transfer).
    Runtime(String),
    /// Tensor shape or argument-arity mismatches.
    Shape(String),
    /// Bad user configuration or CLI usage.
    Config(String),
    /// An experiment-level invariant was violated.
    Invariant(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Invariant(m) => write!(f, "invariant violated: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Shorthand constructors used across the crate.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn runtime(m: impl Into<String>) -> Self {
        Error::Runtime(m.into())
    }
    pub fn shape(m: impl Into<String>) -> Self {
        Error::Shape(m.into())
    }
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn invariant(m: impl Into<String>) -> Self {
        Error::Invariant(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        assert!(Error::parse("x").to_string().contains("parse"));
        assert!(Error::runtime("x").to_string().contains("runtime"));
        assert!(Error::shape("x").to_string().contains("shape"));
        assert!(Error::config("x").to_string().contains("config"));
    }

    #[test]
    fn io_conversion() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
