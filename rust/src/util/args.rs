//! CLI argument parser substrate (clap is not offline-available).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated usage text — exactly what the `repro` binary
//! and the bench harness need.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

pub struct Parser {
    pub program: &'static str,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

impl Parser {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Parser {
            program,
            about,
            specs: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>,
               help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for spec in &self.specs {
            let d = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse argv (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        // seed defaults
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.options.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| Error::config(format!("unknown option --{name}\n\n{}", self.usage())))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(Error::config(format!("--{name} takes no value")));
                    }
                    out.flags.push(name);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| Error::config(format!("--{name} needs a value")))?
                        }
                    };
                    out.options.insert(name, value);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Result<&str> {
        self.options
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::config(format!("missing --{name}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)?
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)?
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be a number")))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new("test", "a test")
            .opt("iters", Some("200"), "iterations")
            .opt("model", None, "model name")
            .flag("verbose", "chatty")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parser().parse(&argv(&["--model", "resnet18t"])).unwrap();
        assert_eq!(a.get_usize("iters").unwrap(), 200);
        assert_eq!(a.get("model").unwrap(), "resnet18t");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = parser()
            .parse(&argv(&["--iters=500", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("iters").unwrap(), 500);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn rejects_unknown() {
        assert!(parser().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parser().parse(&argv(&["--model"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parser().parse(&argv(&["--verbose=yes"])).is_err());
    }
}
