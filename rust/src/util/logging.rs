//! Tiny structured logger bridging the `log` facade to stderr.
//!
//! Timestamps are monotonic seconds since process start (wall-clock isn't
//! interesting for a batch pipeline; relative timings are). Level comes
//! from `REPRO_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// The accepted `REPRO_LOG` values, most to least severe.
pub const ACCEPTED_LEVELS: &[&str] = &["error", "warn", "info", "debug", "trace"];

/// Map a `REPRO_LOG` value to a filter; `None` for anything not in
/// [`ACCEPTED_LEVELS`] (a typo like `inf` must not silently demote to
/// the default — the caller warns).
fn parse_level(raw: &str) -> Option<LevelFilter> {
    match raw {
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent).
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
    });
    let raw = std::env::var("REPRO_LOG").ok();
    let (level, bad_value) = match raw.as_deref() {
        None => (LevelFilter::Info, None),
        Some(v) => match parse_level(v) {
            Some(l) => (l, None),
            None => (LevelFilter::Info, Some(v.to_string())),
        },
    };
    // set_logger fails if called twice; that's fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
    if let Some(bad) = bad_value {
        // after set_max_level so the warning clears the (info) filter
        log::warn!(
            "unrecognized REPRO_LOG value {bad:?}; using \"info\" \
             (accepted: {})",
            ACCEPTED_LEVELS.join("|")
        );
    }
}

#[cfg(test)]
mod tests {
    use log::LevelFilter;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn level_parsing_accepts_exactly_the_documented_set() {
        for (raw, want) in [
            ("error", LevelFilter::Error),
            ("warn", LevelFilter::Warn),
            ("info", LevelFilter::Info),
            ("debug", LevelFilter::Debug),
            ("trace", LevelFilter::Trace),
        ] {
            assert_eq!(super::parse_level(raw), Some(want));
        }
        assert_eq!(super::ACCEPTED_LEVELS.len(), 5);
    }

    #[test]
    fn unrecognized_level_is_flagged_not_swallowed() {
        // the REPRO_LOG=inf bug: a typo'd value must parse to None (so
        // init warns) instead of silently matching the default arm
        for bad in ["inf", "INFO", "warning", "3", ""] {
            assert_eq!(super::parse_level(bad), None, "{bad:?}");
        }
    }
}
