//! Minimal SVG chart emitter — renders Figure 2 (line series) and
//! Figures 3-5 (per-layer bar charts) as standalone .svg files alongside
//! the markdown/CSV reports.

use std::fmt::Write as _;

const W: f64 = 860.0;
const H: f64 = 420.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 20.0;
const MT: f64 = 40.0;
const MB: f64 = 110.0;

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn header(title: &str) -> String {
    format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}">
<style>text{{font-family:monospace;font-size:12px}}.t{{font-size:15px;font-weight:bold}}</style>
<rect width="{W}" height="{H}" fill="white"/>
<text class="t" x="{}" y="24" text-anchor="middle">{}</text>
"#,
        W / 2.0,
        esc(title)
    )
}

/// Vertical bar chart (Figures 3-5: per-layer bit widths).
pub fn bar_chart_svg(title: &str, labels: &[String], values: &[f64]) -> String {
    assert_eq!(labels.len(), values.len());
    let n = values.len().max(1);
    let vmax = values.iter().cloned().fold(1e-12, f64::max);
    let plot_w = W - ML - MR;
    let plot_h = H - MT - MB;
    let bw = (plot_w / n as f64) * 0.8;
    let mut s = header(title);
    // y axis grid
    for i in 0..=4 {
        let v = vmax * i as f64 / 4.0;
        let y = MT + plot_h * (1.0 - i as f64 / 4.0);
        let _ = writeln!(
            s,
            r##"<line x1="{ML}" y1="{y:.1}" x2="{:.1}" y2="{y:.1}" stroke="#ddd"/><text x="{:.1}" y="{:.1}" text-anchor="end">{v:.1}</text>"##,
            W - MR,
            ML - 6.0,
            y + 4.0
        );
    }
    for (i, (&v, label)) in values.iter().zip(labels).enumerate() {
        let x = ML + plot_w * (i as f64 + 0.1) / n as f64;
        let h = plot_h * v / vmax;
        let y = MT + plot_h - h;
        let _ = writeln!(
            s,
            r##"<rect x="{x:.1}" y="{y:.1}" width="{bw:.1}" height="{h:.1}" fill="#4878cf"/>"##
        );
        let lx = x + bw / 2.0;
        let ly = MT + plot_h + 8.0;
        let _ = writeln!(
            s,
            r#"<text x="{lx:.1}" y="{ly:.1}" transform="rotate(60 {lx:.1} {ly:.1})" text-anchor="start">{}</text>"#,
            esc(label)
        );
    }
    s.push_str("</svg>\n");
    s
}

/// Multi-series line chart (Figure 2: τ sweeps).
pub fn line_chart_svg(
    title: &str,
    xs: &[f64],
    series: &[(String, Vec<f64>)],
) -> String {
    let plot_w = W - ML - MR;
    let plot_h = H - MT - MB;
    let xmin = xs.iter().cloned().fold(f64::MAX, f64::min);
    let xmax = xs.iter().cloned().fold(f64::MIN, f64::max).max(xmin + 1e-9);
    let ymin = series
        .iter()
        .flat_map(|(_, v)| v.iter().cloned())
        .fold(f64::MAX, f64::min);
    let ymax = series
        .iter()
        .flat_map(|(_, v)| v.iter().cloned())
        .fold(f64::MIN, f64::max)
        .max(ymin + 1e-9);
    // pad the y range 10% so flat curves stay visible
    let pad = (ymax - ymin) * 0.1 + 1e-9;
    let (ymin, ymax) = (ymin - pad, ymax + pad);
    let colors = ["#4878cf", "#d65f5f", "#59a14f", "#b07aa1", "#e49444"];

    let px = |x: f64| ML + plot_w * (x - xmin) / (xmax - xmin);
    let py = |y: f64| MT + plot_h * (1.0 - (y - ymin) / (ymax - ymin));

    let mut s = header(title);
    for i in 0..=4 {
        let y = ymin + (ymax - ymin) * i as f64 / 4.0;
        let _ = writeln!(
            s,
            r##"<line x1="{ML}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="#ddd"/><text x="{:.1}" y="{:.1}" text-anchor="end">{y:.1}</text>"##,
            py(y),
            W - MR,
            py(y),
            ML - 6.0,
            py(y) + 4.0
        );
    }
    for &x in xs {
        let _ = writeln!(
            s,
            r#"<text x="{:.1}" y="{:.1}" text-anchor="middle">{x:.2}</text>"#,
            px(x),
            MT + plot_h + 18.0
        );
    }
    for (si, (name, ys)) in series.iter().enumerate() {
        let color = colors[si % colors.len()];
        let pts: Vec<String> = xs
            .iter()
            .zip(ys)
            .map(|(&x, &y)| format!("{:.1},{:.1}", px(x), py(y)))
            .collect();
        let _ = writeln!(
            s,
            r#"<polyline points="{}" fill="none" stroke="{color}" stroke-width="2"/>"#,
            pts.join(" ")
        );
        for (&x, &y) in xs.iter().zip(ys) {
            let _ = writeln!(
                s,
                r#"<circle cx="{:.1}" cy="{:.1}" r="3" fill="{color}"/>"#,
                px(x),
                py(y)
            );
        }
        let ly = MT + plot_h + 40.0 + 16.0 * si as f64;
        let _ = writeln!(
            s,
            r#"<rect x="{ML}" y="{:.1}" width="12" height="12" fill="{color}"/><text x="{:.1}" y="{:.1}">{}</text>"#,
            ly - 10.0,
            ML + 18.0,
            ly,
            esc(name)
        );
    }
    s.push_str("</svg>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_is_valid_svgish() {
        let svg = bar_chart_svg(
            "bits",
            &["a".into(), "b<c".into()],
            &[3.0, 8.0],
        );
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<rect").count(), 3); // bg + 2 bars
        assert!(svg.contains("b&lt;c")); // escaping
    }

    #[test]
    fn line_chart_has_all_series() {
        let svg = line_chart_svg(
            "τ sweep",
            &[0.0, 0.5, 1.0],
            &[
                ("W/32".into(), vec![90.0, 91.0, 90.5]),
                ("W/A".into(), vec![88.0, 89.0, 88.5]),
            ],
        );
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert_eq!(svg.matches("<circle").count(), 6);
    }

    #[test]
    fn flat_series_does_not_divide_by_zero() {
        let svg = line_chart_svg("flat", &[0.0, 1.0], &[("s".into(), vec![5.0, 5.0])]);
        assert!(!svg.contains("NaN"));
    }
}
