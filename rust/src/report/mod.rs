//! Result rendering: markdown tables (the paper's Tables 1-5), ASCII bar
//! charts (Figures 2-5), and CSV export for downstream plotting.

pub mod svg;

use std::fmt::Write as _;

/// A simple column-aligned markdown table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows (tests assert on harness output shape).
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}\n", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Horizontal ASCII bar chart — renders the per-layer bit allocations of
/// Figures 3-5 and the τ-sweep of Figure 2 in the terminal.
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], max_width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let vmax = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("### {title}\n\n");
    for (l, &v) in labels.iter().zip(values) {
        let w = ((v / vmax) * max_width as f64).round().max(0.0) as usize;
        let _ = writeln!(out, "{l:<lw$} | {:<max_width$} {v:.3}", "#".repeat(w));
    }
    out
}

/// Format an accuracy fraction the way the paper prints it (percent, 2dp).
pub fn pct(acc: f64) -> String {
    format!("{:.2}", acc * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["Method", "Acc"]);
        t.row(vec!["Ours".into(), "70.72".into()]);
        t.row(vec!["AdaRound".into(), "68.71".into()]);
        let s = t.render();
        assert!(s.contains("### T"));
        assert!(s.contains("| Ours     | 70.72 |"));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        Table::new("", &["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn chart_scales_to_max() {
        let s = bar_chart(
            "c",
            &["l1".into(), "l2".into()],
            &[1.0, 2.0],
            10,
        );
        assert!(s.contains("##########"));
        assert!(s.contains("#####"));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.7072), "70.72");
        assert_eq!(pct(1.0), "100.00");
    }
}
