//! MSE-optimal scale search (paper §4.1: "the optimal quantification
//! interval s was determined by minimization of ‖W − Ŵ‖² under
//! round-to-nearest").
//!
//! Golden-section-free grid refinement: sweep a coarse grid of candidate
//! scales around max|w| / hi, then refine twice around the winner. The
//! MSE(s) landscape is piecewise-smooth with many local minima, so a
//! sweep beats gradient methods and is trivially robust.
//!
//! The sweep is executed by the fused `quant::kernel::quant_sse_multi`
//! kernel: every refinement round reads the tensor **once** and evaluates
//! all 25 candidates per element (the scalar form reads it 25 times),
//! chunked across the thread pool. Candidate enumeration, tie-breaking,
//! and refinement updates are kept verbatim from the scalar reference
//! ([`mse_optimal_scale_scalar`]), so both searches walk the same
//! candidate sequence; with a sequential pool the selected scale is
//! bit-identical (see tests/kernel_properties.rs).

use super::{kernel, QGrid};
use crate::tensor::ops;
use crate::util::error::Result;
use crate::util::threadpool::{self, ThreadPool};

/// MSE between w and nearest-round(w) on a signed grid with scale s.
///
/// A degenerate grid (`bits` outside 2..=16, or a non-finite / non-positive
/// scale) scores `f64::INFINITY`: it can never win a scale search, which is
/// exactly the semantics every caller of this cost function wants.
pub fn quant_mse(w: &[f32], bits: u8, s: f32) -> f64 {
    let g = match QGrid::signed(bits, s) {
        Ok(g) => g,
        Err(_) => return f64::INFINITY,
    };
    let mut acc = 0.0f64;
    for &v in w {
        let d = (v - g.nearest(v)) as f64;
        acc += d * d;
    }
    acc / w.len() as f64
}

/// Find the MSE-optimal per-tensor scale for `bits`-bit signed weights
/// on the shared host pool.
pub fn mse_optimal_scale(w: &[f32], bits: u8) -> Result<f32> {
    mse_optimal_scale_with(threadpool::global(), w, bits)
}

/// Pool-explicit fused search: 3 refinement rounds, one tensor pass per
/// round evaluating all 25 candidate scales at once.
pub fn mse_optimal_scale_with(pool: &ThreadPool, w: &[f32], bits: u8) -> Result<f32> {
    let amax = ops::abs_max(w).max(1e-8);
    let half = (1i64 << (bits - 1)) as f32;
    // candidate range: [amax/half * 0.3, amax/half * 1.2]
    let base = amax / half;
    let mut lo = base * 0.3;
    let mut hi = base * 1.2;
    let mut best_s = base;
    let mut best_e = f64::INFINITY;
    let mut cands = [0.0f32; kernel::MAX_SCALES];
    let mut sse = [0.0f64; kernel::MAX_SCALES];
    for _round in 0..3 {
        let steps = 24;
        let mut nc = 0usize;
        for i in 0..=steps {
            let s = lo + (hi - lo) * i as f32 / steps as f32;
            if s <= 0.0 {
                continue;
            }
            cands[nc] = s;
            nc += 1;
        }
        kernel::quant_sse_multi(pool, w, bits, &cands[..nc], &mut sse[..nc]);
        for j in 0..nc {
            let e = sse[j] / w.len() as f64;
            if e < best_e {
                best_e = e;
                best_s = cands[j];
            }
        }
        let width = (hi - lo) / steps as f32;
        lo = (best_s - width).max(base * 0.05);
        hi = best_s + width;
    }
    Ok(best_s)
}

/// The scalar reference search: one full tensor sweep per candidate.
/// Kept as the semantic baseline for the fused kernel's property tests
/// and the before/after hotpath benches.
pub fn mse_optimal_scale_scalar(w: &[f32], bits: u8) -> Result<f32> {
    let amax = ops::abs_max(w).max(1e-8);
    let half = (1i64 << (bits - 1)) as f32;
    let base = amax / half;
    let mut lo = base * 0.3;
    let mut hi = base * 1.2;
    let mut best_s = base;
    let mut best_e = f64::INFINITY;
    for _round in 0..3 {
        let steps = 24;
        for i in 0..=steps {
            let s = lo + (hi - lo) * i as f32 / steps as f32;
            if s <= 0.0 {
                continue;
            }
            let e = quant_mse(w, bits, s);
            if e < best_e {
                best_e = e;
                best_s = s;
            }
        }
        let width = (hi - lo) / steps as f32;
        lo = (best_s - width).max(base * 0.05);
        hi = best_s + width;
    }
    Ok(best_s)
}

/// Simple max-abs scale (the fallback / ablation reference).
pub fn absmax_scale(w: &[f32], bits: u8) -> f32 {
    let half = (1i64 << (bits - 1)) as f32;
    ops::abs_max(w).max(1e-8) / (half - 1.0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gaussian_f32(0.0, 0.05)).collect()
    }

    #[test]
    fn mse_scale_beats_absmax() {
        let w = gaussian_weights(4096, 1);
        for bits in [3u8, 4, 8] {
            let s_opt = mse_optimal_scale(&w, bits).unwrap();
            let s_max = absmax_scale(&w, bits);
            let e_opt = quant_mse(&w, bits, s_opt);
            let e_max = quant_mse(&w, bits, s_max);
            assert!(
                e_opt <= e_max * 1.0001,
                "bits={bits}: opt {e_opt} > absmax {e_max}"
            );
        }
    }

    #[test]
    fn scale_positive_and_finite() {
        let w = gaussian_weights(512, 2);
        let s = mse_optimal_scale(&w, 4).unwrap();
        assert!(s.is_finite() && s > 0.0);
        // degenerate all-zero weights still give a usable scale
        let z = vec![0.0f32; 64];
        let s0 = mse_optimal_scale(&z, 4).unwrap();
        assert!(s0.is_finite() && s0 > 0.0);
    }

    #[test]
    fn more_bits_lower_error() {
        let w = gaussian_weights(2048, 3);
        let e3 = quant_mse(&w, 3, mse_optimal_scale(&w, 3).unwrap());
        let e4 = quant_mse(&w, 4, mse_optimal_scale(&w, 4).unwrap());
        let e8 = quant_mse(&w, 8, mse_optimal_scale(&w, 8).unwrap());
        assert!(e3 > e4 && e4 > e8, "e3={e3} e4={e4} e8={e8}");
    }

    #[test]
    fn fused_search_matches_scalar_search_sequentially() {
        // With one chunk the fused kernel accumulates in the scalar
        // element order: the selected scale is bit-identical.
        let pool = crate::util::threadpool::ThreadPool::seq();
        for seed in [5u64, 6, 7] {
            let w = gaussian_weights(3000, seed);
            for bits in [3u8, 4, 8] {
                let fused = mse_optimal_scale_with(&pool, &w, bits).unwrap();
                let scalar = mse_optimal_scale_scalar(&w, bits).unwrap();
                assert_eq!(fused, scalar, "seed={seed} bits={bits}");
            }
        }
    }

    #[test]
    fn fused_search_parallel_quality_matches_scalar() {
        // Across chunks the f64 merge order differs; the selected scale
        // must be quality-equivalent to reassociation noise.
        let pool = crate::util::threadpool::ThreadPool::new(4);
        let w = gaussian_weights(100_000, 9);
        for bits in [3u8, 4] {
            let fused = mse_optimal_scale_with(&pool, &w, bits).unwrap();
            let scalar = mse_optimal_scale_scalar(&w, bits).unwrap();
            let e_f = quant_mse(&w, bits, fused);
            let e_s = quant_mse(&w, bits, scalar);
            assert!(
                e_f <= e_s * (1.0 + 1e-9) && e_s <= e_f * (1.0 + 1e-9),
                "bits={bits}: fused mse {e_f} vs scalar {e_s}"
            );
        }
    }
}
