//! MSE-optimal scale search (paper §4.1: "the optimal quantification
//! interval s was determined by minimization of ‖W − Ŵ‖² under
//! round-to-nearest").
//!
//! Golden-section-free grid refinement: sweep a coarse grid of candidate
//! scales around max|w| / hi, then refine twice around the winner. The
//! MSE(s) landscape is piecewise-smooth with many local minima, so a
//! sweep beats gradient methods and is trivially robust.

use super::QGrid;
use crate::tensor::ops;
use crate::util::error::Result;

/// MSE between w and nearest-round(w) on a signed grid with scale s.
fn quant_mse(w: &[f32], bits: u8, s: f32) -> f64 {
    let g = QGrid::signed(bits, s).expect("valid grid");
    let mut acc = 0.0f64;
    for &v in w {
        let d = (v - g.nearest(v)) as f64;
        acc += d * d;
    }
    acc / w.len() as f64
}

/// Find the MSE-optimal per-tensor scale for `bits`-bit signed weights.
pub fn mse_optimal_scale(w: &[f32], bits: u8) -> Result<f32> {
    let amax = ops::abs_max(w).max(1e-8);
    let half = (1i64 << (bits - 1)) as f32;
    // candidate range: [amax/half * 0.3, amax/half * 1.2]
    let base = amax / half;
    let mut lo = base * 0.3;
    let mut hi = base * 1.2;
    let mut best_s = base;
    let mut best_e = f64::INFINITY;
    for _round in 0..3 {
        let steps = 24;
        for i in 0..=steps {
            let s = lo + (hi - lo) * i as f32 / steps as f32;
            if s <= 0.0 {
                continue;
            }
            let e = quant_mse(w, bits, s);
            if e < best_e {
                best_e = e;
                best_s = s;
            }
        }
        let width = (hi - lo) / steps as f32;
        lo = (best_s - width).max(base * 0.05);
        hi = best_s + width;
    }
    Ok(best_s)
}

/// Simple max-abs scale (the fallback / ablation reference).
pub fn absmax_scale(w: &[f32], bits: u8) -> f32 {
    let half = (1i64 << (bits - 1)) as f32;
    ops::abs_max(w).max(1e-8) / (half - 1.0).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gaussian_f32(0.0, 0.05)).collect()
    }

    #[test]
    fn mse_scale_beats_absmax() {
        let w = gaussian_weights(4096, 1);
        for bits in [3u8, 4, 8] {
            let s_opt = mse_optimal_scale(&w, bits).unwrap();
            let s_max = absmax_scale(&w, bits);
            let e_opt = quant_mse(&w, bits, s_opt);
            let e_max = quant_mse(&w, bits, s_max);
            assert!(
                e_opt <= e_max * 1.0001,
                "bits={bits}: opt {e_opt} > absmax {e_max}"
            );
        }
    }

    #[test]
    fn scale_positive_and_finite() {
        let w = gaussian_weights(512, 2);
        let s = mse_optimal_scale(&w, 4).unwrap();
        assert!(s.is_finite() && s > 0.0);
        // degenerate all-zero weights still give a usable scale
        let z = vec![0.0f32; 64];
        let s0 = mse_optimal_scale(&z, 4).unwrap();
        assert!(s0.is_finite() && s0 > 0.0);
    }

    #[test]
    fn more_bits_lower_error() {
        let w = gaussian_weights(2048, 3);
        let e3 = quant_mse(&w, 3, mse_optimal_scale(&w, 3).unwrap());
        let e4 = quant_mse(&w, 4, mse_optimal_scale(&w, 4).unwrap());
        let e8 = quant_mse(&w, 8, mse_optimal_scale(&w, 8).unwrap());
        assert!(e3 > e4 && e4 > e8, "e3={e3} e4={e4} e8={e8}");
    }
}
