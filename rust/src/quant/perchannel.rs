//! Per-output-channel weight quantization — the standard extension of the
//! paper's per-tensor grids (§4.1 uses per-tensor; this module powers the
//! ablation bench comparing the two).
//!
//! Weights are laid out (..., out_ch) row-major everywhere in this repo,
//! so channel c's elements are the strided slice data[c], data[c + C],
//! data[c + 2C], ... — one pass computes all channel scales.

use crate::quant::rounding;
use crate::quant::scale::mse_optimal_scale;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-channel grids for a (..., out_ch) weight tensor.
#[derive(Debug, Clone)]
pub struct PerChannelGrids {
    pub grids: Vec<QGrid>,
    pub out_ch: usize,
}

/// Gather channel c's elements into a contiguous buffer.
fn channel_elems(w: &Tensor, c: usize, out_ch: usize) -> Vec<f32> {
    w.data()[c..].iter().step_by(out_ch).copied().collect()
}

/// MSE-optimal per-channel scales.
pub fn per_channel_scales(w: &Tensor, bits: u8) -> Result<PerChannelGrids> {
    let out_ch = *w
        .shape()
        .last()
        .ok_or_else(|| Error::shape("scalar weight tensor"))?;
    let mut grids = Vec::with_capacity(out_ch);
    for c in 0..out_ch {
        let elems = channel_elems(w, c, out_ch);
        grids.push(QGrid::signed(bits, mse_optimal_scale(&elems, bits)?)?);
    }
    Ok(PerChannelGrids { grids, out_ch })
}

/// Nearest-round with per-channel grids.
pub fn nearest_per_channel(w: &Tensor, g: &PerChannelGrids) -> Result<Tensor> {
    if w.shape().last() != Some(&g.out_ch) {
        return Err(Error::shape(format!(
            "weight {:?} does not end in {} channels",
            w.shape(),
            g.out_ch
        )));
    }
    let mut out = vec![0.0f32; w.len()];
    for (i, &v) in w.data().iter().enumerate() {
        out[i] = g.grids[i % g.out_ch].nearest(v);
    }
    Tensor::new(w.shape().to_vec(), out)
}

/// Quantization MSE of per-tensor vs per-channel nearest rounding —
/// returns (per_tensor_mse, per_channel_mse). Per-channel can never be
/// worse when scales are per-channel MSE-optimal.
pub fn compare_mse(w: &Tensor, bits: u8) -> Result<(f64, f64)> {
    let gt = QGrid::signed(bits, mse_optimal_scale(w.data(), bits)?)?;
    let qt = rounding::nearest(w.data(), &gt);
    let et = crate::tensor::ops::mse(w.data(), &qt);
    let gc = per_channel_scales(w, bits)?;
    let qc = nearest_per_channel(w, &gc)?;
    let ec = crate::tensor::ops::mse(w.data(), qc.data());
    Ok((et, ec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Weight tensor whose channels have very different magnitudes —
    /// the case per-channel quantization exists for.
    fn heterogeneous_weights(out_ch: usize, rows: usize) -> Tensor {
        let mut rng = Rng::new(3);
        let mut data = vec![0.0f32; rows * out_ch];
        for r in 0..rows {
            for c in 0..out_ch {
                let std = 0.01 * (1.0 + 10.0 * c as f32);
                data[r * out_ch + c] = rng.gaussian_f32(0.0, std);
            }
        }
        Tensor::new(vec![rows, out_ch], data).unwrap()
    }

    #[test]
    fn channel_gather_is_strided() {
        let w = Tensor::new(vec![2, 3], vec![0., 1., 2., 10., 11., 12.]).unwrap();
        assert_eq!(channel_elems(&w, 1, 3), vec![1.0, 11.0]);
    }

    #[test]
    fn per_channel_beats_per_tensor_on_heterogeneous_channels() {
        let w = heterogeneous_weights(8, 64);
        let (et, ec) = compare_mse(&w, 4).unwrap();
        assert!(
            ec < et * 0.5,
            "per-channel {ec} should be well below per-tensor {et}"
        );
    }

    #[test]
    fn per_channel_outputs_on_their_grids() {
        let w = heterogeneous_weights(4, 16);
        let g = per_channel_scales(&w, 3).unwrap();
        let q = nearest_per_channel(&w, &g).unwrap();
        for (i, &v) in q.data().iter().enumerate() {
            assert!(g.grids[i % 4].contains(v), "{v} off channel grid");
        }
    }

    #[test]
    fn homogeneous_channels_roughly_tie() {
        let mut rng = Rng::new(5);
        let mut data = vec![0.0f32; 512];
        rng.fill_gaussian(&mut data, 0.0, 0.1);
        let w = Tensor::new(vec![64, 8], data).unwrap();
        let (et, ec) = compare_mse(&w, 4).unwrap();
        assert!(ec <= et * 1.05, "per-channel {ec} vs per-tensor {et}");
    }
}
