//! Quantizer math (host side).
//!
//! Everything the paper's §1/§3 defines that doesn't need a gradient:
//! uniform grids, MSE-optimal scale search (§4.1), the static rounding
//! baselines (Nearest / Floor / Ceil / Stochastic), the Attention-Round
//! probability model of Eq. (2), and activation observers for Table 2/3/5.

pub mod kernel;
pub mod observer;
pub mod perchannel;
pub mod rounding;
pub mod scale;

use crate::util::error::{Error, Result};

/// A signed symmetric uniform quantization grid: values s·q for integer
/// q ∈ [lo, hi]. The paper uses per-tensor symmetric weights with the
/// first/last layers pinned to 8-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QGrid {
    pub scale: f32,
    pub lo: f32,
    pub hi: f32,
    pub bits: u8,
}

impl QGrid {
    /// Signed grid for `bits`: q ∈ [−2^{b−1}, 2^{b−1}−1].
    pub fn signed(bits: u8, scale: f32) -> Result<Self> {
        if !(2..=16).contains(&bits) {
            return Err(Error::config(format!("bits {bits} out of range 2..=16")));
        }
        if !(scale.is_finite() && scale > 0.0) {
            return Err(Error::config(format!("scale {scale} must be positive")));
        }
        let half = 1i64 << (bits - 1);
        Ok(QGrid {
            scale,
            lo: -(half as f32),
            hi: (half - 1) as f32,
            bits,
        })
    }

    /// Unsigned grid (activations after ReLU): q ∈ [0, 2^b − 1].
    pub fn unsigned(bits: u8, scale: f32) -> Result<Self> {
        if !(2..=16).contains(&bits) {
            return Err(Error::config(format!("bits {bits} out of range 2..=16")));
        }
        if !(scale.is_finite() && scale > 0.0) {
            return Err(Error::config(format!("scale {scale} must be positive")));
        }
        Ok(QGrid {
            scale,
            lo: 0.0,
            hi: ((1i64 << bits) - 1) as f32,
            bits,
        })
    }

    /// Number of representable values.
    pub fn levels(&self) -> usize {
        (self.hi - self.lo) as usize + 1
    }

    /// Quantize-dequantize one value with round-to-nearest-even (matching
    /// jnp.round across the stack).
    #[inline]
    pub fn nearest(&self, w: f32) -> f32 {
        self.scale * round_half_even(w / self.scale).clamp(self.lo, self.hi)
    }

    /// Is v exactly representable on this grid?
    pub fn contains(&self, v: f32) -> bool {
        let q = v / self.scale;
        let r = round_half_even(q);
        (q - r).abs() < 1e-4 && (self.lo..=self.hi).contains(&r)
    }
}

/// Round half to even, matching `jnp.round` / IEEE roundTiesToEven so the
/// host-side finalization agrees bit-for-bit with the device executables.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbor
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// Attention-Round probability model (paper Eq. 2): the probability that
/// weight w maps to grid point q_k under perturbation α ~ N(0, τ²), i.e.
/// the Gaussian mass of the rounding cell around q_k.
pub fn attention_probability(w: f32, qk: f32, step: f32, tau: f32) -> f64 {
    if tau <= 0.0 {
        // degenerate: nearest-round indicator
        return if (w - qk).abs() <= step / 2.0 { 1.0 } else { 0.0 };
    }
    let lo = (qk - step / 2.0 - w) as f64 / (tau as f64 * std::f64::consts::SQRT_2);
    let hi = (qk + step / 2.0 - w) as f64 / (tau as f64 * std::f64::consts::SQRT_2);
    0.5 * (erf(hi) - erf(lo))
}

/// Abramowitz–Stegun 7.1.26 erf approximation (|err| < 1.5e-7) — plenty
/// for the probability model and its tests.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_ranges() {
        let g = QGrid::signed(4, 0.1).unwrap();
        assert_eq!((g.lo, g.hi), (-8.0, 7.0));
        assert_eq!(g.levels(), 16);
        let u = QGrid::unsigned(4, 0.1).unwrap();
        assert_eq!((u.lo, u.hi), (0.0, 15.0));
        assert!(QGrid::signed(1, 0.1).is_err());
        assert!(QGrid::signed(4, 0.0).is_err());
        assert!(QGrid::signed(4, f32::NAN).is_err());
    }

    #[test]
    fn nearest_clips() {
        let g = QGrid::signed(4, 0.5).unwrap();
        assert_eq!(g.nearest(0.74), 0.5); // 1.48 -> 1
        assert_eq!(g.nearest(100.0), 3.5); // clipped to hi=7
        assert_eq!(g.nearest(-100.0), -4.0); // clipped to lo=-8
    }

    #[test]
    fn half_even_matches_jnp() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), -0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(-1.6), -2.0);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn attention_probability_sums_to_one() {
        // probabilities over a wide grid should sum to ~1
        let (w, step, tau) = (0.13f32, 0.1f32, 0.25f32);
        let mut total = 0.0;
        for k in -50..=50 {
            total += attention_probability(w, k as f32 * step, step, tau);
        }
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
    }

    #[test]
    fn attention_probability_peaks_at_nearest() {
        let (w, step, tau) = (0.13f32, 0.1f32, 0.05f32);
        let p_near = attention_probability(w, 0.1, step, tau);
        let p_far = attention_probability(w, 0.3, step, tau);
        assert!(p_near > p_far);
        // tau -> 0 degenerates to nearest-round
        assert_eq!(attention_probability(w, 0.1, step, 0.0), 1.0);
        assert_eq!(attention_probability(w, 0.2, step, 0.0), 0.0);
    }
}
