//! Fused, zero-allocation, multithreaded host kernels.
//!
//! The scalar quantizer entry points (`rounding::nearest`, `QGrid::
//! nearest`, `scale::quant_mse`) are the semantic reference; everything
//! here is a performance re-expression with **identical outputs**:
//!
//! * [`round_half_even_fast`] / [`floor_fast`] / [`ceil_fast`] replace
//!   the branchy scalar rounding with straight-line float arithmetic
//!   (the classic `(x + 1.5·2²³) − 1.5·2²³` trick, which rounds at
//!   integer precision with ties-to-even because that is exactly what
//!   f32 addition does at that magnitude). Branch-free means LLVM
//!   auto-vectorizes the kernels, which is where most of the
//!   single-thread win comes from. Composed with the grid clamp these
//!   are bit-identical to the scalar forms for every input (the trick
//!   is exact for |x| ≤ 2²², and any quotient beyond that clamps to the
//!   same grid edge either way; the lone difference is that an exact
//!   `-0.0` comes back as `+0.0`, numerically equal).
//! * [`quant_sse_multi`] fuses the MSE scale search: one pass over the
//!   tensor evaluates every candidate scale (≤ [`MAX_SCALES`]), so a
//!   25-candidate refinement round reads the 147k-element tensor once
//!   instead of 25 times, chunked across the pool with per-thread f64
//!   accumulators merged in deterministic chunk order.
//!
//! On precomputed reciprocals: multiplying by `1/s` instead of dividing
//! by `s` changes the quotient by an ulp, which flips rounding decisions
//! for weights sitting on a rounding-cell boundary — the outputs would
//! no longer be bit-identical to the scalar reference or to the device
//! executables (which also divide). We deliberately keep IEEE division;
//! the fusion + vectorization + chunking above deliver the speedup
//! without giving up exactness, and `vdivps` pipelines well enough that
//! division is not the bottleneck in the vectorized loop.

use crate::util::threadpool::{ThreadPool, MIN_PAR_CHUNK};

/// Upper bound on the candidate-scale count a fused sweep can evaluate
/// (the search uses 25 per refinement round).
pub const MAX_SCALES: usize = 32;

/// 1.5 · 2²³ — adding then subtracting this constant rounds an f32 to
/// integer precision with IEEE ties-to-even.
const MAGIC: f32 = 12_582_912.0;

/// Branch-free round-half-to-even. Exact for |x| ≤ 2²²; beyond that the
/// result may differ from true rounding by the local ulp, which the grid
/// clamp (|edge| ≤ 2¹⁵) absorbs — see the module docs.
#[inline(always)]
pub fn round_half_even_fast(x: f32) -> f32 {
    (x + MAGIC) - MAGIC
}

/// Branch-free floor with the same exactness domain as
/// [`round_half_even_fast`]: round to nearest, then step down when the
/// rounded value overshot.
#[inline(always)]
pub fn floor_fast(x: f32) -> f32 {
    let r = round_half_even_fast(x);
    if r > x {
        r - 1.0
    } else {
        r
    }
}

/// Branch-free ceil, mirror of [`floor_fast`].
#[inline(always)]
pub fn ceil_fast(x: f32) -> f32 {
    let r = round_half_even_fast(x);
    if r < x {
        r + 1.0
    } else {
        r
    }
}

/// Fused multi-scale quantization error: for every candidate scale
/// `scales[j]`, accumulate Σᵢ (wᵢ − nearest(wᵢ; sⱼ))² into `out_sse[j]`
/// in a single pass over `w`, chunked across `pool`.
///
/// Per-candidate math is the scalar `QGrid::nearest` expression verbatim
/// (division included), so with a single chunk the accumulated sums are
/// bit-identical to `scale::quant_mse · len`. Chunk boundaries are a
/// **fixed size** ([`MIN_PAR_CHUNK`]) and partials merge in chunk order,
/// so the result depends only on `w` — not on the pool size or core
/// count; threads just drain the chunk list. A tensor that fits one
/// chunk therefore reproduces the scalar sum exactly on every machine.
pub fn quant_sse_multi(
    pool: &ThreadPool,
    w: &[f32],
    bits: u8,
    scales: &[f32],
    out_sse: &mut [f64],
) {
    assert!(scales.len() <= MAX_SCALES, "too many candidate scales");
    assert_eq!(scales.len(), out_sse.len());
    let half = 1i64 << (bits - 1);
    let lo = -(half as f32);
    let hi = (half - 1) as f32;
    let n_chunks = (w.len() / MIN_PAR_CHUNK).max(1);
    let chunk = (w.len() + n_chunks - 1) / n_chunks.max(1);
    let sse_chunk = |chunk_w: &[f32]| {
        let mut acc = [0.0f64; MAX_SCALES];
        for &v in chunk_w {
            for (j, &s) in scales.iter().enumerate() {
                let q = s * round_half_even_fast(v / s).clamp(lo, hi);
                let d = (v - q) as f64;
                acc[j] += d * d;
            }
        }
        acc
    };
    let partials: Vec<[f64; MAX_SCALES]> = if n_chunks <= 1 {
        vec![sse_chunk(w)]
    } else {
        pool.scope_map(n_chunks, |ci| {
            let start = ci * chunk;
            let end = (start + chunk).min(w.len());
            sse_chunk(&w[start..end])
        })
    };
    for o in out_sse.iter_mut() {
        *o = 0.0;
    }
    for acc in &partials {
        for (j, o) in out_sse.iter_mut().enumerate() {
            *o += acc[j];
        }
    }
}

// ---- explicit-SIMD slice quantizers -------------------------------------
//
// The `_into` rounding kernels feed contiguous chunks here. The vector
// paths run the exact scalar op chain — divps, add/sub MAGIC, clamp,
// mulps — with the same IEEE-correctly-rounded instructions, so every
// lane reproduces the scalar result bit for bit. The clamp is written
// `min(hi, max(lo, r))` with the constants as the FIRST operand: x86
// min/max return the second operand when either input is NaN, so a NaN
// quotient propagates to the output exactly like `f32::clamp` does.

/// out[i] = s · clamp(round_half_even(w[i]/s), lo, hi) over a contiguous
/// slice; AVX/SSE2 when available, [`quantize_nearest_slice_scalar`]
/// otherwise. Bit-identical either way.
#[inline]
pub fn quantize_nearest_slice(w: &[f32], s: f32, lo: f32, hi: f32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: sse2 is the x86_64 baseline; avx is runtime-probed.
        unsafe {
            if crate::linalg::simd::use_avx() {
                x86q::quantize_nearest_avx(w, s, lo, hi, out);
            } else {
                x86q::quantize_nearest_sse2(w, s, lo, hi, out);
            }
        }
        return;
    }
    #[allow(unreachable_code)]
    quantize_nearest_slice_scalar(w, s, lo, hi, out)
}

/// Scalar reference form of [`quantize_nearest_slice`]; public so the
/// identity property tests can pin the vector paths against it.
#[inline]
pub fn quantize_nearest_slice_scalar(w: &[f32], s: f32, lo: f32, hi: f32, out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(w) {
        *o = s * round_half_even_fast(v / s).clamp(lo, hi);
    }
}

/// out[i] = s · clamp(round_half_even(w[i]/s + alpha[i]), lo, hi) — the
/// Attention Round finalizer over a contiguous slice, SIMD-dispatched
/// like [`quantize_nearest_slice`].
#[inline]
pub fn quantize_attention_slice(
    w: &[f32],
    alpha: &[f32],
    s: f32,
    lo: f32,
    hi: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), out.len());
    debug_assert_eq!(w.len(), alpha.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: sse2 is the x86_64 baseline; avx is runtime-probed.
        unsafe {
            if crate::linalg::simd::use_avx() {
                x86q::quantize_attention_avx(w, alpha, s, lo, hi, out);
            } else {
                x86q::quantize_attention_sse2(w, alpha, s, lo, hi, out);
            }
        }
        return;
    }
    #[allow(unreachable_code)]
    quantize_attention_slice_scalar(w, alpha, s, lo, hi, out)
}

/// Scalar reference form of [`quantize_attention_slice`].
#[inline]
pub fn quantize_attention_slice_scalar(
    w: &[f32],
    alpha: &[f32],
    s: f32,
    lo: f32,
    hi: f32,
    out: &mut [f32],
) {
    for ((o, &v), &a) in out.iter_mut().zip(w).zip(alpha) {
        *o = s * round_half_even_fast(v / s + a).clamp(lo, hi);
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86q {
    use super::{round_half_even_fast, MAGIC};
    use core::arch::x86_64::*;

    /// SAFETY: caller must ensure AVX support and equal slice lengths.
    #[target_feature(enable = "avx")]
    pub unsafe fn quantize_nearest_avx(w: &[f32], s: f32, lo: f32, hi: f32, out: &mut [f32]) {
        // SAFETY: contract — AVX present, `w.len() == out.len()`; loop
        // bounds keep every unaligned access inside the slices.
        unsafe {
            let n = w.len();
            let (sv, mg) = (_mm256_set1_ps(s), _mm256_set1_ps(MAGIC));
            let (lov, hiv) = (_mm256_set1_ps(lo), _mm256_set1_ps(hi));
            let (wp, op) = (w.as_ptr(), out.as_mut_ptr());
            let mut i = 0usize;
            while i + 8 <= n {
                let q = _mm256_div_ps(_mm256_loadu_ps(wp.add(i)), sv);
                let r = _mm256_sub_ps(_mm256_add_ps(q, mg), mg);
                let c = _mm256_min_ps(hiv, _mm256_max_ps(lov, r));
                _mm256_storeu_ps(op.add(i), _mm256_mul_ps(sv, c));
                i += 8;
            }
            while i < n {
                *op.add(i) = s * round_half_even_fast(*wp.add(i) / s).clamp(lo, hi);
                i += 1;
            }
        }
    }

    /// SAFETY: caller must ensure equal slice lengths (sse2 is baseline).
    #[target_feature(enable = "sse2")]
    pub unsafe fn quantize_nearest_sse2(w: &[f32], s: f32, lo: f32, hi: f32, out: &mut [f32]) {
        // SAFETY: sse2 is the x86_64 baseline; caller guarantees
        // `w.len() == out.len()` and loop bounds stay in range.
        unsafe {
            let n = w.len();
            let (sv, mg) = (_mm_set1_ps(s), _mm_set1_ps(MAGIC));
            let (lov, hiv) = (_mm_set1_ps(lo), _mm_set1_ps(hi));
            let (wp, op) = (w.as_ptr(), out.as_mut_ptr());
            let mut i = 0usize;
            while i + 4 <= n {
                let q = _mm_div_ps(_mm_loadu_ps(wp.add(i)), sv);
                let r = _mm_sub_ps(_mm_add_ps(q, mg), mg);
                let c = _mm_min_ps(hiv, _mm_max_ps(lov, r));
                _mm_storeu_ps(op.add(i), _mm_mul_ps(sv, c));
                i += 4;
            }
            while i < n {
                *op.add(i) = s * round_half_even_fast(*wp.add(i) / s).clamp(lo, hi);
                i += 1;
            }
        }
    }

    /// SAFETY: caller must ensure AVX support and equal slice lengths.
    #[target_feature(enable = "avx")]
    pub unsafe fn quantize_attention_avx(
        w: &[f32],
        alpha: &[f32],
        s: f32,
        lo: f32,
        hi: f32,
        out: &mut [f32],
    ) {
        // SAFETY: contract — AVX present, `w`, `alpha`, and `out` are
        // equal-length; loop bounds keep every access inside the slices.
        unsafe {
            let n = w.len();
            let (sv, mg) = (_mm256_set1_ps(s), _mm256_set1_ps(MAGIC));
            let (lov, hiv) = (_mm256_set1_ps(lo), _mm256_set1_ps(hi));
            let (wp, ap, op) = (w.as_ptr(), alpha.as_ptr(), out.as_mut_ptr());
            let mut i = 0usize;
            while i + 8 <= n {
                let q = _mm256_add_ps(
                    _mm256_div_ps(_mm256_loadu_ps(wp.add(i)), sv),
                    _mm256_loadu_ps(ap.add(i)),
                );
                let r = _mm256_sub_ps(_mm256_add_ps(q, mg), mg);
                let c = _mm256_min_ps(hiv, _mm256_max_ps(lov, r));
                _mm256_storeu_ps(op.add(i), _mm256_mul_ps(sv, c));
                i += 8;
            }
            while i < n {
                *op.add(i) =
                    s * round_half_even_fast(*wp.add(i) / s + *ap.add(i)).clamp(lo, hi);
                i += 1;
            }
        }
    }

    /// SAFETY: caller must ensure equal slice lengths (sse2 is baseline).
    #[target_feature(enable = "sse2")]
    pub unsafe fn quantize_attention_sse2(
        w: &[f32],
        alpha: &[f32],
        s: f32,
        lo: f32,
        hi: f32,
        out: &mut [f32],
    ) {
        // SAFETY: sse2 is the x86_64 baseline; caller guarantees the
        // three slices are equal-length and loop bounds stay in range.
        unsafe {
            let n = w.len();
            let (sv, mg) = (_mm_set1_ps(s), _mm_set1_ps(MAGIC));
            let (lov, hiv) = (_mm_set1_ps(lo), _mm_set1_ps(hi));
            let (wp, ap, op) = (w.as_ptr(), alpha.as_ptr(), out.as_mut_ptr());
            let mut i = 0usize;
            while i + 4 <= n {
                let q = _mm_add_ps(
                    _mm_div_ps(_mm_loadu_ps(wp.add(i)), sv),
                    _mm_loadu_ps(ap.add(i)),
                );
                let r = _mm_sub_ps(_mm_add_ps(q, mg), mg);
                let c = _mm_min_ps(hiv, _mm_max_ps(lov, r));
                _mm_storeu_ps(op.add(i), _mm_mul_ps(sv, c));
                i += 4;
            }
            while i < n {
                *op.add(i) =
                    s * round_half_even_fast(*wp.add(i) / s + *ap.add(i)).clamp(lo, hi);
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{round_half_even, QGrid};
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    #[test]
    fn fast_round_matches_reference_on_half_grid() {
        // every half-integer in [-500, 500] — all the tie cases — plus
        // the quarter-offsets around them
        for i in -2000..=2000i32 {
            let x = i as f32 * 0.25;
            assert_eq!(
                round_half_even_fast(x),
                round_half_even(x),
                "rhe mismatch at {x}"
            );
        }
    }

    #[test]
    fn fast_round_matches_reference_on_random_values() {
        let mut rng = Rng::new(0xFA57);
        for _ in 0..20_000 {
            let x = rng.gaussian_f32(0.0, 300.0);
            assert_eq!(round_half_even_fast(x), round_half_even(x), "at {x}");
        }
    }

    #[test]
    fn fast_floor_ceil_match_std() {
        let mut rng = Rng::new(0xF100);
        for i in -2000..=2000i32 {
            let x = i as f32 * 0.25;
            assert_eq!(floor_fast(x), x.floor(), "floor at {x}");
            assert_eq!(ceil_fast(x), x.ceil(), "ceil at {x}");
        }
        for _ in 0..20_000 {
            let x = rng.gaussian_f32(0.0, 500.0);
            assert_eq!(floor_fast(x), x.floor(), "floor at {x}");
            assert_eq!(ceil_fast(x), x.ceil(), "ceil at {x}");
        }
    }

    #[test]
    fn clamped_composition_handles_extremes() {
        // Values far outside the exactness domain of the magic constant
        // must still agree once the grid clamp is applied.
        let g = QGrid::signed(8, 0.37).unwrap();
        for v in [
            1.0e9f32,
            -1.0e9,
            4.2e6,
            -4.2e6,
            5.0e6,
            3.3e7,
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
        ] {
            let fast = g.scale * round_half_even_fast(v / g.scale).clamp(g.lo, g.hi);
            assert_eq!(fast, g.nearest(v), "nearest mismatch at {v}");
            let ffast = g.scale * floor_fast(v / g.scale).clamp(g.lo, g.hi);
            let fref = g.scale * (v / g.scale).floor().clamp(g.lo, g.hi);
            assert_eq!(ffast, fref, "floor mismatch at {v}");
        }
    }

    #[test]
    fn simd_slices_match_scalar_bit_for_bit() {
        let mut rng = Rng::new(0x51CE);
        // ragged lengths around the 8/4-lane boundaries
        for &n in &[0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 1000] {
            let mut w = vec![0.0f32; n];
            let mut alpha = vec![0.0f32; n];
            rng.fill_gaussian(&mut w, 0.0, 0.3);
            rng.fill_gaussian(&mut alpha, 0.0, 0.5);
            let g = QGrid::signed(4, 0.07).unwrap();
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            quantize_nearest_slice(&w, g.scale, g.lo, g.hi, &mut got);
            quantize_nearest_slice_scalar(&w, g.scale, g.lo, g.hi, &mut want);
            assert_eq!(got, want, "nearest slice diverged at n={n}");
            quantize_attention_slice(&w, &alpha, g.scale, g.lo, g.hi, &mut got);
            quantize_attention_slice_scalar(&w, &alpha, g.scale, g.lo, g.hi, &mut want);
            assert_eq!(got, want, "attention slice diverged at n={n}");
        }
    }

    #[test]
    fn simd_slices_match_scalar_on_extremes() {
        // NaN/inf/huge/signed-zero inputs: compare bit patterns so a NaN
        // result still has to match exactly (the SIMD clamp is written
        // min(hi, max(lo, r)) precisely so NaN propagates like f32::clamp).
        let w = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MAX,
            f32::MIN,
            1.0e9,
            -1.0e9,
            0.0,
            -0.0,
            0.105,
            -0.105,
            4.2e6,
        ];
        let alpha = [0.5f32; 12];
        let g = QGrid::signed(8, 0.37).unwrap();
        let mut got = [0.0f32; 12];
        let mut want = [0.0f32; 12];
        quantize_nearest_slice(&w, g.scale, g.lo, g.hi, &mut got);
        quantize_nearest_slice_scalar(&w, g.scale, g.lo, g.hi, &mut want);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "nearest bits at {i}: {x} vs {y}");
        }
        quantize_attention_slice(&w, &alpha, g.scale, g.lo, g.hi, &mut got);
        quantize_attention_slice_scalar(&w, &alpha, g.scale, g.lo, g.hi, &mut want);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "attention bits at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn sse_multi_matches_single_scale_reference() {
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; 4096];
        rng.fill_gaussian(&mut w, 0.0, 0.05);
        let scales = [0.004f32, 0.007, 0.011, 0.02];
        let mut sse = [0.0f64; 4];
        let pool = ThreadPool::seq();
        quant_sse_multi(&pool, &w, 4, &scales, &mut sse);
        for (j, &s) in scales.iter().enumerate() {
            let g = QGrid::signed(4, s).unwrap();
            let mut acc = 0.0f64;
            for &v in &w {
                let d = (v - g.nearest(v)) as f64;
                acc += d * d;
            }
            assert_eq!(sse[j], acc, "sse mismatch for scale {s}");
        }
    }

    #[test]
    fn sse_multi_independent_of_pool_size() {
        // Chunk boundaries are fixed-size, so the f64 merge order — and
        // therefore the result bits — must not depend on the pool.
        let mut rng = Rng::new(8);
        let mut w = vec![0.0f32; 80_000];
        rng.fill_gaussian(&mut w, 0.0, 0.05);
        let scales = [0.004f32, 0.011];
        let mut seq = [0.0f64; 2];
        let mut par = [0.0f64; 2];
        quant_sse_multi(&ThreadPool::seq(), &w, 4, &scales, &mut seq);
        for threads in [2usize, 4, 7] {
            quant_sse_multi(&ThreadPool::new(threads), &w, 4, &scales, &mut par);
            assert_eq!(seq, par, "pool size {threads} changed the sums");
        }
    }
}
