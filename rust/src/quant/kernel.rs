//! Fused, zero-allocation, multithreaded host kernels.
//!
//! The scalar quantizer entry points (`rounding::nearest`, `QGrid::
//! nearest`, `scale::quant_mse`) are the semantic reference; everything
//! here is a performance re-expression with **identical outputs**:
//!
//! * [`round_half_even_fast`] / [`floor_fast`] / [`ceil_fast`] replace
//!   the branchy scalar rounding with straight-line float arithmetic
//!   (the classic `(x + 1.5·2²³) − 1.5·2²³` trick, which rounds at
//!   integer precision with ties-to-even because that is exactly what
//!   f32 addition does at that magnitude). Branch-free means LLVM
//!   auto-vectorizes the kernels, which is where most of the
//!   single-thread win comes from. Composed with the grid clamp these
//!   are bit-identical to the scalar forms for every input (the trick
//!   is exact for |x| ≤ 2²², and any quotient beyond that clamps to the
//!   same grid edge either way; the lone difference is that an exact
//!   `-0.0` comes back as `+0.0`, numerically equal).
//! * [`quant_sse_multi`] fuses the MSE scale search: one pass over the
//!   tensor evaluates every candidate scale (≤ [`MAX_SCALES`]), so a
//!   25-candidate refinement round reads the 147k-element tensor once
//!   instead of 25 times, chunked across the pool with per-thread f64
//!   accumulators merged in deterministic chunk order.
//!
//! On precomputed reciprocals: multiplying by `1/s` instead of dividing
//! by `s` changes the quotient by an ulp, which flips rounding decisions
//! for weights sitting on a rounding-cell boundary — the outputs would
//! no longer be bit-identical to the scalar reference or to the device
//! executables (which also divide). We deliberately keep IEEE division;
//! the fusion + vectorization + chunking above deliver the speedup
//! without giving up exactness, and `vdivps` pipelines well enough that
//! division is not the bottleneck in the vectorized loop.

use crate::util::threadpool::{ThreadPool, MIN_PAR_CHUNK};

/// Upper bound on the candidate-scale count a fused sweep can evaluate
/// (the search uses 25 per refinement round).
pub const MAX_SCALES: usize = 32;

/// 1.5 · 2²³ — adding then subtracting this constant rounds an f32 to
/// integer precision with IEEE ties-to-even.
const MAGIC: f32 = 12_582_912.0;

/// Branch-free round-half-to-even. Exact for |x| ≤ 2²²; beyond that the
/// result may differ from true rounding by the local ulp, which the grid
/// clamp (|edge| ≤ 2¹⁵) absorbs — see the module docs.
#[inline(always)]
pub fn round_half_even_fast(x: f32) -> f32 {
    (x + MAGIC) - MAGIC
}

/// Branch-free floor with the same exactness domain as
/// [`round_half_even_fast`]: round to nearest, then step down when the
/// rounded value overshot.
#[inline(always)]
pub fn floor_fast(x: f32) -> f32 {
    let r = round_half_even_fast(x);
    if r > x {
        r - 1.0
    } else {
        r
    }
}

/// Branch-free ceil, mirror of [`floor_fast`].
#[inline(always)]
pub fn ceil_fast(x: f32) -> f32 {
    let r = round_half_even_fast(x);
    if r < x {
        r + 1.0
    } else {
        r
    }
}

/// Fused multi-scale quantization error: for every candidate scale
/// `scales[j]`, accumulate Σᵢ (wᵢ − nearest(wᵢ; sⱼ))² into `out_sse[j]`
/// in a single pass over `w`, chunked across `pool`.
///
/// Per-candidate math is the scalar `QGrid::nearest` expression verbatim
/// (division included), so with a single chunk the accumulated sums are
/// bit-identical to `scale::quant_mse · len`. Chunk boundaries are a
/// **fixed size** ([`MIN_PAR_CHUNK`]) and partials merge in chunk order,
/// so the result depends only on `w` — not on the pool size or core
/// count; threads just drain the chunk list. A tensor that fits one
/// chunk therefore reproduces the scalar sum exactly on every machine.
pub fn quant_sse_multi(
    pool: &ThreadPool,
    w: &[f32],
    bits: u8,
    scales: &[f32],
    out_sse: &mut [f64],
) {
    assert!(scales.len() <= MAX_SCALES, "too many candidate scales");
    assert_eq!(scales.len(), out_sse.len());
    let half = 1i64 << (bits - 1);
    let lo = -(half as f32);
    let hi = (half - 1) as f32;
    let n_chunks = (w.len() / MIN_PAR_CHUNK).max(1);
    let chunk = (w.len() + n_chunks - 1) / n_chunks.max(1);
    let sse_chunk = |chunk_w: &[f32]| {
        let mut acc = [0.0f64; MAX_SCALES];
        for &v in chunk_w {
            for (j, &s) in scales.iter().enumerate() {
                let q = s * round_half_even_fast(v / s).clamp(lo, hi);
                let d = (v - q) as f64;
                acc[j] += d * d;
            }
        }
        acc
    };
    let partials: Vec<[f64; MAX_SCALES]> = if n_chunks <= 1 {
        vec![sse_chunk(w)]
    } else {
        pool.scope_map(n_chunks, |ci| {
            let start = ci * chunk;
            let end = (start + chunk).min(w.len());
            sse_chunk(&w[start..end])
        })
    };
    for o in out_sse.iter_mut() {
        *o = 0.0;
    }
    for acc in &partials {
        for (j, o) in out_sse.iter_mut().enumerate() {
            *o += acc[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{round_half_even, QGrid};
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    #[test]
    fn fast_round_matches_reference_on_half_grid() {
        // every half-integer in [-500, 500] — all the tie cases — plus
        // the quarter-offsets around them
        for i in -2000..=2000i32 {
            let x = i as f32 * 0.25;
            assert_eq!(
                round_half_even_fast(x),
                round_half_even(x),
                "rhe mismatch at {x}"
            );
        }
    }

    #[test]
    fn fast_round_matches_reference_on_random_values() {
        let mut rng = Rng::new(0xFA57);
        for _ in 0..20_000 {
            let x = rng.gaussian_f32(0.0, 300.0);
            assert_eq!(round_half_even_fast(x), round_half_even(x), "at {x}");
        }
    }

    #[test]
    fn fast_floor_ceil_match_std() {
        let mut rng = Rng::new(0xF100);
        for i in -2000..=2000i32 {
            let x = i as f32 * 0.25;
            assert_eq!(floor_fast(x), x.floor(), "floor at {x}");
            assert_eq!(ceil_fast(x), x.ceil(), "ceil at {x}");
        }
        for _ in 0..20_000 {
            let x = rng.gaussian_f32(0.0, 500.0);
            assert_eq!(floor_fast(x), x.floor(), "floor at {x}");
            assert_eq!(ceil_fast(x), x.ceil(), "ceil at {x}");
        }
    }

    #[test]
    fn clamped_composition_handles_extremes() {
        // Values far outside the exactness domain of the magic constant
        // must still agree once the grid clamp is applied.
        let g = QGrid::signed(8, 0.37).unwrap();
        for v in [
            1.0e9f32,
            -1.0e9,
            4.2e6,
            -4.2e6,
            5.0e6,
            3.3e7,
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
        ] {
            let fast = g.scale * round_half_even_fast(v / g.scale).clamp(g.lo, g.hi);
            assert_eq!(fast, g.nearest(v), "nearest mismatch at {v}");
            let ffast = g.scale * floor_fast(v / g.scale).clamp(g.lo, g.hi);
            let fref = g.scale * (v / g.scale).floor().clamp(g.lo, g.hi);
            assert_eq!(ffast, fref, "floor mismatch at {v}");
        }
    }

    #[test]
    fn sse_multi_matches_single_scale_reference() {
        let mut rng = Rng::new(7);
        let mut w = vec![0.0f32; 4096];
        rng.fill_gaussian(&mut w, 0.0, 0.05);
        let scales = [0.004f32, 0.007, 0.011, 0.02];
        let mut sse = [0.0f64; 4];
        let pool = ThreadPool::seq();
        quant_sse_multi(&pool, &w, 4, &scales, &mut sse);
        for (j, &s) in scales.iter().enumerate() {
            let g = QGrid::signed(4, s).unwrap();
            let mut acc = 0.0f64;
            for &v in &w {
                let d = (v - g.nearest(v)) as f64;
                acc += d * d;
            }
            assert_eq!(sse[j], acc, "sse mismatch for scale {s}");
        }
    }

    #[test]
    fn sse_multi_independent_of_pool_size() {
        // Chunk boundaries are fixed-size, so the f64 merge order — and
        // therefore the result bits — must not depend on the pool.
        let mut rng = Rng::new(8);
        let mut w = vec![0.0f32; 80_000];
        rng.fill_gaussian(&mut w, 0.0, 0.05);
        let scales = [0.004f32, 0.011];
        let mut seq = [0.0f64; 2];
        let mut par = [0.0f64; 2];
        quant_sse_multi(&ThreadPool::seq(), &w, 4, &scales, &mut seq);
        for threads in [2usize, 4, 7] {
            quant_sse_multi(&ThreadPool::new(threads), &w, 4, &scales, &mut par);
            assert_eq!(seq, par, "pool size {threads} changed the sums");
        }
    }
}
