//! The static rounding baselines of Table 5: Nearest, Floor, Ceil,
//! Stochastic — plus finalization for the two trained rounders
//! (Attention Round's α and AdaRound's h(V)).
//!
//! All functions quantize-dequantize: output values live on the grid but
//! stay in f32, which is what the forward executables consume (fake
//! quantization, standard for PTQ evaluation).

use super::kernel::{
    ceil_fast, floor_fast, quantize_attention_slice, quantize_nearest_slice,
};
use super::{round_half_even, QGrid};
use crate::util::rng::Rng;
use crate::util::threadpool::{ThreadPool, MIN_PAR_CHUNK};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    Nearest,
    Floor,
    Ceil,
    Stochastic,
    /// Attention Round (paper §3.3): ⌊w/s + α⌉ with trained α.
    Attention,
    /// AdaRound: ⌊w/s⌋ + (h(V) ≥ ½) with trained V.
    AdaRound,
}

impl Rounding {
    pub fn name(&self) -> &'static str {
        match self {
            Rounding::Nearest => "nearest",
            Rounding::Floor => "floor",
            Rounding::Ceil => "ceil",
            Rounding::Stochastic => "stochastic",
            Rounding::Attention => "attention",
            Rounding::AdaRound => "adaround",
        }
    }

    pub fn parse(s: &str) -> Option<Rounding> {
        Some(match s {
            "nearest" => Rounding::Nearest,
            "floor" => Rounding::Floor,
            "ceil" => Rounding::Ceil,
            "stochastic" => Rounding::Stochastic,
            "attention" | "ours" => Rounding::Attention,
            "adaround" => Rounding::AdaRound,
            _ => return None,
        })
    }
}

/// Nearest-round a tensor onto the grid (the paper's baseline quantizer).
pub fn nearest(w: &[f32], g: &QGrid) -> Vec<f32> {
    w.iter().map(|&v| g.nearest(v)).collect()
}

pub fn floor(w: &[f32], g: &QGrid) -> Vec<f32> {
    w.iter()
        .map(|&v| g.scale * (v / g.scale).floor().clamp(g.lo, g.hi))
        .collect()
}

pub fn ceil(w: &[f32], g: &QGrid) -> Vec<f32> {
    w.iter()
        .map(|&v| g.scale * (v / g.scale).ceil().clamp(g.lo, g.hi))
        .collect()
}

/// Stochastic round: up with probability frac(w/s), down otherwise
/// (unbiased: E[ŵ] = w inside the clip range).
pub fn stochastic(w: &[f32], g: &QGrid, rng: &mut Rng) -> Vec<f32> {
    w.iter()
        .map(|&v| {
            let q = v / g.scale;
            let f = q.floor();
            let p_up = q - f;
            let r = if (rng.next_f64() as f32) < p_up { f + 1.0 } else { f };
            g.scale * r.clamp(g.lo, g.hi)
        })
        .collect()
}

/// Finalize Attention Round: ŵ = s·clip(⌊w/s + α⌉, lo, hi) with the
/// calibrated α (matches kernels/attention_round.py bit-for-bit: same
/// round-half-even).
pub fn attention_finalize(w: &[f32], alpha: &[f32], g: &QGrid) -> Vec<f32> {
    debug_assert_eq!(w.len(), alpha.len());
    w.iter()
        .zip(alpha)
        .map(|(&v, &a)| g.scale * round_half_even(v / g.scale + a).clamp(g.lo, g.hi))
        .collect()
}

// ---- in-place parallel kernels (quant::kernel subsystem) ----------------
//
// Zero-allocation `_into` variants of every rounding kernel above: the
// caller owns the output buffer, chunks run across the scoped pool, and
// the per-element math uses the branch-free (auto-vectorizing) rounding
// primitives from `quant::kernel` — bit-identical to the scalar forms
// (see kernel.rs for the exactness argument; verified by
// tests/kernel_properties.rs).

/// In-place parallel [`nearest`]. Chunks dispatch into the explicit-SIMD
/// slice quantizer (`quant::kernel::quantize_nearest_slice`), which is
/// bit-identical to the scalar expression on every path.
pub fn nearest_into(pool: &ThreadPool, w: &[f32], g: &QGrid, out: &mut [f32]) {
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    pool.par_chunks(w, out, |_, ic, oc| {
        quantize_nearest_slice(ic, s, lo, hi, oc);
    });
}

/// In-place parallel [`floor`].
pub fn floor_into(pool: &ThreadPool, w: &[f32], g: &QGrid, out: &mut [f32]) {
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    pool.par_chunks(w, out, |_, ic, oc| {
        for (o, &v) in oc.iter_mut().zip(ic) {
            *o = s * floor_fast(v / s).clamp(lo, hi);
        }
    });
}

/// In-place parallel [`ceil`].
pub fn ceil_into(pool: &ThreadPool, w: &[f32], g: &QGrid, out: &mut [f32]) {
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    pool.par_chunks(w, out, |_, ic, oc| {
        for (o, &v) in oc.iter_mut().zip(ic) {
            *o = s * ceil_fast(v / s).clamp(lo, hi);
        }
    });
}

/// In-place **parallel** [`stochastic`] with deterministic per-chunk RNG
/// streams. Elements are split into fixed-size logical chunks of
/// [`MIN_PAR_CHUNK`]; chunk `i` draws from an independent stream seeded
/// `seed ⊕ mix(i)`. Chunk boundaries depend only on the input length —
/// never on the pool size — so the output is a pure function of
/// `(w, grid, seed)` and is **bit-identical for every thread count**
/// (property-tested in tests/kernel_properties.rs). The pool bounds
/// concurrency: chunks are dispatched in pool-sized waves of scoped
/// workers.
pub fn stochastic_into(pool: &ThreadPool, w: &[f32], g: &QGrid, seed: u64, out: &mut [f32]) {
    assert_eq!(w.len(), out.len(), "stochastic_into arity");
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    let kernel = |ci: usize, wc: &[f32], oc: &mut [f32]| {
        let mut rng = Rng::new(
            seed ^ (ci as u64)
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(0xD1B54A32D192ED03),
        );
        for (o, &v) in oc.iter_mut().zip(wc) {
            let q = v / s;
            let f = q.floor();
            let p_up = q - f;
            let r = if (rng.next_f64() as f32) < p_up { f + 1.0 } else { f };
            *o = s * r.clamp(lo, hi);
        }
    };
    if w.len() <= MIN_PAR_CHUNK || pool.width() <= 1 {
        // single chunk or sequential pool: still chunked logically so the
        // result matches the parallel path bit for bit
        for (ci, (wc, oc)) in w
            .chunks(MIN_PAR_CHUNK)
            .zip(out.chunks_mut(MIN_PAR_CHUNK))
            .enumerate()
        {
            kernel(ci, wc, oc);
        }
        return;
    }
    let mut jobs: Vec<(usize, &[f32], &mut [f32])> = w
        .chunks(MIN_PAR_CHUNK)
        .zip(out.chunks_mut(MIN_PAR_CHUNK))
        .enumerate()
        .map(|(ci, (wc, oc))| (ci, wc, oc))
        .collect();
    // width-sized waves of scoped workers (same pattern as gram_tr_with)
    let wave = pool.width();
    while !jobs.is_empty() {
        let batch: Vec<_> = jobs.drain(..wave.min(jobs.len())).collect();
        std::thread::scope(|sc| {
            for (ci, wc, oc) in batch {
                let k = &kernel;
                sc.spawn(move || k(ci, wc, oc));
            }
        });
    }
}

/// In-place parallel [`attention_finalize`], dispatching chunks into the
/// explicit-SIMD attention slice quantizer.
pub fn attention_finalize_into(
    pool: &ThreadPool,
    w: &[f32],
    alpha: &[f32],
    g: &QGrid,
    out: &mut [f32],
) {
    assert_eq!(w.len(), alpha.len(), "attention_finalize_into arity");
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    pool.par_chunks(w, out, |off, ic, oc| {
        quantize_attention_slice(ic, &alpha[off..off + ic.len()], s, lo, hi, oc);
    });
}

/// In-place parallel [`adaround_finalize`].
pub fn adaround_finalize_into(
    pool: &ThreadPool,
    w: &[f32],
    v: &[f32],
    g: &QGrid,
    out: &mut [f32],
) {
    assert_eq!(w.len(), v.len(), "adaround_finalize_into arity");
    let (s, lo, hi) = (g.scale, g.lo, g.hi);
    pool.par_chunks(w, out, |off, ic, oc| {
        let vc = &v[off..off + ic.len()];
        for ((o, &wv), &vv) in oc.iter_mut().zip(ic).zip(vc) {
            let up = if adaround_h(vv) >= 0.5 { 1.0 } else { 0.0 };
            *o = s * (floor_fast(wv / s) + up).clamp(lo, hi);
        }
    });
}

/// AdaRound's rectified sigmoid h(V) = clip(sigmoid(V)·1.2 − 0.1, 0, 1).
pub fn adaround_h(v: f32) -> f32 {
    let s = 1.0 / (1.0 + (-v).exp());
    (s * 1.2 - 0.1).clamp(0.0, 1.0)
}

/// Finalize AdaRound: ŵ = s·clip(⌊w/s⌋ + (h(V) ≥ ½), lo, hi).
pub fn adaround_finalize(w: &[f32], v: &[f32], g: &QGrid) -> Vec<f32> {
    debug_assert_eq!(w.len(), v.len());
    w.iter()
        .zip(v)
        .map(|(&wv, &vv)| {
            let up = if adaround_h(vv) >= 0.5 { 1.0 } else { 0.0 };
            g.scale * ((wv / g.scale).floor() + up).clamp(g.lo, g.hi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> QGrid {
        QGrid::signed(4, 0.5).unwrap()
    }

    #[test]
    fn floor_le_nearest_le_ceil() {
        let w: Vec<f32> = (-20..20).map(|i| i as f32 * 0.13).collect();
        let g = grid();
        let f = floor(&w, &g);
        let n = nearest(&w, &g);
        let c = ceil(&w, &g);
        for i in 0..w.len() {
            assert!(f[i] <= n[i] + 1e-6, "floor > nearest at {i}");
            assert!(n[i] <= c[i] + 1e-6, "nearest > ceil at {i}");
        }
    }

    #[test]
    fn all_outputs_on_grid() {
        let w: Vec<f32> = (-30..30).map(|i| i as f32 * 0.21).collect();
        let g = grid();
        let mut rng = Rng::new(0);
        for out in [
            nearest(&w, &g),
            floor(&w, &g),
            ceil(&w, &g),
            stochastic(&w, &g, &mut rng),
            attention_finalize(&w, &vec![0.2; w.len()], &g),
            adaround_finalize(&w, &vec![-3.0; w.len()], &g),
        ] {
            for v in out {
                assert!(g.contains(v), "{v} not on grid");
            }
        }
    }

    #[test]
    fn stochastic_is_unbiased_inside_range() {
        let g = QGrid::signed(8, 0.1).unwrap();
        let w = [0.537f32];
        let mut rng = Rng::new(9);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            sum += stochastic(&w, &g, &mut rng)[0] as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.537).abs() < 0.002, "mean {mean}");
    }

    #[test]
    fn attention_zero_alpha_is_nearest() {
        let w: Vec<f32> = (-10..10).map(|i| i as f32 * 0.37).collect();
        let g = grid();
        assert_eq!(attention_finalize(&w, &vec![0.0; w.len()], &g), nearest(&w, &g));
    }

    #[test]
    fn attention_large_alpha_shifts_cell() {
        let g = grid();
        // w=0.2 -> w/s=0.4 -> nearest 0; alpha=1 pushes it to cell 1
        assert_eq!(attention_finalize(&[0.2], &[1.0], &g)[0], 0.5);
        assert_eq!(attention_finalize(&[0.2], &[-1.0], &g)[0], -0.5);
    }

    #[test]
    fn adaround_h_rectified() {
        assert_eq!(adaround_h(-10.0), 0.0);
        assert_eq!(adaround_h(10.0), 1.0);
        assert!((adaround_h(0.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn into_kernels_match_scalar_kernels() {
        // big enough to split into real parallel chunks (> MIN_PAR_CHUNK)
        let mut rng = Rng::new(0x1217);
        let mut w = vec![0.0f32; 40_000];
        rng.fill_gaussian(&mut w, 0.0, 0.3);
        let mut alpha = vec![0.0f32; w.len()];
        rng.fill_gaussian(&mut alpha, 0.0, 0.5);
        let g = QGrid::signed(4, 0.07).unwrap();
        let pool = ThreadPool::new(3);
        let mut out = vec![0.0f32; w.len()];

        nearest_into(&pool, &w, &g, &mut out);
        assert_eq!(out, nearest(&w, &g));
        floor_into(&pool, &w, &g, &mut out);
        assert_eq!(out, floor(&w, &g));
        ceil_into(&pool, &w, &g, &mut out);
        assert_eq!(out, ceil(&w, &g));
        attention_finalize_into(&pool, &w, &alpha, &g, &mut out);
        assert_eq!(out, attention_finalize(&w, &alpha, &g));
        adaround_finalize_into(&pool, &w, &alpha, &g, &mut out);
        assert_eq!(out, adaround_finalize(&w, &alpha, &g));

        // stochastic: fixed seed -> identical output for every pool size,
        // and every value lands on the grid
        let mut o1 = vec![0.0f32; w.len()];
        let mut o3 = vec![0.0f32; w.len()];
        stochastic_into(&ThreadPool::seq(), &w, &g, 99, &mut o1);
        stochastic_into(&pool, &w, &g, 99, &mut o3);
        assert_eq!(o1, o3, "stochastic must not depend on thread count");
        assert!(o1.iter().all(|&v| g.contains(v)));
        // different seed -> different coin flips somewhere
        let mut o2 = vec![0.0f32; w.len()];
        stochastic_into(&pool, &w, &g, 100, &mut o2);
        assert_ne!(o1, o2);
    }

    #[test]
    fn stochastic_into_is_unbiased() {
        let g = QGrid::signed(8, 0.1).unwrap();
        let n = 40_000; // > MIN_PAR_CHUNK: crosses a chunk boundary
        let w = vec![0.537f32; n];
        let mut out = vec![0.0f32; n];
        let pool = ThreadPool::new(3);
        stochastic_into(&pool, &w, &g, 1234, &mut out);
        let mean = out.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.537).abs() < 0.002, "mean {mean}");
    }

    #[test]
    fn parse_names() {
        for r in [
            Rounding::Nearest,
            Rounding::Floor,
            Rounding::Ceil,
            Rounding::Stochastic,
            Rounding::Attention,
            Rounding::AdaRound,
        ] {
            assert_eq!(Rounding::parse(r.name()), Some(r));
        }
        assert_eq!(Rounding::parse("ours"), Some(Rounding::Attention));
        assert_eq!(Rounding::parse("bogus"), None);
    }
}
