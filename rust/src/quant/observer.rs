//! Activation observers — per-layer activation quantization parameters
//! for Tables 2, 3 and 5.
//!
//! Activations flow through `forward_actq` with a per-layer (scale,
//! zero-point) pair; the observer picks them from captured calibration
//! activations. Post-ReLU tensors are one-sided so an unsigned affine
//! grid with a zero shift is the natural fit; the stem input (zero-mean
//! images) gets a negative zero-point from the same affine rule.

use crate::tensor::ops;
use crate::util::error::Result;

#[derive(Debug, Clone, Copy)]
pub struct ActQuantParams {
    pub scale: f32,
    /// Value-domain shift: x is quantized as x' = x − zero, so `zero` is
    /// the left edge of the representable range.
    pub zero: f32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObserverKind {
    /// min/max of the calibration sample.
    MinMax,
    /// percentile clipping (99.9%) — robust to activation outliers.
    Percentile,
    /// grid search over clip range minimizing quantization MSE (OMSE-like).
    Mse,
}

/// Compute activation quant params for a given bit width from samples.
pub fn observe(xs: &[f32], bits: u8, kind: ObserverKind) -> Result<ActQuantParams> {
    let mut scratch = Vec::new();
    observe_with(xs, bits, kind, &mut scratch)
}

/// [`observe`] with a caller-provided scratch buffer: the percentile
/// observer selects into `scratch` instead of allocating, so a pipeline
/// observing dozens of layers reuses one buffer (see
/// `coordinator::pipeline`).
pub fn observe_with(
    xs: &[f32],
    bits: u8,
    kind: ObserverKind,
    scratch: &mut Vec<f32>,
) -> Result<ActQuantParams> {
    let levels = ((1u32 << bits) - 1) as f32;
    let (lo, hi) = match kind {
        ObserverKind::MinMax => ops::min_max(xs),
        ObserverKind::Percentile => (
            ops::percentile_with(xs, 0.1, scratch),
            ops::percentile_with(xs, 99.9, scratch),
        ),
        ObserverKind::Mse => return mse_observe(xs, bits),
    };
    let lo = lo.min(0.0); // keep 0 representable (ReLU outputs, padding)
    let range = (hi - lo).max(1e-6);
    Ok(ActQuantParams {
        scale: range / levels,
        zero: lo,
    })
}

fn quant_err(xs: &[f32], lo: f32, hi: f32, levels: f32) -> f64 {
    let scale = ((hi - lo) / levels).max(1e-9);
    let mut acc = 0.0f64;
    for &x in xs {
        let q = ((x - lo) / scale).round().clamp(0.0, levels);
        let d = (x - (q * scale + lo)) as f64;
        acc += d * d;
    }
    acc
}

fn mse_observe(xs: &[f32], bits: u8) -> Result<ActQuantParams> {
    let levels = ((1u32 << bits) - 1) as f32;
    let (mut lo, hi) = ops::min_max(xs);
    lo = lo.min(0.0);
    let mut best = (f64::INFINITY, lo, hi);
    // shrink the max clip progressively (Banner/Choukroun-style)
    for i in 0..=20 {
        let frac = 1.0 - 0.035 * i as f32;
        let h = lo + (hi - lo) * frac;
        let e = quant_err(xs, lo, h, levels);
        if e < best.0 {
            best = (e, lo, h);
        }
    }
    let range = (best.2 - best.1).max(1e-6);
    Ok(ActQuantParams {
        scale: range / levels,
        zero: best.1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn relu_acts(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| rng.gaussian_f32(0.0, 1.0).max(0.0))
            .collect()
    }

    #[test]
    fn minmax_covers_range() {
        let xs = relu_acts(1000, 1);
        let p = observe(&xs, 8, ObserverKind::MinMax).unwrap();
        assert_eq!(p.zero, 0.0);
        let max = crate::tensor::ops::abs_max(&xs);
        assert!((p.scale * 255.0 - max).abs() < 1e-4);
    }

    #[test]
    fn mse_clips_tighter_than_minmax() {
        let mut xs = relu_acts(4000, 2);
        xs.push(40.0); // inject an outlier
        let mm = observe(&xs, 4, ObserverKind::MinMax).unwrap();
        let ms = observe(&xs, 4, ObserverKind::Mse).unwrap();
        assert!(
            ms.scale < mm.scale,
            "mse {0} should clip below minmax {1}",
            ms.scale,
            mm.scale
        );
    }

    #[test]
    fn mse_beats_minmax_on_error() {
        let mut xs = relu_acts(4000, 3);
        xs.push(25.0);
        let levels = 15.0;
        let mm = observe(&xs, 4, ObserverKind::MinMax).unwrap();
        let ms = observe(&xs, 4, ObserverKind::Mse).unwrap();
        let e_mm = quant_err(&xs, mm.zero, mm.zero + mm.scale * levels, levels);
        let e_ms = quant_err(&xs, ms.zero, ms.zero + ms.scale * levels, levels);
        assert!(e_ms <= e_mm, "mse {e_ms} > minmax {e_mm}");
    }

    #[test]
    fn signed_input_gets_negative_zero() {
        let mut rng = Rng::new(4);
        let xs: Vec<f32> = (0..1000).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
        let p = observe(&xs, 8, ObserverKind::MinMax).unwrap();
        assert!(p.zero < 0.0);
    }

    #[test]
    fn observer_handles_constant_input() {
        let xs = vec![0.0f32; 128];
        let p = observe(&xs, 4, ObserverKind::Mse).unwrap();
        assert!(p.scale > 0.0 && p.scale.is_finite());
    }

    #[test]
    fn percentile_observer_scratch_reuse_is_equivalent() {
        let xs = relu_acts(4000, 6);
        let fresh = observe(&xs, 8, ObserverKind::Percentile).unwrap();
        let mut scratch = Vec::new();
        for _ in 0..3 {
            let p = observe_with(&xs, 8, ObserverKind::Percentile, &mut scratch).unwrap();
            assert_eq!(p.scale, fresh.scale);
            assert_eq!(p.zero, fresh.zero);
        }
        // percentile clipping must sit at or inside the min/max range
        let mm = observe(&xs, 8, ObserverKind::MinMax).unwrap();
        assert!(fresh.scale <= mm.scale * 1.0001);
    }
}
