//! # attention-round
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"Attention Round for Post-Training Quantization"* (Diao, Li, Xu, Hao,
//! 2022).
//!
//! The crate is the **Layer-3 coordinator**: it owns the calibration
//! pipeline, the mixed-precision bit allocator, every rounding baseline,
//! and the experiment harness that regenerates the paper's Tables 1–5 and
//! Figures 2–5. Compute graphs (Layer 2, JAX) and quantization kernels
//! (Layer 1, Pallas) are AOT-compiled at build time by
//! `python/compile/aot.py` into `artifacts/` and executed here through the
//! PJRT C API — Python never runs on the request path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — substrates the offline registry lacks: JSON, CLI args,
//!   RNG, logging, thread pool, timing.
//! * [`io`] — `.npy` codec and the artifact manifest loader.
//! * [`tensor`] — dense f32 tensors.
//! * [`linalg`] — matmul / Cholesky / log-det for the coding length.
//! * [`data`] — dataset loading, batching, and the synthetic generator.
//! * [`quant`] — quantizer math: scales, rounding functions, observers.
//! * [`mixed`] — rate-distortion coding length + 1-D k-means allocator
//!   (paper §3.4, Algorithm 1).
//! * [`runtime`] — PJRT executable loading and device-resident execution.
//! * [`backend`] — pluggable execution backends: the PJRT device path
//!   and a pure-host executor that runs the whole pipeline with zero
//!   artifacts.
//! * [`deploy`] — packed quantized artifacts: integer-code bitstreams
//!   at the allocated 2–8-bit widths, the versioned artifact format,
//!   dequant-on-the-fly serving, compression accounting.
//! * [`coordinator`] — the calibration pipeline and experiment drivers.
//! * [`serve`] — batched serving: hot prepared model, bounded request
//!   queue with admission control, micro-batching worker, latency /
//!   throughput metrics.
//! * [`trace`] — unified tracing: span ring buffers, Chrome trace-event
//!   export (`--trace`), windowed serve telemetry; the process clock
//!   every timing number comes from.
//! * [`report`] — tables, ASCII charts, CSV.
//! * [`bench_harness`] — the in-repo criterion replacement.
//!
//! Machine-enforced invariants (`cargo run -p analyze`, blocking in CI):
//! every `unsafe` carries a `// SAFETY:` comment, every SIMD path has a
//! scalar sibling, kernel hot paths stay free of `unwrap`/`expect`/
//! `Instant::now`/bare `thread::spawn`, and every public module keeps a
//! module doc. See README "Correctness tooling".

// Redundant with the workspace lint table on purpose: the guarantee is
// part of this crate's contract even when the file is built outside the
// workspace (e.g. vendored into another tree).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod backend;
pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod io;
pub mod linalg;
pub mod mixed;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod util;

pub use util::error::{Error, Result};
