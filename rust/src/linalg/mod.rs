//! Dense linear algebra substrate for the bit allocator.
//!
//! The coding length (paper Eq. 12) needs `log2 det(I + c·W·Wᵀ)` per
//! layer. The matrix is symmetric positive definite by construction, so
//! log-det comes from a Cholesky factorization: log det(A) = 2·Σ log Lᵢᵢ.
//!
//! Gram products are the host-side hot spot (the 1152×128 zoo layer is
//! ~9.5M f64 multiply-adds), so they are blocked for the kernel
//! subsystem: dot products run 4-way unrolled (breaking the serial f64
//! dependence chain so LLVM vectorizes), row blocks fan out across a
//! scoped [`ThreadPool`], and `gram_tr_with` forms AᵀW·... AᵀA directly
//! from the row-major storage via rank-1 row updates — no transposed
//! copy. Partial results merge in deterministic block order; only f64
//! association differs from the naive loops (the `gram_naive` reference
//! stays for property tests and benches).

use crate::util::error::{Error, Result};
use crate::util::threadpool::{ThreadPool, MIN_PAR_CHUNK};

pub mod simd;

/// Row-major dense matrix of f64 (the determinant accumulates across
/// hundreds of multiplications — f32 would visibly drift).
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

/// 4-way unrolled dot product: four independent partial sums break the
/// floating-point dependence chain, letting the loop vectorize. The
/// summation order is fixed (chunk order, then tail), so results are
/// deterministic — just not the naive left-to-right association.
#[inline]
fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = [0.0f64; 4];
    for (ca, cb) in a.chunks_exact(4).zip(b.chunks_exact(4)) {
        s[0] += ca[0] * cb[0];
        s[1] += ca[1] * cb[1];
        s[2] += ca[2] * cb[2];
        s[3] += ca[3] * cb[3];
    }
    let mut acc = (s[0] + s[1]) + (s[2] + s[3]);
    for (x, y) in a
        .chunks_exact(4)
        .remainder()
        .iter()
        .zip(b.chunks_exact(4).remainder())
    {
        acc += x * y;
    }
    acc
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if rows * cols != data.len() {
            return Err(Error::shape(format!(
                "{rows}x{cols} != {} elements",
                data.len()
            )));
        }
        Ok(Mat {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// Gram matrix G = A·Aᵀ (rows as vectors), sequential. Same blocked
    /// kernel as [`Mat::gram_with`] on one thread.
    pub fn gram(&self) -> Mat {
        self.gram_with(&ThreadPool::seq())
    }

    /// Gram matrix G = A·Aᵀ across the pool: the lower triangle is
    /// computed in parallel row blocks with unrolled dots, then mirrored.
    /// Bit-identical to [`Mat::gram`] for any pool size (each entry is
    /// one independent dot product). Row i of the triangle costs i+1
    /// dots, so block boundaries follow a square-root schedule (work up
    /// to row r is ∝ r²) instead of equal row counts — equal splits
    /// would leave the last block with most of the triangle.
    pub fn gram_with(&self, pool: &ThreadPool) -> Mat {
        let n = self.rows;
        let k = self.cols;
        let mut g = Mat::zeros(n, n);
        if n == 0 {
            return g;
        }
        let data = &self.data;
        // Work ≈ n²k/2 multiply-adds; below the chunk threshold a thread
        // spawn costs more than the whole triangle, so stay inline.
        let blocks = if n * n * k / 2 < MIN_PAR_CHUNK {
            1
        } else {
            pool.width().min(n).max(1)
        };
        let fill_rows = |first_row: usize, block: &mut [f64]| {
            for (bi, grow) in block.chunks_mut(n).enumerate() {
                let i = first_row + bi;
                let ri = &data[i * k..(i + 1) * k];
                for (j, gv) in grow.iter_mut().enumerate().take(i + 1) {
                    let rj = &data[j * k..(j + 1) * k];
                    *gv = dot_unrolled(ri, rj);
                }
            }
        };
        if blocks <= 1 {
            fill_rows(0, &mut g.data);
        } else {
            std::thread::scope(|s| {
                let mut rest: &mut [f64] = &mut g.data;
                let mut start = 0usize;
                for b in 0..blocks {
                    let end = if b + 1 == blocks {
                        n
                    } else {
                        // cumulative work ∝ r², so split at n·√(frac);
                        // max-then-min keeps the bounds ordered even when
                        // the schedule saturates early (then the trailing
                        // blocks are empty, which fill_rows handles)
                        let frac = (b + 1) as f64 / blocks as f64;
                        ((n as f64 * frac.sqrt()) as usize)
                            .max(start + 1)
                            .min(n)
                    };
                    if end == start {
                        continue;
                    }
                    let tmp = std::mem::take(&mut rest);
                    let (block, tail) = tmp.split_at_mut((end - start) * n);
                    rest = tail;
                    let f = &fill_rows;
                    s.spawn(move || f(start, block));
                    start = end;
                }
            });
        }
        for i in 0..n {
            for j in 0..i {
                g.data[j * n + i] = g.data[i * n + j];
            }
        }
        g
    }

    /// The original naive Gram (serial dots, left-to-right association).
    /// Reference implementation for property tests and the before/after
    /// hotpath benches.
    pub fn gram_naive(&self) -> Mat {
        let n = self.rows;
        let k = self.cols;
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            let ri = &self.data[i * k..(i + 1) * k];
            for j in 0..=i {
                let rj = &self.data[j * k..(j + 1) * k];
                let mut acc = 0.0;
                for t in 0..k {
                    acc += ri[t] * rj[t];
                }
                *g.at_mut(i, j) = acc;
                *g.at_mut(j, i) = acc;
            }
        }
        g
    }

    /// Transposed Gram G = AᵀA (columns as vectors), computed directly
    /// from the row-major storage by rank-1 row updates — no transposed
    /// copy, and the upper-triangle update rows vectorize. Row strips
    /// are a **fixed size** (≈[`MIN_PAR_CHUNK`] elements), accumulated
    /// into per-strip partials merged in strip order, so the result
    /// depends only on the input — not on the pool size or core count;
    /// threads just drain the strip list. Association differs from a
    /// serial evaluation by reassociation noise only.
    pub fn gram_tr_with(&self, pool: &ThreadPool) -> Mat {
        let n = self.rows;
        let m = self.cols;
        let mut out = Mat::zeros(m, m);
        if n == 0 || m == 0 {
            return out;
        }
        let rows_per = (MIN_PAR_CHUNK / m).clamp(1, n);
        let strips = (n + rows_per - 1) / rows_per;
        let strip_gram = |si: usize| {
            let r0 = si * rows_per;
            let r1 = ((si + 1) * rows_per).min(n);
            let mut g = vec![0.0f64; m * m];
            for i in r0..r1 {
                let row = &self.data[i * m..(i + 1) * m];
                for j1 in 0..m {
                    let a = row[j1];
                    let grow = &mut g[j1 * m + j1..(j1 + 1) * m];
                    for (gv, &x) in grow.iter_mut().zip(&row[j1..]) {
                        *gv += a * x;
                    }
                }
            }
            g
        };
        // Strips are processed in width-sized waves so at most
        // pool.width() m×m partials are live at once, but every += into
        // `out` happens in ascending strip order — the accumulated value
        // is identical for every pool size and width cap.
        let wave = pool.width().max(1);
        let mut si0 = 0usize;
        while si0 < strips {
            let batch = (strips - si0).min(wave);
            let partials: Vec<Vec<f64>> = pool.scope_map(batch, |bi| strip_gram(si0 + bi));
            for p in &partials {
                for (o, &v) in out.data.iter_mut().zip(p) {
                    *o += v;
                }
            }
            si0 += batch;
        }
        for j1 in 0..m {
            for j2 in 0..j1 {
                out.data[j1 * m + j2] = out.data[j2 * m + j1];
            }
        }
        out
    }

    /// C = self · other (sequential; same kernel as [`Mat::matmul_with`]
    /// on one thread).
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        self.matmul_with(&ThreadPool::seq(), other)
    }

    /// C = self · other with C's rows computed in parallel blocks. The
    /// per-row ikj loop accumulates in ascending-k order regardless of
    /// blocking, so this is bit-identical to the sequential form. The
    /// inner j-loop is the [`simd`] axpy kernel: unconditional, so the
    /// lanes stay full (a data-dependent zero skip would block
    /// vectorization, and adding `±0.0·b` products from a `+0.0` start
    /// cannot flip a bit for finite operands — 0·∞/0·NaN is the only
    /// case where skip and no-skip differ).
    pub fn matmul_with(&self, pool: &ThreadPool, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::shape(format!(
                "matmul {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return Ok(c);
        }
        let a = &self.data;
        let b = &other.data;
        let fill = |first_row: usize, block: &mut [f64]| {
            for (bi, crow) in block.chunks_mut(n).enumerate() {
                let i = first_row + bi;
                for t in 0..k {
                    let av = a[i * k + t];
                    simd::axpy(crow, av, &b[t * n..(t + 1) * n]);
                }
            }
        };
        // ~m·k·n multiply-adds: below the chunk threshold thread spawns
        // dominate, so stay inline.
        if m * k * n < MIN_PAR_CHUNK {
            fill(0, &mut c.data);
        } else {
            pool.par_row_blocks(&mut c.data, n, fill);
        }
        Ok(c)
    }

    /// In-place A ← A + s·I.
    pub fn add_scaled_identity(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += s;
        }
    }

    /// In-place A ← c·A.
    pub fn scale(&mut self, c: f64) {
        for v in &mut self.data {
            *v *= c;
        }
    }
}

/// Cholesky factorization A = L·Lᵀ for symmetric positive-definite A.
/// Returns the lower-triangular L; errors on non-PD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        return Err(Error::shape("cholesky needs a square matrix"));
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::invariant(format!(
                        "matrix not positive definite (pivot {i}: {sum})"
                    )));
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// log₂ det(A) for symmetric positive-definite A via Cholesky.
pub fn log2_det_spd(a: &Mat) -> Result<f64> {
    let l = cholesky(a)?;
    let mut acc = 0.0;
    for i in 0..a.rows {
        acc += l.at(i, i).log2();
    }
    Ok(2.0 * acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gram_matches_manual() {
        // rows: [1,2], [3,4]
        let a = Mat::from_rows_f32(2, 2, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let g = a.gram();
        assert_eq!(g.at(0, 0), 5.0);
        assert_eq!(g.at(0, 1), 11.0);
        assert_eq!(g.at(1, 0), 11.0);
        assert_eq!(g.at(1, 1), 25.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows_f32(2, 3, &[1., 2., 3., 4., 5., 6.]).unwrap();
        let i3 = Mat::eye(3);
        let c = a.matmul(&i3).unwrap();
        assert_eq!(c.data, a.data);
        assert!(a.matmul(&Mat::eye(2)).is_err());
    }

    #[test]
    fn cholesky_known() {
        // A = [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let mut a = Mat::zeros(2, 2);
        a.data.copy_from_slice(&[4.0, 2.0, 2.0, 3.0]);
        let l = cholesky(&a).unwrap();
        assert!((l.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.at(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.at(1, 1) - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn log2det_diag() {
        // det(diag(2, 4, 8)) = 64 -> log2 = 6
        let mut a = Mat::zeros(3, 3);
        for (i, v) in [2.0, 4.0, 8.0].iter().enumerate() {
            *a.at_mut(i, i) = *v;
        }
        assert!((log2_det_spd(&a).unwrap() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::zeros(2, 2);
        a.data.copy_from_slice(&[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn log2det_matches_gram_identity_shift() {
        // A = I + G with G PSD -> det >= 1 -> log2 det >= 0
        let w = Mat::from_rows_f32(3, 5, &(0..15).map(|i| (i as f32) * 0.1).collect::<Vec<_>>()).unwrap();
        let mut a = w.gram();
        a.add_scaled_identity(1.0);
        assert!(log2_det_spd(&a).unwrap() > 0.0);
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut data = vec![0.0f32; rows * cols];
        rng.fill_gaussian(&mut data, 0.0, 1.0);
        Mat::from_rows_f32(rows, cols, &data).unwrap()
    }

    #[test]
    fn parallel_gram_bit_identical_to_sequential() {
        let a = random_mat(37, 53, 1);
        let seq = a.gram();
        let par = a.gram_with(&ThreadPool::new(4));
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn blocked_gram_close_to_naive() {
        let a = random_mat(23, 101, 2);
        let blocked = a.gram();
        let naive = a.gram_naive();
        // mixed tolerance: near-zero entries (cancellation) get an
        // absolute floor far above the reassociation error bound
        for (x, y) in blocked.data.iter().zip(&naive.data) {
            assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn gram_tr_matches_explicit_transpose() {
        let a = random_mat(41, 17, 3);
        // explicit transpose reference
        let mut t = Mat::zeros(17, 41);
        for i in 0..41 {
            for j in 0..17 {
                *t.at_mut(j, i) = a.at(i, j);
            }
        }
        let want = t.gram_naive();
        for pool in [ThreadPool::seq(), ThreadPool::new(3)] {
            let got = a.gram_tr_with(&pool);
            assert_eq!((got.rows, got.cols), (17, 17));
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "{x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_matmul_bit_identical() {
        let a = random_mat(19, 31, 4);
        let b = random_mat(31, 11, 5);
        let seq = a.matmul(&b).unwrap();
        let par = a.matmul_with(&ThreadPool::new(4), &b).unwrap();
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn degenerate_shapes_are_safe() {
        let empty = Mat::zeros(0, 5);
        assert_eq!(empty.gram().rows, 0);
        assert_eq!(empty.gram_tr_with(&ThreadPool::seq()).rows, 5);
        let a = Mat::zeros(3, 0);
        assert_eq!(a.gram_tr_with(&ThreadPool::seq()).rows, 0);
        let b = Mat::zeros(0, 4);
        let c = Mat::zeros(4, 0);
        assert_eq!(b.matmul(&Mat::zeros(5, 2)).is_err(), true);
        let prod = Mat::zeros(2, 4).matmul(&c).unwrap();
        assert_eq!((prod.rows, prod.cols), (2, 0));
    }
}
