//! Dense linear algebra substrate for the bit allocator.
//!
//! The coding length (paper Eq. 12) needs `log2 det(I + c·W·Wᵀ)` per
//! layer. The matrix is symmetric positive definite by construction, so
//! log-det comes from a Cholesky factorization: log det(A) = 2·Σ log Lᵢᵢ.
//! Sizes are small (the Gram side is min(n, m) ≤ a few hundred for the
//! zoo), so straightforward cache-friendly loops are plenty.

use crate::util::error::{Error, Result};

/// Row-major dense matrix of f64 (the determinant accumulates across
/// hundreds of multiplications — f32 would visibly drift).
#[derive(Debug, Clone)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if rows * cols != data.len() {
            return Err(Error::shape(format!(
                "{rows}x{cols} != {} elements",
                data.len()
            )));
        }
        Ok(Mat {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// Gram matrix G = A·Aᵀ (rows as vectors). ikj loop order for cache
    /// friendliness; G is symmetric so only the lower triangle is computed
    /// then mirrored.
    pub fn gram(&self) -> Mat {
        let n = self.rows;
        let k = self.cols;
        let mut g = Mat::zeros(n, n);
        for i in 0..n {
            let ri = &self.data[i * k..(i + 1) * k];
            for j in 0..=i {
                let rj = &self.data[j * k..(j + 1) * k];
                let mut acc = 0.0;
                for t in 0..k {
                    acc += ri[t] * rj[t];
                }
                *g.at_mut(i, j) = acc;
                *g.at_mut(j, i) = acc;
            }
        }
        g
    }

    /// C = self · other.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::shape(format!(
                "matmul {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for t in 0..k {
                let a = self.at(i, t);
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[t * n..(t + 1) * n];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * brow[j];
                }
            }
        }
        Ok(c)
    }

    /// In-place A ← A + s·I.
    pub fn add_scaled_identity(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += s;
        }
    }

    /// In-place A ← c·A.
    pub fn scale(&mut self, c: f64) {
        for v in &mut self.data {
            *v *= c;
        }
    }
}

/// Cholesky factorization A = L·Lᵀ for symmetric positive-definite A.
/// Returns the lower-triangular L; errors on non-PD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        return Err(Error::shape("cholesky needs a square matrix"));
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::invariant(format!(
                        "matrix not positive definite (pivot {i}: {sum})"
                    )));
                }
                *l.at_mut(i, j) = sum.sqrt();
            } else {
                *l.at_mut(i, j) = sum / l.at(j, j);
            }
        }
    }
    Ok(l)
}

/// log₂ det(A) for symmetric positive-definite A via Cholesky.
pub fn log2_det_spd(a: &Mat) -> Result<f64> {
    let l = cholesky(a)?;
    let mut acc = 0.0;
    for i in 0..a.rows {
        acc += l.at(i, i).log2();
    }
    Ok(2.0 * acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_matches_manual() {
        // rows: [1,2], [3,4]
        let a = Mat::from_rows_f32(2, 2, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let g = a.gram();
        assert_eq!(g.at(0, 0), 5.0);
        assert_eq!(g.at(0, 1), 11.0);
        assert_eq!(g.at(1, 0), 11.0);
        assert_eq!(g.at(1, 1), 25.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows_f32(2, 3, &[1., 2., 3., 4., 5., 6.]).unwrap();
        let i3 = Mat::eye(3);
        let c = a.matmul(&i3).unwrap();
        assert_eq!(c.data, a.data);
        assert!(a.matmul(&Mat::eye(2)).is_err());
    }

    #[test]
    fn cholesky_known() {
        // A = [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let mut a = Mat::zeros(2, 2);
        a.data.copy_from_slice(&[4.0, 2.0, 2.0, 3.0]);
        let l = cholesky(&a).unwrap();
        assert!((l.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.at(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.at(1, 1) - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn log2det_diag() {
        // det(diag(2, 4, 8)) = 64 -> log2 = 6
        let mut a = Mat::zeros(3, 3);
        for (i, v) in [2.0, 4.0, 8.0].iter().enumerate() {
            *a.at_mut(i, i) = *v;
        }
        assert!((log2_det_spd(&a).unwrap() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::zeros(2, 2);
        a.data.copy_from_slice(&[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn log2det_matches_gram_identity_shift() {
        // A = I + G with G PSD -> det >= 1 -> log2 det >= 0
        let w = Mat::from_rows_f32(3, 5, &(0..15).map(|i| (i as f32) * 0.1).collect::<Vec<_>>()).unwrap();
        let mut a = w.gram();
        a.add_scaled_identity(1.0);
        assert!(log2_det_spd(&a).unwrap() > 0.0);
    }
}
