//! Explicit-width SIMD microkernel for the matmul inner loop.
//!
//! The whole host matmul family (`Mat::matmul_with`, the fused packed
//! kernel in `deploy::fused`) reduces to one primitive: a row-scaled
//! accumulate `c[j] += a · b[j]` over a contiguous column panel. This
//! module provides that primitive three ways — AVX (4×f64 lanes, two
//! per iteration), SSE2 (2×f64 lanes, four per iteration), and an
//! 8-wide manually unrolled scalar form — selected at runtime and
//! gated behind the `simd` cargo feature.
//!
//! ## Why every path is bit-identical
//!
//! Each output element `c[j]` sees exactly one multiply and one add per
//! call, in the same order, whichever lane it lands in: the vector
//! paths use separate `mul` + `add` instructions (never FMA, which
//! fuses the intermediate rounding away), and IEEE 754 arithmetic is
//! deterministic per element. Vectorizing across `j` therefore cannot
//! change a single bit of any `c[j]` — there is no reassociation,
//! because each lane owns a distinct output element. The scalar
//! fallback unrolls 8 wide for the same reason the callers block by
//! rows: independent accumulators pipeline; the unroll factor is
//! likewise invisible in the results. `axpy` vs [`axpy_scalar`]
//! identity is property-tested in rust/tests/fused_kernel.rs, so the
//! `core::arch` path can never silently diverge.

/// c[j] += a · b[j] for every j. Runtime-dispatched: AVX when the CPU
/// has it, SSE2 otherwise (baseline on x86_64), the unrolled scalar
/// form on other targets or with the `simd` feature disabled.
#[inline]
pub fn axpy(c: &mut [f64], a: f64, b: &[f64]) {
    debug_assert_eq!(c.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // SAFETY: sse2 is part of the x86_64 baseline; the avx call is
        // guarded by the runtime feature probe.
        unsafe {
            if use_avx() {
                x86::axpy_avx(c, a, b);
            } else {
                x86::axpy_sse2(c, a, b);
            }
        }
        return;
    }
    #[allow(unreachable_code)]
    axpy_scalar(c, a, b)
}

/// The scalar reference form of [`axpy`]: 8 independent update slots per
/// iteration. Public so the identity property test (and the bench
/// harness) can pin the vector paths against it.
#[inline]
pub fn axpy_scalar(c: &mut [f64], a: f64, b: &[f64]) {
    debug_assert_eq!(c.len(), b.len());
    let mut cc = c.chunks_exact_mut(8);
    let mut bc = b.chunks_exact(8);
    for (cw, bw) in (&mut cc).zip(&mut bc) {
        cw[0] += a * bw[0];
        cw[1] += a * bw[1];
        cw[2] += a * bw[2];
        cw[3] += a * bw[3];
        cw[4] += a * bw[4];
        cw[5] += a * bw[5];
        cw[6] += a * bw[6];
        cw[7] += a * bw[7];
    }
    for (cv, &bv) in cc.into_remainder().iter_mut().zip(bc.remainder()) {
        *cv += a * bv;
    }
}

/// One-time AVX probe, cached in a process-wide flag (0 = unprobed,
/// 1 = sse2 only, 2 = avx). Shared with the `quant::kernel` slice
/// quantizers so the whole crate dispatches off one probe.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
pub(crate) fn use_avx() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static LEVEL: AtomicU8 = AtomicU8::new(0);
    match LEVEL.load(Ordering::Relaxed) {
        0 => {
            let l = if std::is_x86_feature_detected!("avx") { 2 } else { 1 };
            LEVEL.store(l, Ordering::Relaxed);
            l == 2
        }
        l => l == 2,
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use core::arch::x86_64::*;

    /// SAFETY: caller must ensure the CPU supports AVX and
    /// `c.len() == b.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy_avx(c: &mut [f64], a: f64, b: &[f64]) {
        // SAFETY: the documented contract holds (AVX present, equal
        // lengths); every unaligned load/store below stays inside
        // `[0, n)` of its slice by the loop bounds.
        unsafe {
            let n = c.len();
            let av = _mm256_set1_pd(a);
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut j = 0usize;
            while j + 8 <= n {
                let r0 = _mm256_add_pd(
                    _mm256_loadu_pd(cp.add(j)),
                    _mm256_mul_pd(av, _mm256_loadu_pd(bp.add(j))),
                );
                let r1 = _mm256_add_pd(
                    _mm256_loadu_pd(cp.add(j + 4)),
                    _mm256_mul_pd(av, _mm256_loadu_pd(bp.add(j + 4))),
                );
                _mm256_storeu_pd(cp.add(j), r0);
                _mm256_storeu_pd(cp.add(j + 4), r1);
                j += 8;
            }
            if j + 4 <= n {
                let r = _mm256_add_pd(
                    _mm256_loadu_pd(cp.add(j)),
                    _mm256_mul_pd(av, _mm256_loadu_pd(bp.add(j))),
                );
                _mm256_storeu_pd(cp.add(j), r);
                j += 4;
            }
            while j < n {
                *cp.add(j) += a * *bp.add(j);
                j += 1;
            }
        }
    }

    /// SAFETY: caller must ensure `c.len() == b.len()` (sse2 is the
    /// x86_64 baseline).
    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(c: &mut [f64], a: f64, b: &[f64]) {
        // SAFETY: sse2 is the x86_64 baseline and the caller guarantees
        // equal-length slices; loop bounds keep every access in range.
        unsafe {
            let n = c.len();
            let av = _mm_set1_pd(a);
            let cp = c.as_mut_ptr();
            let bp = b.as_ptr();
            let mut j = 0usize;
            while j + 8 <= n {
                let r0 =
                    _mm_add_pd(_mm_loadu_pd(cp.add(j)), _mm_mul_pd(av, _mm_loadu_pd(bp.add(j))));
                let r1 = _mm_add_pd(
                    _mm_loadu_pd(cp.add(j + 2)),
                    _mm_mul_pd(av, _mm_loadu_pd(bp.add(j + 2))),
                );
                let r2 = _mm_add_pd(
                    _mm_loadu_pd(cp.add(j + 4)),
                    _mm_mul_pd(av, _mm_loadu_pd(bp.add(j + 4))),
                );
                let r3 = _mm_add_pd(
                    _mm_loadu_pd(cp.add(j + 6)),
                    _mm_mul_pd(av, _mm_loadu_pd(bp.add(j + 6))),
                );
                _mm_storeu_pd(cp.add(j), r0);
                _mm_storeu_pd(cp.add(j + 2), r1);
                _mm_storeu_pd(cp.add(j + 4), r2);
                _mm_storeu_pd(cp.add(j + 6), r3);
                j += 8;
            }
            while j < n {
                *cp.add(j) += a * *bp.add(j);
                j += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dispatch_matches_scalar_bit_for_bit() {
        let mut rng = Rng::new(0x51AD);
        // ragged lengths around the 8/4/2-wide boundaries
        for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 100, 1023] {
            let mut bf = vec![0.0f32; n];
            rng.fill_gaussian(&mut bf, 0.0, 1.0);
            let b: Vec<f64> = bf.iter().map(|&v| v as f64).collect();
            let mut c0: Vec<f64> = (0..n).map(|i| (i as f64) * 0.25 - 3.0).collect();
            let mut c1 = c0.clone();
            let a = rng.gaussian_f32(0.0, 2.0) as f64;
            axpy(&mut c0, a, &b);
            axpy_scalar(&mut c1, a, &b);
            assert_eq!(c0, c1, "axpy diverged from scalar at n={n}");
        }
    }

    #[test]
    fn zero_and_special_scalars() {
        let b = vec![1.5f64, -2.25, 0.0, -0.0, 7.125];
        for a in [0.0f64, -0.0, 1.0, -3.5] {
            let mut c0 = vec![0.5f64; 5];
            let mut c1 = c0.clone();
            axpy(&mut c0, a, &b);
            axpy_scalar(&mut c1, a, &b);
            assert_eq!(c0, c1);
        }
    }
}
