//! Benchmark harness substrate (criterion is not offline-available).
//!
//! `cargo bench` targets are `harness = false` binaries that call into
//! this module: warmup, timed iterations, and robust statistics (median /
//! mean / p95 / stddev), printed in a criterion-like one-line format plus
//! an optional machine-readable CSV appended to `target/bench_results.csv`.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }

    pub fn print(&self) {
        println!(
            "bench {:<44} {:>10}  med {:>10}  p95 {:>10}  ±{:>9}  ({} iters)",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.median_s),
            fmt_dur(self.p95_s),
            fmt_dur(self.stddev_s),
            self.iters
        );
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

pub struct Bencher {
    /// Minimum measurement budget per benchmark.
    pub budget: Duration,
    /// Max iterations regardless of budget (slow end-to-end benches).
    pub max_iters: usize,
    pub warmup_iters: usize,
    /// Substring filter (`--only` in the bench binaries): names not
    /// containing it are skipped entirely — no warmup, no samples — and
    /// return an `iters == 0` placeholder the caller drops before
    /// writing a baseline. Lets CI time a single row (e.g. the serve
    /// rows for the tracing-overhead gate) without paying for the rest.
    pub only: Option<String>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(3),
            max_iters: 1000,
            warmup_iters: 2,
            only: None,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            budget: Duration::from_millis(500),
            max_iters: 10,
            warmup_iters: 1,
            only: None,
        }
    }

    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        if let Some(pat) = &self.only {
            if !name.contains(pat.as_str()) {
                return compute_stats(name, &[]);
            }
        }
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let stats = compute_stats(name, &samples);
        stats.print();
        append_csv(&stats);
        stats
    }
}

/// Robust statistics over raw duration samples (seconds). Public so
/// other subsystems with their own sample streams — the serve latency
/// histogram — can land in the same JSON baseline as the benches.
pub fn stats_from_samples(name: &str, samples: &[f64]) -> Stats {
    compute_stats(name, samples)
}

fn compute_stats(name: &str, samples: &[f64]) -> Stats {
    if samples.is_empty() {
        return Stats {
            name: name.to_string(),
            iters: 0,
            mean_s: 0.0,
            median_s: 0.0,
            p95_s: 0.0,
            stddev_s: 0.0,
            min_s: 0.0,
            max_s: 0.0,
        };
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: sorted[n / 2],
        p95_s: sorted[(n as f64 * 0.95) as usize % n.max(1)],
        stddev_s: var.sqrt(),
        min_s: sorted[0],
        max_s: sorted[n - 1],
    }
}

fn append_csv(s: &Stats) {
    use std::io::Write as _;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("target/bench_results.csv")
    {
        let _ = writeln!(
            f,
            "{},{},{:.9},{:.9},{:.9},{:.9}",
            s.name, s.iters, s.mean_s, s.median_s, s.p95_s, s.stddev_s
        );
    }
}

/// Write a set of bench stats as a machine-readable JSON baseline (the
/// committed `BENCH_host.json` evidence file). Hand-rolled like
/// `util::json` — serde is not offline-available.
pub fn write_json(path: &std::path::Path, stats: &[Stats]) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{{")?;
    for (i, s) in stats.iter().enumerate() {
        let comma = if i + 1 == stats.len() { "" } else { "," };
        writeln!(
            f,
            "  \"{}\": {{\"iters\": {}, \"mean_s\": {:e}, \"median_s\": {:e}, \"p95_s\": {:e}, \"stddev_s\": {:e}, \"min_s\": {:e}, \"max_s\": {:e}}}{}",
            s.name, s.iters, s.mean_s, s.median_s, s.p95_s, s.stddev_s, s.min_s, s.max_s, comma
        )?;
    }
    writeln!(f, "}}")?;
    Ok(())
}

/// Locate the artifacts directory for bench binaries (env override first).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("REPRO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = compute_stats("t", &[1.0, 2.0, 3.0]);
        assert_eq!(s.iters, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(s.median_s, 2.0);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
    }

    #[test]
    fn bencher_runs_at_least_three() {
        let b = Bencher {
            budget: Duration::from_millis(1),
            max_iters: 100,
            warmup_iters: 0,
            only: None,
        };
        let mut count = 0usize;
        let s = b.run("noop", || count += 1);
        assert!(s.iters >= 3);
        assert!(count >= 3);
    }

    #[test]
    fn only_filter_skips_without_running() {
        let b = Bencher {
            budget: Duration::from_millis(1),
            max_iters: 100,
            warmup_iters: 2,
            only: Some("serve".into()),
        };
        let mut ran = 0usize;
        let skipped = b.run("host/unrelated_bench", || ran += 1);
        assert_eq!(skipped.iters, 0, "filtered row must not execute");
        assert_eq!(ran, 0, "not even warmup");
        let kept = b.run("host/serve_smoke", || ran += 1);
        assert!(kept.iters >= 3);
    }

    #[test]
    fn json_baseline_roundtrips_through_parser() {
        let stats = vec![
            compute_stats("host/a_bench", &[0.001, 0.002, 0.003]),
            compute_stats("host/b_bench", &[1.5, 2.5]),
        ];
        let path = std::env::temp_dir().join(format!(
            "bench_host_json_test_{}.json",
            std::process::id()
        ));
        write_json(&path, &stats).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let j = crate::util::json::parse(&text).unwrap();
        let a = j.get("host/a_bench").unwrap();
        let mean = a.get("mean_s").unwrap().as_f64().unwrap();
        assert!((mean - 0.002).abs() < 1e-12, "mean {mean}");
        assert_eq!(a.get("iters").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(2e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(3e-3).ends_with("ms"));
        assert!(fmt_dur(2.5).ends_with('s'));
    }
}
