//! `repro` — the attention-round CLI.
//!
//! ```text
//! repro info                          artifact + model inventory
//! repro evaluate  --model M           FP32 top-1 on the eval split
//! repro evaluate  --artifact DIR      score a packed artifact's top-1
//! repro quantize  --model M --wbits B [--abits B] [--method ...]
//! repro allocate  --model M --bits 3,4,5,6      Algorithm-1 bit allocation
//! repro pack      --model M [--mixed|--wbits B] [--abits B] [--pack-out D]
//!                 [--chunks N]                  chunked v3 layout + manifest
//! repro qat       --model M --steps N           budgeted STE-QAT
//! repro serve     --requests N [--batch B --max-wait-us U --queue-depth D]
//!                 [--workers N --deadline-ms D --chaos <scenario|matrix>]
//! repro serve     --artifact DIR [--progressive]  serve a packed artifact
//!                 (progressive streams a chunked v3 artifact in while serving)
//! repro reproduce <table1..5|fig2|fig3|fig4|fig5|all>
//! ```
//!
//! Every subcommand takes `--artifacts DIR` (default `artifacts`),
//! `--backend auto|pjrt|host`, `--profile quick|paper`, and repeatable
//! `--set key=value` overrides (see coordinator::config). With
//! `--backend auto` (the default) a checkout without artifacts runs the
//! whole pipeline on the host backend against the synthetic model.

#![deny(unsafe_op_in_unsafe_fn)]

use std::path::PathBuf;

use attention_round::coordinator::capture::capture;
use attention_round::coordinator::config::CalibConfig;
use attention_round::coordinator::experiments::{self, Ctx};
use attention_round::coordinator::pipeline::{
    quantize_and_eval, resolve_act_bits, resolve_uniform_bits, QuantSpec,
};
use attention_round::coordinator::{evaluate, qat, state};
use attention_round::deploy;
use attention_round::io::manifest::Manifest;
use attention_round::mixed;
use attention_round::quant::observer::{observe_with, ActQuantParams};
use attention_round::quant::rounding::Rounding;
use attention_round::report::pct;
use attention_round::serve;
use attention_round::trace;
use attention_round::util::args::Parser;
use attention_round::util::{error::Error, error::Result, logging};

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parser() -> Parser {
    Parser::new("repro", "Attention Round PTQ — paper reproduction CLI")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("backend", Some("auto"), "execution backend: auto|pjrt|host")
        .opt("out", Some("results"), "output directory for reports")
        .opt("profile", Some("quick"), "calibration profile: quick|paper")
        .opt("set", None, "config override key=value (comma-separated)")
        .opt("model", None, "model name")
        .opt("models", None, "comma-separated model subset for reproduce")
        .opt("wbits", Some("4"), "weight bits")
        .opt("abits", None, "activation bits (omit = FP32 activations)")
        .opt("method", Some("attention"), "rounding: nearest|floor|ceil|stochastic|adaround|attention")
        .opt("bits", Some("3,4,5,6"), "bit list for allocate")
        .opt("eps2", Some("0.001"), "coding-length error tolerance ε²")
        .opt("steps", Some("300"), "QAT training steps")
        .opt("taus", Some("0,0.25,0.5,0.75,1"), "τ values for fig2")
        .opt("requests", Some("1024"), "serve: load-generator request count")
        .opt("batch", Some("16"), "serve: micro-batch size (pad target)")
        .opt("max-wait-us", Some("200"), "serve: micro-batch coalesce window (µs)")
        .opt("queue-depth", Some("64"), "serve: admission bound (reject beyond)")
        .opt("producers", Some("4"), "serve: load-generator producer threads")
        .opt("worker-width", Some("0"), "serve: per-worker inner-parallelism cap (0 = split the pool across the fleet)")
        .opt("workers", Some("1"), "serve: fleet size (supervised workers off the one queue)")
        .opt("deadline-ms", None, "serve: per-request deadline in ms (expired requests are shed, never served stale)")
        .opt("chaos", None, "serve: fault-injection scenario (worker-crash|slow-consumer|latency-spike|burst|mixed-size|slow-loader) or 'matrix' for all")
        .opt("artifact", None, "packed artifact dir (serve or evaluate a saved quantized model)")
        .opt("pack-out", None, "pack: artifact output dir (default <out>/qmodels/<model>-<tag>)")
        .opt("chunks", None, "pack: emit the chunked v3 layout (qmodel.qpak + manifest.json) split into N layer-range chunks")
        .opt("min-depth", Some("1"), "pack: min_runnable_depth recorded in the chunk manifest (chunks needed before progressive serving answers)")
        .opt("trace", None, "write a Chrome trace-event JSON of this run to the given path (load in Perfetto / chrome://tracing)")
        .flag("mixed", "pack: Algorithm-1 per-layer bits from --bits/--eps2 instead of uniform --wbits")
        .flag("progressive", "serve: progressively load a chunked (v3) artifact, answering partial-depth while chunks stream in")
        .flag("no-verify", "serve: skip the bit-identity check against direct forward")
        .flag("save", "persist the quantized model under <out>/qmodels/ (packed v2 artifact)")
        .flag("help", "print usage")
}

fn build_cfg(a: &attention_round::util::args::Args) -> Result<CalibConfig> {
    let mut cfg = CalibConfig::profile(a.get("profile")?)?;
    if let Ok(sets) = a.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| Error::config(format!("--set wants key=value, got {kv:?}")))?;
            cfg.set(k.trim(), v.trim())?;
        }
    }
    Ok(cfg)
}

fn run(argv: &[String]) -> Result<()> {
    let p = parser();
    let a = p.parse(argv)?;
    if a.has_flag("help") || a.positional.is_empty() {
        println!("{}", p.usage());
        println!("subcommands: info | evaluate | quantize | allocate | pack | qat | serve | reproduce <target>");
        return Ok(());
    }
    let cmd = a.positional[0].as_str();
    let artifacts = a.get("artifacts")?.to_string();

    let trace_path = a.get("trace").ok().map(PathBuf::from);
    if trace_path.is_some() {
        if trace::available() {
            trace::enable();
            trace::set_thread_label("main");
        } else {
            log::warn!(
                "--trace requested but this binary was built without the \
                 `trace` feature; no trace will be written"
            );
        }
    }

    let result = match cmd {
        "info" => info(&artifacts, &a),
        "evaluate" => cmd_evaluate(&artifacts, &a),
        "quantize" => cmd_quantize(&artifacts, &a),
        "allocate" => cmd_allocate(&artifacts, &a),
        "pack" => cmd_pack(&artifacts, &a),
        "qat" => cmd_qat(&artifacts, &a),
        "serve" => cmd_serve(&artifacts, &a),
        "reproduce" => cmd_reproduce(&artifacts, &a),
        other => Err(Error::config(format!("unknown subcommand {other:?}"))),
    };

    // export even when the subcommand failed: a trace of the run that
    // died is exactly the one worth looking at
    if let Some(path) = trace_path {
        if trace::available() {
            match trace::chrome::export(&path) {
                Ok(n) => println!("wrote {n} trace events to {}", path.display()),
                Err(e) => log::warn!("trace export to {} failed: {e}", path.display()),
            }
        }
    }
    result
}

fn info(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let have = std::path::Path::new(artifacts).join("manifest.json").exists();
    // honor --backend exactly like load_ctx: host describes the synthetic
    // manifest, pjrt requires real artifacts (a bad path must error, not
    // silently fall back), auto picks by availability
    let m = match a.get("backend")? {
        "host" => Manifest::synthetic(),
        "pjrt" => Manifest::load(artifacts)?,
        _ if have => Manifest::load(artifacts)?,
        _ => {
            println!("no artifacts at {artifacts}: showing the synthetic host-backend manifest");
            Manifest::synthetic()
        }
    };
    println!(
        "artifacts: {} (scan_k={}, calib_batch={}, eval_batch={})",
        m.root.display(),
        m.scan_k,
        m.dataset.calib_batch,
        m.dataset.eval_batch
    );
    println!(
        "dataset: {} classes, {}x{}x{}",
        m.dataset.num_classes, m.dataset.image_hw, m.dataset.image_hw, m.dataset.channels
    );
    for model in &m.models {
        let params: usize = model.layers.iter().map(|l| l.params).sum();
        println!(
            "  {:<14} fp_acc={:.2}%  layers={}  params={}  qat={}",
            model.name,
            model.fp_acc * 100.0,
            model.layers.len(),
            params,
            model.qat_step.is_some() || model.w_files.is_empty()
        );
    }
    Ok(())
}

fn load_ctx(artifacts: &str, a: &attention_round::util::args::Args) -> Result<Ctx> {
    let cfg = build_cfg(a)?;
    let out = a.get("out")?;
    match a.get("backend")? {
        "pjrt" => Ctx::new(artifacts, cfg, out),
        "host" => Ctx::synthetic(cfg, out),
        "auto" => Ctx::auto(artifacts, cfg, out),
        other => Err(Error::config(format!(
            "unknown backend {other:?} (expected auto|pjrt|host)"
        ))),
    }
}

/// `--model` if given, else the context's first default model.
fn pick_model(ctx: &Ctx, a: &attention_round::util::args::Args) -> Result<String> {
    ctx.primary_model(a.get("model").ok())
}

fn cmd_evaluate(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    if let Ok(dir) = a.get("artifact") {
        // score a packed artifact directly, through the same staging
        // path the serve subsystem drives (dequant-on-the-fly on host)
        let art = deploy::PackedModel::load(std::path::Path::new(dir))?;
        let acc = evaluate::evaluate_artifact(
            ctx.backend.as_ref(), &ctx.manifest, &art, &ctx.eval,
        )?;
        println!(
            "{} [{}] from artifact {dir}: top-1 {}{} (packed at {}, FP {})",
            art.model,
            ctx.backend.name(),
            pct(acc),
            if art.act_params.is_some() { " (actq)" } else { "" },
            pct(art.acc),
            pct(art.fp_acc)
        );
        return Ok(());
    }
    let model_name = pick_model(&ctx, a)?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;
    let acc = evaluate::evaluate(
        ctx.backend.as_ref(), &ctx.manifest, &model, &model.weights, &ctx.eval,
    )?;
    println!(
        "{} [{}]: FP32 top-1 {} (manifest said {})",
        model.info.name,
        ctx.backend.name(),
        pct(acc),
        pct(model.info.fp_acc)
    );
    Ok(())
}

fn cmd_quantize(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    let mut cfg = ctx.cfg.clone();
    cfg.method = Rounding::parse(a.get("method")?)
        .ok_or_else(|| Error::config("bad --method"))?;
    let model_name = pick_model(&ctx, a)?;
    let loaded = ctx.backend.load_model(&ctx.manifest, &model_name)?;
    let wbits: u8 = a.get_usize("wbits")? as u8;
    let abits = a.get("abits").ok().map(|s| s.parse::<u8>()).transpose()
        .map_err(|_| Error::config("bad --abits"))?;
    let spec = QuantSpec {
        model: model_name.to_string(),
        wbits: resolve_uniform_bits(&loaded, wbits),
        abits,
    };
    let out = quantize_and_eval(
        ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg, &ctx.calib, &ctx.eval,
    )?;
    println!(
        "{} {}/{} via {:?} on {}: top-1 {}% (FP {}%), {:.1}s",
        model_name,
        wbits,
        abits.map(|b| b.to_string()).unwrap_or_else(|| "32".into()),
        cfg.method,
        ctx.backend.platform(),
        pct(out.acc),
        pct(out.fp_acc),
        out.wall_s
    );
    for l in &out.per_layer {
        log::info!(
            "  {:<18} {}b s={:.5} loss {:.3e} -> {:.3e}",
            l.name, l.bits, l.scale, l.first_loss, l.last_loss
        );
    }
    if a.has_flag("save") {
        let tag = format!(
            "{}w{}a{}",
            cfg.method.name(),
            wbits,
            abits.map(|b| b.to_string()).unwrap_or_else(|| "fp".into())
        );
        let dir = attention_round::coordinator::state::default_dir(
            &ctx.out_dir, &model_name, &tag,
        );
        attention_round::coordinator::state::save(&out, &dir)?;
        println!("saved quantized model to {}", dir.display());
    }
    println!("--- pipeline metrics ---\n{}", ctx.backend.metrics().report());
    Ok(())
}

fn cmd_allocate(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    let model_name = pick_model(&ctx, a)?;
    let model = ctx.backend.load_model(&ctx.manifest, &model_name)?;
    let bits: Vec<u8> = a
        .get("bits")?
        .split(',')
        .map(|s| s.trim().parse::<u8>().map_err(|_| Error::config("bad --bits")))
        .collect::<Result<_>>()?;
    let eps2 = a.get_f64("eps2")?;
    let alloc = mixed::allocate(&model.info.layers, &model.weights, &bits, eps2)?;
    println!(
        "{}: bit list {:?}, size {}",
        model.info.name,
        bits,
        mixed::format_size_mb(alloc.size_bytes)
    );
    for (l, (&b, &len)) in model
        .info
        .layers
        .iter()
        .zip(alloc.bits.iter().zip(alloc.lengths.iter()))
    {
        println!(
            "  {:<20} {:>6} params  L={:>8.1} bits  -> {}b{}{}",
            l.name,
            l.params,
            len,
            b,
            if l.pinned_8bit { " (pinned)" } else { "" },
            if l.downsample { " (downsample)" } else { "" }
        );
    }
    Ok(())
}

/// `repro pack` — quantize a model and write a **packed v2 artifact**:
/// integer codes bit-packed at each layer's width (`deploy::bitpack`),
/// header with per-layer scale/shape/checksum and, under `--mixed`, the
/// Algorithm-1 coding-length provenance. Prints the per-layer
/// compression table and writes `<out>/pack.json` (the CI
/// `artifact-smoke` job asserts ratio < 0.5 from it).
fn cmd_pack(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    let mut cfg = ctx.cfg.clone();
    cfg.method = Rounding::parse(a.get("method")?)
        .ok_or_else(|| Error::config("bad --method"))?;
    let model_name = pick_model(&ctx, a)?;
    let loaded = ctx.backend.load_model(&ctx.manifest, &model_name)?;
    let abits = a.get("abits").ok().map(|s| s.parse::<u8>()).transpose()
        .map_err(|_| Error::config("bad --abits"))?;
    let (wbits, lengths, bits_desc) = if a.has_flag("mixed") {
        let bit_list: Vec<u8> = a
            .get("bits")?
            .split(',')
            .map(|s| s.trim().parse::<u8>().map_err(|_| Error::config("bad --bits")))
            .collect::<Result<_>>()?;
        let eps2 = a.get_f64("eps2")?;
        let alloc =
            mixed::allocate(&loaded.info.layers, &loaded.weights, &bit_list, eps2)?;
        let desc = bit_list
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join("-");
        (alloc.bits, Some(alloc.lengths), format!("mix{desc}"))
    } else {
        let wb = a.get_usize("wbits")? as u8;
        (resolve_uniform_bits(&loaded, wb), None, format!("w{wb}"))
    };
    let spec = QuantSpec {
        model: model_name.clone(),
        wbits,
        abits,
    };
    let out = quantize_and_eval(
        ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg, &ctx.calib, &ctx.eval,
    )?;
    // the pack span lives here (not in deploy/) — kernel-adjacent
    // modules stay clock-free per the analyzer's AR003 scope
    let pack_span = trace::span(trace::Category::Pack, format!("pack:{model_name}"));
    let packed = deploy::PackedModel::from_outcome(&out, lengths.as_deref())?;
    let tag = format!(
        "pack-{}-{}a{}",
        cfg.method.name(),
        bits_desc,
        abits.map(|b| b.to_string()).unwrap_or_else(|| "fp".into())
    );
    let dir = match a.get("pack-out") {
        Ok(d) => PathBuf::from(d),
        Err(_) => state::default_dir(&ctx.out_dir, &model_name, &tag),
    };
    let chunked = a
        .get("chunks")
        .ok()
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|_| Error::config("bad --chunks"))?;
    let chunk_manifest = match chunked {
        Some(n) => Some(packed.save_chunked(&dir, n, a.get_usize("min-depth")?)?),
        None => {
            packed.save(&dir)?;
            None
        }
    };
    drop(pack_span);
    println!("{}", deploy::compression_table(&packed).render());
    println!(
        "{model_name} via {:?} on {}: top-1 {}% (FP {}%), {:.1}s",
        cfg.method,
        ctx.backend.platform(),
        pct(out.acc),
        pct(out.fp_acc),
        out.wall_s
    );
    let summary = deploy::summarize(&packed);
    let json = summary.to_json();
    println!("{json}");
    let json_path = ctx.out_dir.join("pack.json");
    std::fs::write(&json_path, &json)?;
    println!("wrote {}", json_path.display());
    println!(
        "packed artifact: {} ({} -> {} weight bytes, ratio {:.3}, {:.2} bits/weight)",
        dir.display(),
        summary.f32_bytes,
        summary.packed_bytes,
        summary.ratio,
        summary.effective_bits
    );
    if let Some(m) = &chunk_manifest {
        println!(
            "chunked artifact: {} chunks over {} layers, min_runnable_depth {}, \
             {} qpak bytes ({})",
            m.chunks.len(),
            m.full_depth(),
            m.min_runnable_depth,
            m.total_bytes(),
            dir.join("manifest.json").display()
        );
    }
    Ok(())
}

fn cmd_qat(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    let model_name = pick_model(&ctx, a)?;
    let train = ctx.train_split()?;
    let out = qat::run_qat(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &model_name,
        a.get_usize("wbits")? as u8,
        a.get("abits").ok().and_then(|s| s.parse().ok()).unwrap_or(4),
        a.get_usize("steps")?,
        1e-3,
        &train,
        &ctx.eval,
        7,
    )?;
    println!(
        "QAT {} [{}]: top-1 {}% (FP {}%), {} steps / {} samples, {:.1}s",
        model_name,
        ctx.backend.name(),
        pct(out.acc),
        pct(out.fp_acc),
        out.steps,
        out.train_samples_seen,
        out.wall_s
    );
    Ok(())
}

/// Observer-calibrate an activation-quant deployment config for the
/// plain-pipeline serve path (`serve --abits B` with no artifact):
/// capture layer inputs over the calibration split and observe each
/// with the configured observer, first/last pinned to 8-bit like the
/// quantization pipeline.
fn derive_actq(
    ctx: &Ctx,
    model_name: &str,
    abits: u8,
) -> Result<(Vec<ActQuantParams>, Vec<u8>)> {
    if !(2..=16).contains(&abits) {
        return Err(Error::config(format!(
            "--abits {abits} out of range 2..=16"
        )));
    }
    let model = ctx.backend.load_model(&ctx.manifest, model_name)?;
    let bits = resolve_act_bits(&model, abits);
    let cache = capture(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &model,
        &model.weights,
        &ctx.calib,
        ctx.cfg.calib_samples,
    )?;
    let mut scratch = Vec::new();
    let mut params = Vec::with_capacity(model.num_layers());
    for li in 0..model.num_layers() {
        let x = cache.peek(li)?;
        params.push(observe_with(
            x.data(),
            bits[li],
            ctx.cfg.observer,
            &mut scratch,
        )?);
    }
    Ok((params, bits))
}

fn print_serve_report(ctx: &Ctx, report: &serve::ServeReport) -> Result<()> {
    println!("{}", report.table().render());
    let json = report.to_json();
    println!("{json}");
    let json_path = ctx.out_dir.join("serve.json");
    std::fs::write(&json_path, &json)?;
    println!("wrote {}", json_path.display());
    // the windowed telemetry goes to its own file so the serve.json
    // schema (frozen by golden-key tests) stays untouched
    let tl_path = ctx.out_dir.join("serve.timeline.json");
    std::fs::write(&tl_path, report.timeline.to_json())?;
    println!("wrote {}", tl_path.display());
    Ok(())
}

/// Judge a chaos run against its scenario's SLO; a failed verdict is a
/// hard error so CI chaos jobs exit nonzero.
fn print_chaos_verdict(
    cfg: &serve::ServeConfig,
    report: &serve::ServeReport,
) -> Result<()> {
    if let Some(spec) = &cfg.chaos {
        let v = serve::judge(spec, report);
        println!("{}", v.line());
        if !v.pass {
            return Err(Error::invariant(format!(
                "chaos scenario {:?} failed its SLO (lost {}, p99 {:.3}ms vs \
                 target {:.0}ms)",
                spec.name,
                v.lost,
                v.p99_s * 1e3,
                v.p99_target_s * 1e3
            )));
        }
    }
    Ok(())
}

/// The `serve: clean shutdown` line the CI smoke jobs grep for, now with
/// the full terminal-state accounting.
fn shutdown_line(report: &serve::ServeReport) -> String {
    format!(
        "serve: clean shutdown ({} completed, {} rejected, {} expired, {} errors, \
         {} restarts, accounting {}, {:.1} req/s)",
        report.completed,
        report.rejected,
        report.expired,
        report.errors,
        report.restarts,
        if report.accounting_balanced() { "balanced" } else { "UNBALANCED" },
        report.throughput_rps
    )
}

/// `repro serve` — the batched-serving load generator: keeps a prepared
/// model hot behind the bounded request queue, drives `--requests`
/// synthetic requests through the micro-batching worker, and reports
/// p50/p95/p99 latency + sustained throughput as a table and as JSON
/// (stdout and `<out>/serve.json`, which the CI smoke jobs assert on).
///
/// Two model sources: `--artifact DIR` serves a saved packed quantized
/// model (with its recorded activation-quant deployment config;
/// dequant-on-the-fly on the host backend), while the plain path serves
/// the backend's own weights — with `--abits B` behind an
/// observer-calibrated activation-quant config (the actq deployment
/// path), FP32 activations otherwise.
fn cmd_serve(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let ctx = load_ctx(artifacts, a)?;
    let deadline = a
        .get("deadline-ms")
        .ok()
        .map(|s| s.parse::<u64>())
        .transpose()
        .map_err(|_| Error::config("bad --deadline-ms"))?
        .map(std::time::Duration::from_millis);
    let mut cfg = serve::ServeConfig {
        max_batch: a.get_usize("batch")?.max(1),
        max_wait: std::time::Duration::from_micros(a.get_usize("max-wait-us")? as u64),
        queue_depth: a.get_usize("queue-depth")?.max(1),
        workers: a.get_usize("workers")?.max(1),
        worker_width: a.get_usize("worker-width")?,
        deadline,
        verify: !a.has_flag("no-verify"),
        actq: None,
        chaos: None,
        fleet: serve::FleetConfig::default(),
    };
    let requests = a.get_usize("requests")?;
    let producers = a.get_usize("producers")?.max(1);
    let chaos_arg = a.get("chaos").ok().map(str::to_string);
    if let Some(name) = chaos_arg.as_deref() {
        if name != "matrix" {
            cfg.chaos = Some(serve::ChaosSpec::scenario(name, serve::CHAOS_SEED)?);
            println!("chaos scenario {name:?} armed (seed {})", serve::CHAOS_SEED);
        }
    }

    if let Ok(dir) = a.get("artifact") {
        if chaos_arg.as_deref() == Some("matrix") {
            return Err(Error::config(
                "--chaos matrix runs against the backend's own model; pass a \
                 single scenario name with --artifact",
            ));
        }
        if a.has_flag("progressive") {
            // the chunked artifact carries its own deployment config and
            // the progressive model applies it; an --abits override would
            // deploy a different model than the operator packed
            if a.get("abits").is_ok() {
                return Err(Error::config(
                    "--abits conflicts with --progressive: the chunked artifact \
                     already carries its deployment config (re-pack with a \
                     different --abits instead)",
                ));
            }
            println!(
                "serving {requests} requests ({producers} producers) progressively \
                 from chunked artifact {dir} on [{}], batch ≤{} / wait {}µs / queue {}",
                ctx.backend.platform(),
                cfg.max_batch,
                cfg.max_wait.as_micros(),
                cfg.queue_depth
            );
            let report = serve::run_progressive_load_generator(
                ctx.backend.as_ref(),
                &ctx.manifest,
                std::path::Path::new(dir),
                &cfg,
                requests,
                producers,
            )?;
            print_serve_report(&ctx, &report)?;
            print_chaos_verdict(&cfg, &report)?;
            if cfg.verify {
                println!(
                    "verified: converged progressive outputs bit-identical to \
                     the dequantized direct forward"
                );
            }
            println!(
                "progressive: converged to full depth {} ({} partial-depth rows served)",
                report.resident_depth, report.depth_served_partial
            );
            println!("{}", shutdown_line(&report));
            return Ok(());
        }
        let art = deploy::PackedModel::load(std::path::Path::new(dir))?;
        if let Ok(s) = a.get("abits") {
            // A saved W+A artifact already carries its deployment
            // config (which run_artifact_load_generator applies);
            // silently serving something else would deploy a different
            // model than the operator asked for.
            if art.act_params.is_some() {
                return Err(Error::config(
                    "--abits conflicts with --artifact: this artifact already \
                     carries its activation deployment config (re-pack with a \
                     different --abits instead)",
                ));
            }
            let abits: u8 = s.parse().map_err(|_| Error::config("bad --abits"))?;
            cfg.actq = Some(derive_actq(&ctx, &art.model, abits)?);
            println!(
                "serving through forward_actq at {abits}b activations \
                 (observer-calibrated; weights-only artifact)"
            );
        }
        println!(
            "serving {requests} requests ({producers} producers) from packed artifact \
             {dir} ({} via {}, {}{}) on [{}], batch ≤{} / wait {}µs / queue {}",
            art.model,
            art.method,
            mixed::format_size_mb(art.payload_bytes() as f64),
            if art.act_params.is_some() { ", actq" } else { "" },
            ctx.backend.platform(),
            cfg.max_batch,
            cfg.max_wait.as_micros(),
            cfg.queue_depth
        );
        let report = serve::run_artifact_load_generator(
            ctx.backend.as_ref(),
            &ctx.manifest,
            &art,
            &cfg,
            requests,
            producers,
        )?;
        print_serve_report(&ctx, &report)?;
        print_chaos_verdict(&cfg, &report)?;
        if cfg.verify {
            println!(
                "verified: artifact serve outputs bit-identical to the \
                 dequantized direct forward"
            );
        }
        println!("{}", shutdown_line(&report));
        return Ok(());
    }

    if a.has_flag("progressive") {
        return Err(Error::config(
            "--progressive needs --artifact DIR (a chunked v3 artifact)",
        ));
    }
    let model_name = pick_model(&ctx, a)?;
    if let Ok(s) = a.get("abits") {
        let abits: u8 = s.parse().map_err(|_| Error::config("bad --abits"))?;
        cfg.actq = Some(derive_actq(&ctx, &model_name, abits)?);
        println!("serving through forward_actq at {abits}b activations (observer-calibrated)");
    }
    if chaos_arg.as_deref() == Some("matrix") {
        println!(
            "chaos matrix: {} scenarios × {requests} requests on {} [{}]",
            serve::SCENARIOS.len(),
            model_name,
            ctx.backend.platform()
        );
        let results = serve::run_matrix(
            ctx.backend.as_ref(),
            &ctx.manifest,
            &model_name,
            &cfg,
            requests,
            producers,
            serve::CHAOS_SEED,
        )?;
        let mut entries = Vec::new();
        let mut failed = Vec::new();
        for (spec, report, verdict) in &results {
            println!("{}", report.table().render());
            println!("{}", verdict.line());
            if !verdict.pass {
                failed.push(spec.name.clone());
            }
            entries.push(verdict.to_json());
        }
        let json = format!(
            "{{\n  \"chaos_matrix\": [\n    {}\n  ]\n}}",
            entries.join(",\n    ")
        );
        println!("{json}");
        let json_path = ctx.out_dir.join("chaos.json");
        std::fs::write(&json_path, &json)?;
        println!("wrote {}", json_path.display());
        if !failed.is_empty() {
            return Err(Error::invariant(format!(
                "chaos matrix: scenarios failed their SLO: {failed:?}"
            )));
        }
        println!(
            "chaos matrix: all {} scenarios passed their SLO",
            results.len()
        );
        return Ok(());
    }
    println!(
        "serving {requests} requests ({} producers) on {} [{}], {} worker(s), \
         batch ≤{} / wait {}µs / queue {}{}",
        producers,
        model_name,
        ctx.backend.platform(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait.as_micros(),
        cfg.queue_depth,
        cfg.deadline
            .map(|d| format!(" / deadline {}ms", d.as_millis()))
            .unwrap_or_default()
    );
    let report = serve::run_load_generator(
        ctx.backend.as_ref(),
        &ctx.manifest,
        &model_name,
        &cfg,
        requests,
        producers,
    )?;
    print_serve_report(&ctx, &report)?;
    print_chaos_verdict(&cfg, &report)?;
    if cfg.verify {
        println!("verified: serve outputs bit-identical to direct forward");
    }
    println!("{}", shutdown_line(&report));
    Ok(())
}

fn cmd_reproduce(artifacts: &str, a: &attention_round::util::args::Args) -> Result<()> {
    let target = a
        .positional
        .get(1)
        .ok_or_else(|| Error::config("reproduce needs a target (table1..5, fig2, fig3..5, all)"))?
        .clone();
    let ctx = load_ctx(artifacts, a)?;
    let models_owned: Vec<String> = match a.get("models") {
        Ok(s) => s.split(',').map(|m| m.trim().to_string()).collect(),
        // tolerate zoo subsets: artifacts may be built for fewer models
        // on constrained machines (see Makefile knobs); the synthetic
        // context substitutes its own model list
        Err(_) => ctx.default_models(),
    };
    let models: Vec<&str> = models_owned.iter().map(String::as_str).collect();
    let primary = models
        .first()
        .copied()
        .ok_or_else(|| Error::config("no models available for reproduce"))?;
    let eps2 = a.get_f64("eps2")?;
    let taus: Vec<f32> = a
        .get("taus")?
        .split(',')
        .map(|s| s.trim().parse::<f32>().map_err(|_| Error::config("bad --taus")))
        .collect::<Result<_>>()?;
    let qat_steps = a.get_usize("steps")?;

    let run_one = |t: &str| -> Result<()> {
        match t {
            "table1" => experiments::table1(&ctx, &models).map(|_| ()),
            "table2" => experiments::table2(&ctx, &models).map(|_| ()),
            "table3" => experiments::table3(&ctx, qat_steps).map(|_| ()),
            "table4" => experiments::table4(&ctx, &models, eps2).map(|_| ()),
            "table5" => experiments::table5(&ctx).map(|_| ()),
            "fig2" => experiments::fig2(&ctx, &[primary], &taus).map(|_| ()),
            "fig3" => experiments::fig_alloc(&ctx, primary, eps2).map(|_| ()),
            "fig4" => experiments::fig_alloc(
                &ctx,
                models.get(1).copied().unwrap_or(primary),
                eps2,
            )
            .map(|_| ()),
            "fig5" => experiments::fig_alloc(
                &ctx,
                models.get(2).copied().unwrap_or(primary),
                eps2,
            )
            .map(|_| ()),
            other => Err(Error::config(format!("unknown target {other:?}"))),
        }
    };
    if target == "all" {
        for t in [
            "fig3", "fig4", "fig5", "table5", "table1", "table2", "table3",
            "table4", "fig2",
        ] {
            run_one(t)?;
        }
    } else {
        run_one(&target)?;
    }
    println!("--- pipeline metrics ---\n{}", ctx.backend.metrics().report());
    Ok(())
}
