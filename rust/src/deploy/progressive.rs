//! Progressive partial-depth serving over a chunked (v3) artifact.
//!
//! A v2 artifact is all-or-nothing: a fleet worker cannot answer a
//! single request until every layer is loaded and verified — the
//! cold-start bottleneck for the million-user north star (ROADMAP
//! item 2). The v3 chunked layout (`deploy::manifest` +
//! `PackedModel::save_chunked`) makes layers independently decodable
//! units, so load order becomes a serving policy:
//!
//! * [`ProgressiveModel`] opens the artifact's metadata only
//!   ([`crate::deploy::artifact::load_v3_meta`]) — no payload reads —
//!   and exposes a chunk table where each chunk is absent until a
//!   loader thread verifies it ([`ProgressiveModel::load_chunk`]).
//! * As soon as the first `min_runnable_depth` chunks are resident the
//!   model answers **truncated-depth** forwards: features through the
//!   deepest resident prefix (the exact `layer_pass` chain the packed
//!   host path runs), global-average-pooled if 4-D, read out through a
//!   nearest-class-mean head calibrated at that depth from the same
//!   prototype draw the synthetic head uses (`PROTO_SEED` /
//!   `PROTO_SAMPLES`). Answers are tagged with the depth that served
//!   them.
//! * Remaining chunks hot-swap in lock-free: each chunk slot is a
//!   write-once cell the loader fills *before* publishing it with a
//!   single release-store of the resident count. Readers
//!   acquire-load the count and never block on the loader — no Mutex
//!   anywhere on the forward path, same reader discipline as
//!   `PackedHostForward`.
//!
//! Once every chunk is resident, a forward is **bit-identical** to
//! [`crate::deploy::dequant::PackedHostForward`] on the same artifact:
//! both walk the same payloads through the same `layer_pass` in the
//! same order (asserted in rust/tests/progressive.rs).

use std::io::{Read as _, Seek, SeekFrom};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

use crate::backend::host::{avg_pool, fake_quant_act, layer_pass, HostWeights};
use crate::backend::host::{PROTO_SAMPLES, PROTO_SEED};
use crate::backend::PreparedModel;
use crate::coordinator::model::LoadedModel;
use crate::data::synth;
use crate::deploy::artifact::{decode_v3_payload, ChunkedMeta, Payload};
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::threadpool::{self, ThreadPool};

/// How long a blocked reader naps between residency checks. Short
/// enough that first-answer latency is dominated by chunk decode, long
/// enough not to spin a core.
const WAIT_NAP: Duration = Duration::from_micros(200);

/// Truncated-depth readout calibrated at one chunk boundary:
/// `W[:,c] = μ_c`, `b_c = −‖μ_c‖²/2` over the prototype draw — the
/// same closed-form nearest-class-mean head the synthetic model
/// builder calibrates for the full feature stack.
struct Head {
    /// `[features, classes]`, row-major like every layer weight.
    w: Tensor,
    /// Per-class bias.
    b: Vec<f32>,
}

/// A chunked artifact being served while it loads.
///
/// Readers (`forward*`) and the single loader (`load_chunk`, called
/// with ascending chunk ids from one thread) synchronize only through
/// `resident`: the loader fills the write-once chunk slot and head
/// slot first, then release-stores the new resident count; readers
/// acquire-load the count and touch only slots at indices below it.
pub struct ProgressiveModel<'a> {
    pool: &'static ThreadPool,
    model: &'a LoadedModel,
    meta: ChunkedMeta,
    /// `layer → (chunk index, index within that chunk's payload vec)`.
    layer_chunk: Vec<(usize, usize)>,
    /// Write-once decoded payloads, one slot per chunk.
    chunks: Vec<OnceLock<Vec<Payload>>>,
    /// Write-once partial-depth readouts; slot `k` serves residency
    /// `k + 1` chunks. The last slot stays empty — full residency uses
    /// the model's real classifier head.
    heads: Vec<OnceLock<Head>>,
    /// Number of verified-resident chunks (monotone 0 → chunk count).
    resident: AtomicUsize,
    /// Rows answered at less than full depth (serve telemetry).
    partial_rows: AtomicU64,
    /// Set by the loader on a fatal load error so blocked readers fail
    /// fast instead of waiting forever.
    failed: AtomicBool,
}

impl<'a> ProgressiveModel<'a> {
    /// Validate the chunked metadata against the execution model and
    /// stage an empty chunk table. Reads no payload bytes.
    pub fn open(model: &'a LoadedModel, meta: ChunkedMeta) -> Result<Self> {
        let k = model.num_layers();
        if meta.layers.len() != k {
            return Err(Error::shape(format!(
                "artifact {}: {} layers, model {} has {k}",
                meta.model,
                meta.layers.len(),
                model.info.name
            )));
        }
        for (li, (pl, w)) in meta.layers.iter().zip(&model.weights).enumerate() {
            if pl.name != model.info.layers[li].name {
                return Err(Error::shape(format!(
                    "layer {li}: artifact has {:?}, model has {:?}",
                    pl.name, model.info.layers[li].name
                )));
            }
            if pl.shape != w.shape() {
                return Err(Error::shape(format!(
                    "{}: artifact shape {:?}, model shape {:?}",
                    pl.name,
                    pl.shape,
                    w.shape()
                )));
            }
            if pl.shape.len() != 2 {
                return Err(Error::shape(format!(
                    "{}: host backend executes 2-D (conv-as-matmul) weights, \
                     got {:?} — use the PJRT backend for real checkpoints",
                    pl.name, pl.shape
                )));
            }
        }
        let nc = meta.manifest.chunks.len();
        let mut layer_chunk = vec![(0usize, 0usize); k];
        for (ci, c) in meta.manifest.chunks.iter().enumerate() {
            for li in c.layer_start..c.layer_end {
                layer_chunk[li] = (ci, li - c.layer_start);
            }
        }
        Ok(ProgressiveModel {
            pool: threadpool::global(),
            model,
            meta,
            layer_chunk,
            chunks: (0..nc).map(|_| OnceLock::new()).collect(),
            heads: (0..nc).map(|_| OnceLock::new()).collect(),
            resident: AtomicUsize::new(0),
            partial_rows: AtomicU64::new(0),
            failed: AtomicBool::new(false),
        })
    }

    /// The chunked metadata this model serves from.
    pub fn meta(&self) -> &ChunkedMeta {
        &self.meta
    }

    /// Total chunks in the artifact.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Chunks that must be resident before the first answer.
    pub fn min_runnable(&self) -> usize {
        self.meta.manifest.min_runnable_depth
    }

    /// Verified-resident chunk count right now.
    pub fn resident_chunks(&self) -> usize {
        self.resident.load(Ordering::Acquire)
    }

    /// Layers servable right now (the deepest resident prefix).
    pub fn resident_depth(&self) -> usize {
        self.meta.manifest.depth_at(self.resident_chunks())
    }

    /// The model's full layer depth.
    pub fn full_depth(&self) -> usize {
        self.meta.layers.len()
    }

    /// Rows answered at less than full depth so far.
    pub fn partial_rows(&self) -> u64 {
        self.partial_rows.load(Ordering::Relaxed)
    }

    /// Whether the loader declared a fatal error.
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// Declare the load failed: blocked readers return an error
    /// instead of napping forever. Called by the serve-side loader
    /// when [`ProgressiveModel::load_chunk`] errors.
    pub fn mark_failed(&self) {
        self.failed.store(true, Ordering::Release);
    }

    /// Read, verify, and publish chunk `k` from `qmodel.qpak`. Chunks
    /// must be loaded in ascending order by a single loader thread —
    /// `k` must equal the current resident count. For every chunk but
    /// the last this also calibrates the partial-depth readout head at
    /// the chunk's boundary *before* publishing, so a reader that
    /// observes residency `k + 1` always finds its head.
    pub fn load_chunk(&self, k: usize) -> Result<()> {
        let rc = self.resident.load(Ordering::Acquire);
        if k != rc {
            return Err(Error::invariant(format!(
                "progressive loader: chunk {k} loaded out of order \
                 ({rc} chunks resident)"
            )));
        }
        let c = &self.meta.manifest.chunks[k];
        let off = self.meta.manifest.chunk_offset(k);
        let len = c.bytes as usize;
        let mut f = std::fs::File::open(&self.meta.qpak)?;
        f.seek(SeekFrom::Start(off))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf).map_err(|e| {
            Error::parse(format!(
                "qmodel.qpak: chunk {}: reading {len} bytes at offset {off}: \
                 {e} (truncated?)",
                c.id
            ))
        })?;
        let sum = format!("{:016x}", crate::deploy::artifact::fnv1a64(&buf));
        if sum != c.checksum {
            return Err(Error::parse(format!(
                "qmodel.qpak: chunk {}: checksum mismatch ({sum} vs manifest {})",
                c.id, c.checksum
            )));
        }
        let mut payloads = Vec::with_capacity(c.layers());
        let mut pos = 0usize;
        for li in c.layer_start..c.layer_end {
            let n = self.meta.payload_lens[li];
            payloads.push(decode_v3_payload(&self.meta, li, &buf[pos..pos + n])?);
            pos += n;
        }
        if self.chunks[k].set(payloads).is_err() {
            return Err(Error::invariant(format!(
                "progressive loader: chunk {k} published twice"
            )));
        }
        if k + 1 < self.chunks.len() {
            let head = self.build_head(c.layer_end)?;
            if self.heads[k].set(head).is_err() {
                return Err(Error::invariant(format!(
                    "progressive loader: head {k} published twice"
                )));
            }
        }
        self.resident.store(k + 1, Ordering::Release);
        Ok(())
    }

    /// Block (napping) until at least `min_runnable_depth` chunks are
    /// resident; returns the resident count observed.
    fn wait_runnable(&self) -> Result<usize> {
        let need = self.min_runnable();
        loop {
            if self.is_failed() {
                return Err(Error::runtime(
                    "progressive model: chunk loader failed; artifact not servable",
                ));
            }
            let rc = self.resident.load(Ordering::Acquire);
            if rc >= need {
                return Ok(rc);
            }
            std::thread::sleep(WAIT_NAP);
        }
    }

    /// Block (napping) until every chunk is resident.
    fn wait_full(&self) -> Result<()> {
        loop {
            if self.is_failed() {
                return Err(Error::runtime(
                    "progressive model: chunk loader failed; artifact not servable",
                ));
            }
            if self.resident.load(Ordering::Acquire) == self.chunks.len() {
                return Ok(());
            }
            std::thread::sleep(WAIT_NAP);
        }
    }

    /// Run the first `depth` layers off the resident payloads —
    /// exactly the `PackedHostForward::run` loop, so full depth is
    /// bit-identical to the non-progressive packed path.
    fn run_prefix(
        &self,
        x: &Tensor,
        depth: usize,
        mut record: Option<&mut Vec<Tensor>>,
        actq: Option<(&[ActQuantParams], &[u8])>,
    ) -> Result<Tensor> {
        let mut cur = x.clone();
        for li in 0..depth {
            let layer = &self.model.info.layers[li];
            let pl = &self.meta.layers[li];
            let nm = (pl.shape[0], pl.shape[1]);
            let (ci, within) = self.layer_chunk[li];
            let payloads = self.chunks[ci].get().ok_or_else(|| {
                Error::invariant(format!(
                    "progressive forward: layer {li} read before chunk {ci} resident"
                ))
            })?;
            let weights = match &payloads[within] {
                Payload::Packed(bytes) => HostWeights::Packed {
                    bytes,
                    bits: pl.bits,
                    scale: pl.scale,
                    scales: pl.scales.as_deref(),
                },
                Payload::F32(t) => HostWeights::Dense(t.data()),
            };
            let bias = self
                .model
                .biases
                .get(li)
                .map(|b| b.data())
                .unwrap_or(&[]);
            let tf: Option<Box<dyn Fn(&mut [f32])>> = actq.map(|(params, bits)| {
                let (p, b) = (params[li], bits[li]);
                Box::new(move |a: &mut [f32]| fake_quant_act(a, &p, b))
                    as Box<dyn Fn(&mut [f32])>
            });
            // scope the pass so its borrow of `cur` ends before
            // reassignment
            let next = {
                let pass =
                    layer_pass(self.pool, layer, weights, nm, bias, &cur, tf.as_deref(), true)?;
                if let Some(rec) = record.as_mut() {
                    rec.push(Tensor::new(pass.in_shape.clone(), pass.a.to_vec())?);
                }
                pass.out.ok_or_else(|| {
                    Error::invariant("layer_pass(want_out=true) returned no output")
                })?
            };
            cur = next;
        }
        Ok(cur)
    }

    /// Calibrate the nearest-class-mean readout at `depth` layers:
    /// the synthetic head construction, verbatim, over the features
    /// the resident prefix produces for the fixed prototype draw.
    fn build_head(&self, depth: usize) -> Result<Head> {
        let (imgs, labels) = synth::generate(PROTO_SAMPLES, PROTO_SEED);
        let mut feats = self.run_prefix(&imgs, depth, None, None)?;
        if feats.shape().len() == 4 {
            feats = avg_pool(&feats)?;
        }
        let f = feats.shape()[1];
        let k = self.model.num_layers();
        let hm = self.model.info.layers[k - 1].wshape[1];
        let mut sums = vec![0.0f64; f * hm];
        let mut counts = vec![0usize; hm];
        for (bi, &lab) in labels.iter().enumerate() {
            let c = lab as usize % hm;
            counts[c] += 1;
            for (j, &v) in feats.data()[bi * f..(bi + 1) * f].iter().enumerate() {
                sums[j * hm + c] += v as f64;
            }
        }
        let mut wh = vec![0.0f32; f * hm];
        let mut bh = vec![0.0f32; hm];
        for c in 0..hm {
            if counts[c] == 0 {
                continue;
            }
            let inv = 1.0 / counts[c] as f64;
            let mut norm2 = 0.0f64;
            for j in 0..f {
                let mu = sums[j * hm + c] * inv;
                wh[j * hm + c] = mu as f32;
                norm2 += mu * mu;
            }
            bh[c] = (-0.5 * norm2) as f32;
        }
        Ok(Head {
            w: Tensor::new(vec![f, hm], wh)?,
            b: bh,
        })
    }

    /// Apply the chunk-boundary head to prefix features (pooled if
    /// 4-D): `logits = f · W + b`, f64 accumulate like `layer_pass`.
    fn partial_logits(&self, feats: Tensor, rc: usize) -> Result<Tensor> {
        let head = self.heads[rc - 1].get().ok_or_else(|| {
            Error::invariant(format!(
                "progressive forward: no readout head at residency {rc}"
            ))
        })?;
        let feats = if feats.shape().len() == 4 {
            avg_pool(&feats)?
        } else {
            feats
        };
        let (rows, f) = (feats.shape()[0], feats.shape()[1]);
        let hm = head.b.len();
        if head.w.shape()[0] != f {
            return Err(Error::shape(format!(
                "progressive head expects {} features, prefix produces {f}",
                head.w.shape()[0]
            )));
        }
        let (fd, wd) = (feats.data(), head.w.data());
        let mut out = vec![0.0f32; rows * hm];
        for i in 0..rows {
            let frow = &fd[i * f..(i + 1) * f];
            let orow = &mut out[i * hm..(i + 1) * hm];
            for c in 0..hm {
                let mut acc = head.b[c] as f64;
                for (j, &v) in frow.iter().enumerate() {
                    acc += v as f64 * wd[j * hm + c] as f64;
                }
                orow[c] = acc as f32;
            }
        }
        Tensor::new(vec![rows, hm], out)
    }

    /// Forward at an explicit residency (`rc` chunks, all verified
    /// resident): the deterministic core of progressive serving,
    /// `pub` so tests can pin a depth. Returns the logits and the
    /// layer depth that served them.
    pub fn forward_at_chunks(
        &self,
        x: &Tensor,
        rc: usize,
        actq: Option<(&[ActQuantParams], &[u8])>,
    ) -> Result<(Tensor, usize)> {
        if rc == 0 || rc > self.resident_chunks() {
            return Err(Error::invariant(format!(
                "forward_at_chunks: {rc} chunks requested, {} resident",
                self.resident_chunks()
            )));
        }
        let depth = self.meta.manifest.depth_at(rc);
        let full = self.full_depth();
        if depth == full {
            let logits = self.run_prefix(x, full, None, actq)?;
            return Ok((logits, full));
        }
        let feats = self.run_prefix(x, depth, None, actq)?;
        let logits = self.partial_logits(feats, rc)?;
        self.partial_rows
            .fetch_add(logits.shape()[0] as u64, Ordering::Relaxed);
        Ok((logits, depth))
    }

    /// Forward at whatever depth is resident right now, waiting (if
    /// needed) for the first `min_runnable_depth` chunks. Returns the
    /// logits and the `depth_served` tag.
    pub fn forward_with_depth(&self, x: &Tensor) -> Result<(Tensor, usize)> {
        let rc = self.wait_runnable()?;
        self.forward_at_chunks(x, rc, None)
    }

    /// A [`PreparedModel`] view for fleet workers; cheap, one per
    /// worker.
    pub fn handle(&'a self) -> ProgressiveHandle<'a> {
        ProgressiveHandle { pm: self }
    }
}

/// Per-worker [`PreparedModel`] over a shared [`ProgressiveModel`] —
/// the handle `serve::fleet` workers drive. Forwards serve at the
/// current resident depth; `collect` (capture semantics) waits for
/// full residency since it must record every layer.
pub struct ProgressiveHandle<'a> {
    pm: &'a ProgressiveModel<'a>,
}

impl PreparedModel for ProgressiveHandle<'_> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        Ok(self.pm.forward_with_depth(x)?.0)
    }

    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor> {
        let k = self.pm.full_depth();
        if act_params.len() != k || act_bits.len() != k {
            return Err(Error::shape(format!(
                "expected {k} activation params/bits, got {}/{}",
                act_params.len(),
                act_bits.len()
            )));
        }
        let rc = self.pm.wait_runnable()?;
        Ok(self
            .pm
            .forward_at_chunks(x, rc, Some((act_params, act_bits)))?
            .0)
    }

    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        self.pm.wait_full()?;
        let mut rec = Vec::with_capacity(self.pm.full_depth());
        let logits = self
            .pm
            .run_prefix(x, self.pm.full_depth(), Some(&mut rec), None)?;
        Ok((rec, logits))
    }

    fn resident_depth(&self) -> Option<usize> {
        Some(self.pm.resident_depth())
    }
}
