//! Fused execution of a packed artifact on the host backend.
//!
//! The naive way to serve a packed model is to dequantize every layer
//! up front — which materializes a second full-f32 copy of the model
//! and gives back the memory the packing saved. [`PackedHostForward`]
//! never dequantizes a layer at all: each forward borrows the layer's
//! payload via [`PackedModel::layer_view`] and hands `layer_pass` a
//! `HostWeights::Packed` provider, so the fused dequant-matmul kernel
//! (`deploy::fused`) streams the bitstream through cache-sized panels
//! inside the matmul tile. Lossless-fallback f32 layers are borrowed
//! in place as `HostWeights::Dense`. A whole-f32 layer therefore never
//! exists anywhere, for any model size.
//!
//! The in-tile dequant is the same `s · q` multiply the rounding
//! kernels finalize with (see `deploy::artifact`), and `layer_pass` is
//! the exact per-layer forward `run_graph` uses — so a forward off the
//! packed representation is **bit-identical** to quantize-then-forward
//! with the original tensors (asserted end-to-end by
//! `rust/tests/deploy.rs` and in this module).
//!
//! The handle holds no mutable state — panel scratch is owned by the
//! kernel's row-block workers — so it is lock-free `Send + Sync` and
//! N fleet workers serving one artifact never serialize on it (the
//! PR-6 `Mutex<Scratch>` bottleneck is gone).

use crate::backend::host::{fake_quant_act, layer_pass, HostWeights};
use crate::backend::PreparedModel;
use crate::coordinator::model::LoadedModel;
use crate::deploy::artifact::{LayerView, PackedModel};
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::threadpool::{self, ThreadPool};

/// A packed artifact staged for host serving: codes stay packed and
/// are multiplied in place by the fused dequant-matmul kernel.
pub struct PackedHostForward<'a> {
    pool: &'static ThreadPool,
    model: &'a LoadedModel,
    artifact: &'a PackedModel,
}

impl<'a> PackedHostForward<'a> {
    /// Validate the artifact against the execution model (layer count,
    /// per-layer shapes, 2-D conv-as-matmul weights) and stage it.
    pub fn new(model: &'a LoadedModel, artifact: &'a PackedModel) -> Result<Self> {
        artifact.check_matches(model)?;
        for l in &artifact.layers {
            if l.shape.len() != 2 {
                return Err(Error::shape(format!(
                    "{}: host backend executes 2-D (conv-as-matmul) weights, \
                     got {:?} — use the PJRT backend for real checkpoints",
                    l.name, l.shape
                )));
            }
        }
        Ok(PackedHostForward {
            pool: threadpool::global(),
            model,
            artifact,
        })
    }

    fn run(
        &self,
        x: &Tensor,
        mut record: Option<&mut Vec<Tensor>>,
        actq: Option<(&[ActQuantParams], &[u8])>,
    ) -> Result<Tensor> {
        let mut cur = x.clone();
        for (li, layer) in self.model.info.layers.iter().enumerate() {
            let pl = &self.artifact.layers[li];
            let nm = (pl.shape[0], pl.shape[1]);
            let weights = match self.artifact.layer_view(li)? {
                LayerView::Packed {
                    bytes,
                    bits,
                    scale,
                    scales,
                } => HostWeights::Packed {
                    bytes,
                    bits,
                    scale,
                    scales,
                },
                LayerView::F32(t) => HostWeights::Dense(t.data()),
            };
            let bias = self
                .model
                .biases
                .get(li)
                .map(|b| b.data())
                .unwrap_or(&[]);
            let tf: Option<Box<dyn Fn(&mut [f32])>> = actq.map(|(params, bits)| {
                let (p, b) = (params[li], bits[li]);
                Box::new(move |a: &mut [f32]| fake_quant_act(a, &p, b))
                    as Box<dyn Fn(&mut [f32])>
            });
            // scope the pass so its borrow of `cur` ends before
            // reassignment
            let next = {
                let pass =
                    layer_pass(self.pool, layer, weights, nm, bias, &cur, tf.as_deref(), true)?;
                if let Some(rec) = record.as_mut() {
                    rec.push(Tensor::new(pass.in_shape.clone(), pass.a.to_vec())?);
                }
                pass.out.ok_or_else(|| {
                    Error::invariant("layer_pass(want_out=true) returned no output")
                })?
            };
            cur = next;
        }
        Ok(cur)
    }
}

impl PreparedModel for PackedHostForward<'_> {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.run(x, None, None)
    }

    fn forward_actq(
        &self,
        x: &Tensor,
        act_params: &[ActQuantParams],
        act_bits: &[u8],
    ) -> Result<Tensor> {
        let k = self.model.num_layers();
        if act_params.len() != k || act_bits.len() != k {
            return Err(Error::shape(format!(
                "expected {k} activation params/bits, got {}/{}",
                act_params.len(),
                act_bits.len()
            )));
        }
        self.run(x, None, Some((act_params, act_bits)))
    }

    fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        let mut rec = Vec::with_capacity(self.model.num_layers());
        let logits = self.run(x, Some(&mut rec), None)?;
        Ok((rec, logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, HostBackend};
    use crate::coordinator::pipeline::{LayerOutcome, Outcome};
    use crate::data::synth;
    use crate::io::manifest::Manifest;
    use crate::quant::rounding::{nearest, Rounding};
    use crate::quant::scale::absmax_scale;
    use crate::quant::QGrid;

    /// Quantize every layer of a loaded model with nearest/absmax at
    /// `bits` (the static-rounding pipeline path) and wrap it in an
    /// outcome + packed artifact.
    fn packed_from_model(
        model: &LoadedModel,
        bits: u8,
        with_acts: bool,
    ) -> (PackedModel, Vec<Tensor>) {
        let mut per_layer = Vec::new();
        let mut qweights = Vec::new();
        for (l, w) in model.info.layers.iter().zip(&model.weights) {
            let s = absmax_scale(w.data(), bits);
            let grid = QGrid::signed(bits, s).unwrap();
            qweights.push(
                Tensor::new(w.shape().to_vec(), nearest(w.data(), &grid)).unwrap(),
            );
            per_layer.push(LayerOutcome {
                name: l.name.clone(),
                bits,
                scale: s,
                first_loss: f32::NAN,
                last_loss: f32::NAN,
            });
        }
        let k = model.num_layers();
        let outcome = Outcome {
            model: model.info.name.clone(),
            method: Rounding::Nearest,
            acc: 0.0,
            fp_acc: 0.0,
            per_layer,
            qweights: qweights.clone(),
            act_params: with_acts.then(|| {
                vec![ActQuantParams { scale: 0.05, zero: 0.0 }; k]
            }),
            act_bits: with_acts.then(|| vec![8u8; k]),
            wall_s: 0.0,
        };
        (PackedModel::from_outcome(&outcome, None).unwrap(), qweights)
    }

    #[test]
    fn packed_forward_matches_dequantized_prepare_bit_for_bit() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let (art, qweights) = packed_from_model(&model, 4, false);
        let packed = PackedHostForward::new(&model, &art).unwrap();
        let direct = be.prepare(&model, &qweights).unwrap();
        let (x, _) = synth::generate(5, 2024);
        let got = packed.forward(&x).unwrap();
        let want = direct.forward(&x).unwrap();
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.data(), want.data(), "packed forward must be bit-identical");
    }

    #[test]
    fn packed_forward_actq_and_collect_match() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let (art, qweights) = packed_from_model(&model, 4, true);
        let packed = PackedHostForward::new(&model, &art).unwrap();
        let direct = be.prepare(&model, &qweights).unwrap();
        let (x, _) = synth::generate(3, 77);
        let params = art.act_params.clone().unwrap();
        let bits = art.act_bits.clone().unwrap();
        let got = packed.forward_actq(&x, &params, &bits).unwrap();
        let want = direct.forward_actq(&x, &params, &bits).unwrap();
        assert_eq!(got.data(), want.data());
        let (rec_p, log_p) = packed.collect(&x).unwrap();
        let (rec_d, log_d) = direct.collect(&x).unwrap();
        assert_eq!(log_p.data(), log_d.data());
        assert_eq!(rec_p.len(), rec_d.len());
        for (a, b) in rec_p.iter().zip(&rec_d) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn arity_mismatch_rejected() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let model = be.load_model(&manifest, "synthnet").unwrap();
        let (art, _) = packed_from_model(&model, 4, false);
        let packed = PackedHostForward::new(&model, &art).unwrap();
        let (x, _) = synth::generate(2, 5);
        assert!(packed
            .forward_actq(&x, &[ActQuantParams { scale: 0.1, zero: 0.0 }], &[8])
            .is_err());
    }
}
