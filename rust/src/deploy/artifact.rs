//! Versioned quantized-model artifact format (single directory).
//!
//! ## v2 (written by this module)
//!
//! ```text
//! <dir>/qmodel.json      header: format_version 2, model/method/acc,
//!                        per-layer {name, bits, scale, shape, encoding,
//!                        file, packed_bytes, checksum, coding_length},
//!                        optional act_params + act_bits (the activation
//!                        deployment config), method provenance
//! <dir>/NN_<layer>.qbin  LSB-first packed integer codes (deploy::bitpack)
//! <dir>/NN_<layer>.q.npy f32 fallback for layers that are not exactly
//!                        on a 2–8-bit grid (legacy tensors, wide grids)
//! ```
//!
//! A layer's codes are grid offsets `q − lo` with `lo = −2^{b−1}`
//! (signed symmetric grid, like [`crate::quant::QGrid::signed`]);
//! dequantization computes `s · (code + lo)` — the **same single f32
//! multiply** every rounding kernel finalizes with, so a dequantized
//! layer is bit-identical to the tensor that was packed. Packing
//! verifies this round-trip element-by-element and falls back to the
//! f32 encoding for any layer where it does not hold, so `save ∘ load`
//! is lossless for every input, packed or not.
//!
//! ## v3 (chunked, written by [`PackedModel::save_chunked`])
//!
//! ```text
//! <dir>/qmodel.json    header: format_version 3, same per-layer
//!                      metadata as v2 plus per-layer payload_bytes +
//!                      checksum (every layer, both encodings)
//! <dir>/qmodel.qpak    every layer payload concatenated in layer order
//!                      (packed bitstreams verbatim; f32 fallback layers
//!                      as raw little-endian f32), mmap-friendly
//! <dir>/manifest.json  contiguous layer-range chunks over the .qpak
//!                      with per-chunk byte extents + FNV checksums and
//!                      min_runnable_depth (deploy::manifest)
//! ```
//!
//! v3 exists for progressive serving ([`crate::deploy::progressive`]):
//! a server can verify and swap in chunk prefixes instead of waiting
//! for the whole model. [`PackedModel::load`] eager-loads v3 dirs like
//! any other version, so `evaluate`/non-progressive `serve` work
//! unchanged. Per-layer values are bit-identical across v2 and v3 —
//! only the container differs.
//!
//! Layers may carry **per-channel scales** (`scales` array +
//! `scale_axis`, always the last axis): element `i` of a layer with `m`
//! output channels dequantizes with `scales[i % m]` instead of the
//! per-tensor `scale`. `quant::perchannel` computes such grids;
//! [`PackedModel::from_per_channel`] packs them.
//!
//! ## v1 (read-compatible)
//!
//! The original `coordinator::state` format: the same header keys at
//! `format_version: 1` with every weight stored as a full-f32 `.q.npy`
//! — zero storage win, no `act_bits`. [`PackedModel::load`] reads both;
//! `coordinator::state::save` now always emits v2.
//!
//! ## Validation
//!
//! The loader rejects: arity mismatches (layers vs weight files vs
//! activation params), non-finite or non-positive scales, packed
//! streams whose byte length or FNV-1a checksum disagree with the
//! header, nonzero pad bits, and codes outside the declared width
//! (impossible by construction for intact streams, guaranteed by the
//! width mask on unpack) — all as typed [`Error::Parse`] values instead
//! of a model that NaNs at forward time.

use std::path::{Path, PathBuf};

use crate::coordinator::pipeline::Outcome;
use crate::deploy::bitpack;
use crate::deploy::manifest::{ArtifactManifest, ChunkEntry, QPAK_FILE};
use crate::io::npy;
use crate::quant::observer::ActQuantParams;
use crate::quant::round_half_even;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};
use crate::util::threadpool;

/// Current written format version (single-file-per-layer layout).
pub const FORMAT_VERSION: u32 = 2;

/// Format version of the chunked layout ([`PackedModel::save_chunked`]).
pub const CHUNKED_FORMAT_VERSION: u32 = 3;

/// Integer grid floor for a signed symmetric `bits`-wide grid.
fn grid_lo(bits: u8) -> i64 {
    -(1i64 << (bits - 1))
}

/// FNV-1a 64-bit — the stream checksum (offline substrate; no crc crate).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// How one layer's weights are stored on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// `deploy::bitpack` integer codes at the layer's width.
    Packed,
    /// Full-f32 npy (v1 dirs; v2 fallback for off-grid tensors).
    F32,
}

impl Encoding {
    fn name(self) -> &'static str {
        match self {
            Encoding::Packed => "qpack",
            Encoding::F32 => "f32",
        }
    }
}

/// One layer's metadata in the artifact header.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub shape: Vec<usize>,
    pub encoding: Encoding,
    pub file: String,
    /// Coding-length provenance from `mixed::allocate` (Eq. 12), when
    /// the pack ran under the paper's mixed-precision allocation.
    pub coding_length: Option<f64>,
    /// Per-output-channel scales over the **last** shape axis: element
    /// `i` dequantizes with `scales[i % channels]`. When present, the
    /// per-tensor `scale` is provenance only (it holds `scales[0]`) —
    /// every dequant path indexes `scales`.
    pub scales: Option<Vec<f32>>,
}

impl PackedLayer {
    pub fn params(&self) -> usize {
        self.shape.iter().product()
    }

    /// On-disk payload bytes for this layer.
    pub fn payload_bytes(&self) -> usize {
        match self.encoding {
            Encoding::Packed => bitpack::packed_len(self.params(), self.bits),
            Encoding::F32 => self.params() * 4,
        }
    }
}

/// In-memory layer payload (codes stay packed until dequantization).
/// `pub(crate)` so the progressive chunk loader can hold decoded
/// payloads without re-verifying them.
#[derive(Debug, Clone)]
pub(crate) enum Payload {
    Packed(Vec<u8>),
    F32(Tensor),
}

/// Borrowed payload view handed out by [`PackedModel::layer_view`]:
/// either the raw LSB-first bitstream with its grid params, or the
/// resident f32 tensor of a lossless-fallback layer.
#[derive(Debug, Clone, Copy)]
pub enum LayerView<'a> {
    Packed {
        bytes: &'a [u8],
        bits: u8,
        scale: f32,
        /// Per-output-channel scales (last axis) when the layer was
        /// quantized per channel; `None` means `scale` applies to every
        /// element.
        scales: Option<&'a [f32]>,
    },
    F32(&'a Tensor),
}

/// A loaded (or about-to-be-saved) quantized model artifact.
#[derive(Debug)]
pub struct PackedModel {
    pub format_version: u32,
    pub model: String,
    pub method: String,
    pub acc: f64,
    pub fp_acc: f64,
    pub layers: Vec<PackedLayer>,
    /// Per-layer activation quant params (the actq deployment config).
    pub act_params: Option<Vec<ActQuantParams>>,
    /// Per-layer activation bit widths (v2 only; v1 dirs did not record
    /// them — consumers fall back to the weight widths).
    pub act_bits: Option<Vec<u8>>,
    payloads: Vec<Payload>,
}

impl PackedModel {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Encode a pipeline outcome. Layers whose quantized weights sit
    /// exactly on their declared 2–8-bit grid are bit-packed; anything
    /// else (wide grids, off-grid legacy tensors) keeps the f32
    /// encoding so `save ∘ load` is lossless for every input.
    /// `coding_lengths` is the per-layer provenance from
    /// `mixed::allocate` when the pack ran under Algorithm-1 bits.
    pub fn from_outcome(
        outcome: &Outcome,
        coding_lengths: Option<&[f64]>,
    ) -> Result<PackedModel> {
        if outcome.qweights.len() != outcome.per_layer.len() {
            return Err(Error::shape(format!(
                "outcome has {} weight tensors for {} layer records",
                outcome.qweights.len(),
                outcome.per_layer.len()
            )));
        }
        if let Some(cl) = coding_lengths {
            if cl.len() != outcome.per_layer.len() {
                return Err(Error::shape(format!(
                    "{} coding lengths for {} layers",
                    cl.len(),
                    outcome.per_layer.len()
                )));
            }
        }
        let pool = threadpool::global();
        let mut layers = Vec::with_capacity(outcome.per_layer.len());
        let mut payloads = Vec::with_capacity(outcome.per_layer.len());
        for (li, (l, qw)) in outcome
            .per_layer
            .iter()
            .zip(&outcome.qweights)
            .enumerate()
        {
            let fname_base = format!("{li:02}_{}", l.name.replace('.', "_"));
            let (encoding, file, payload) = match encode_codes(qw.data(), l.scale, l.bits) {
                Some(codes) => {
                    let mut packed =
                        vec![0u8; bitpack::packed_len(codes.len(), l.bits)];
                    bitpack::pack_into_with(pool, &codes, l.bits, &mut packed)?;
                    (
                        Encoding::Packed,
                        format!("{fname_base}.qbin"),
                        Payload::Packed(packed),
                    )
                }
                None => {
                    log::warn!(
                        "{}: not exactly on a {}-bit grid at scale {} — storing f32",
                        l.name,
                        l.bits,
                        l.scale
                    );
                    (
                        Encoding::F32,
                        format!("{fname_base}.q.npy"),
                        Payload::F32(qw.clone()),
                    )
                }
            };
            layers.push(PackedLayer {
                name: l.name.clone(),
                bits: l.bits,
                scale: l.scale,
                shape: qw.shape().to_vec(),
                encoding,
                file,
                coding_length: coding_lengths.map(|cl| cl[li]),
                scales: None,
            });
            payloads.push(payload);
        }
        Ok(PackedModel {
            format_version: FORMAT_VERSION,
            model: outcome.model.clone(),
            method: outcome.method.name().to_string(),
            acc: outcome.acc,
            fp_acc: outcome.fp_acc,
            layers,
            act_params: outcome.act_params.clone(),
            act_bits: outcome.act_bits.clone(),
            payloads,
        })
    }

    /// Build a packed artifact from per-channel-quantized layers (the
    /// `quant::perchannel` path). Each entry is
    /// `(name, bits, per-channel scales, quantized weights)`; element
    /// `i` belongs to output channel `i % channels` (channels = last
    /// shape axis) and must sit exactly on that channel's grid
    /// `scales[c] · q`. No f32 fallback: per-channel scales exist
    /// precisely to keep the packed encoding exact, so off-grid input
    /// is an error rather than a silent storage downgrade.
    pub fn from_per_channel(
        model: &str,
        method: &str,
        acc: f64,
        fp_acc: f64,
        per_layer: Vec<(String, u8, Vec<f32>, Tensor)>,
    ) -> Result<PackedModel> {
        let pool = threadpool::global();
        let mut layers = Vec::with_capacity(per_layer.len());
        let mut payloads = Vec::with_capacity(per_layer.len());
        for (li, (name, bits, scales, qw)) in per_layer.into_iter().enumerate() {
            let channels = qw.shape().last().copied().unwrap_or(0);
            if scales.is_empty() || scales.len() != channels {
                return Err(Error::shape(format!(
                    "{name}: {} per-channel scales for {channels} output channels",
                    scales.len()
                )));
            }
            for &s in &scales {
                if !(s.is_finite() && s > 0.0) {
                    return Err(Error::invariant(format!(
                        "{name}: per-channel scale {s} must be finite and positive"
                    )));
                }
            }
            let codes = encode_codes_per_channel(qw.data(), &scales, bits)
                .ok_or_else(|| {
                    Error::invariant(format!(
                        "{name}: weights are not exactly on the per-channel \
                         {bits}-bit grid"
                    ))
                })?;
            let mut packed = vec![0u8; bitpack::packed_len(codes.len(), bits)];
            bitpack::pack_into_with(pool, &codes, bits, &mut packed)?;
            let file = format!("{li:02}_{}.qbin", name.replace('.', "_"));
            layers.push(PackedLayer {
                name,
                bits,
                scale: scales[0],
                shape: qw.shape().to_vec(),
                encoding: Encoding::Packed,
                file,
                coding_length: None,
                scales: Some(scales),
            });
            payloads.push(Payload::Packed(packed));
        }
        Ok(PackedModel {
            format_version: FORMAT_VERSION,
            model: model.to_string(),
            method: method.to_string(),
            acc,
            fp_acc,
            layers,
            act_params: None,
            act_bits: None,
            payloads,
        })
    }

    /// Dequantize layer `li` into `out` (resized to the layer's element
    /// count), using `codes` as unpack scratch. Bit-identical to the
    /// tensor that was packed: the same `s · q` f32 multiply every
    /// rounding kernel finalizes with.
    pub fn dequantize_layer_into(
        &self,
        li: usize,
        codes: &mut Vec<u32>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let l = self
            .layers
            .get(li)
            .ok_or_else(|| Error::shape(format!("layer {li} out of range")))?;
        let n = l.params();
        match &self.payloads[li] {
            Payload::Packed(bytes) => {
                codes.resize(n, 0);
                bitpack::unpack_into(bytes, l.bits, codes)?;
                out.resize(n, 0.0);
                let lo = grid_lo(l.bits);
                match &l.scales {
                    Some(ss) => {
                        // per-channel: element i belongs to output
                        // channel i % channels (last axis)
                        let m = ss.len();
                        for (i, (o, &c)) in
                            out.iter_mut().zip(codes.iter()).enumerate()
                        {
                            *o = ss[i % m] * ((c as i64 + lo) as f32);
                        }
                    }
                    None => {
                        let s = l.scale;
                        for (o, &c) in out.iter_mut().zip(codes.iter()) {
                            *o = s * ((c as i64 + lo) as f32);
                        }
                    }
                }
            }
            Payload::F32(t) => {
                out.clear();
                out.extend_from_slice(t.data());
            }
        }
        Ok(())
    }

    /// Borrow layer `li`'s payload without dequantizing: the packed
    /// bytes plus grid params, or the resident f32 tensor for lossless
    /// layers. This is what the fused dequant-matmul serving path
    /// consumes — no scratch, no full-layer f32 expansion, no lock.
    pub fn layer_view(&self, li: usize) -> Result<LayerView<'_>> {
        let l = self
            .layers
            .get(li)
            .ok_or_else(|| Error::shape(format!("layer {li} out of range")))?;
        Ok(match &self.payloads[li] {
            Payload::Packed(bytes) => LayerView::Packed {
                bytes,
                bits: l.bits,
                scale: l.scale,
                scales: l.scales.as_deref(),
            },
            Payload::F32(t) => LayerView::F32(t),
        })
    }

    /// Dequantize one layer into a fresh tensor.
    pub fn dequantize(&self, li: usize) -> Result<Tensor> {
        let mut codes = Vec::new();
        let mut data = Vec::new();
        self.dequantize_layer_into(li, &mut codes, &mut data)?;
        Tensor::new(self.layers[li].shape.clone(), data)
    }

    /// Dequantize every layer (the staging path for backends that need
    /// resident f32 weights, e.g. PJRT device upload; the host serving
    /// path streams per layer instead — see `deploy::dequant`).
    pub fn dequantize_all(&self) -> Result<Vec<Tensor>> {
        (0..self.num_layers()).map(|li| self.dequantize(li)).collect()
    }

    /// The activation-quant deployment config this artifact should be
    /// *served and evaluated* with: `act_params` paired with `act_bits`
    /// when present, or — for v1 dirs, which carried params but never
    /// recorded widths — the weight widths as the documented fallback,
    /// provided every one is a usable activation width (the actq grids
    /// shift by them). `None` when the artifact has no activation
    /// config (plain `forward`). Both the serve path and
    /// `repro evaluate --artifact` resolve through here, so a saved W+A
    /// model always runs exactly the configuration it was calibrated
    /// with.
    pub fn deployment_actq(&self) -> Result<Option<(Vec<ActQuantParams>, Vec<u8>)>> {
        let Some(params) = &self.act_params else {
            return Ok(None);
        };
        let bits: Vec<u8> = match &self.act_bits {
            Some(b) => b.clone(),
            None => {
                let bits: Vec<u8> = self.layers.iter().map(|l| l.bits).collect();
                if let Some(&b) = bits.iter().find(|&&b| !(1..=16).contains(&b)) {
                    return Err(Error::config(format!(
                        "artifact {}: v1 dir has act_params but no act_bits, and \
                         weight width {b} is not a usable activation width — \
                         re-save the model to migrate it to v2",
                        self.model
                    )));
                }
                log::warn!(
                    "artifact {}: act_params without act_bits (v1 dir) — \
                     serving with the weight widths",
                    self.model
                );
                bits
            }
        };
        Ok(Some((params.clone(), bits)))
    }

    /// Weight-payload f32 baseline in bytes (what v1 stored).
    pub fn f32_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.params() as u64 * 4).sum()
    }

    /// On-disk weight-payload bytes under this artifact's encodings.
    pub fn payload_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.payload_bytes() as u64).sum()
    }

    /// The artifact's weights must match the execution model it will be
    /// served through: same layer count, same per-layer weight shapes.
    pub fn check_matches(&self, model: &crate::coordinator::model::LoadedModel) -> Result<()> {
        if self.num_layers() != model.num_layers() {
            return Err(Error::shape(format!(
                "artifact has {} layers, model {} has {}",
                self.num_layers(),
                model.info.name,
                model.num_layers()
            )));
        }
        for (l, w) in self.layers.iter().zip(&model.weights) {
            if l.shape != w.shape() {
                return Err(Error::shape(format!(
                    "artifact layer {} shape {:?} vs model weight {:?}",
                    l.name,
                    l.shape,
                    w.shape()
                )));
            }
        }
        Ok(())
    }

    /// Re-encode any f32-payload layers whose tensors sit exactly on
    /// their declared grid — the v1→v2 migration path (`load` a legacy
    /// dir, `repack`, `save` to a new dir). Returns how many layers
    /// switched to the packed encoding; off-grid layers stay f32.
    pub fn repack(&mut self) -> Result<usize> {
        let pool = threadpool::global();
        let mut packed_count = 0;
        for (li, (l, p)) in self.layers.iter_mut().zip(&mut self.payloads).enumerate() {
            let t = match p {
                Payload::F32(t) => t,
                Payload::Packed(_) => continue,
            };
            if let Some(codes) = encode_codes(t.data(), l.scale, l.bits) {
                let mut bytes = vec![0u8; bitpack::packed_len(codes.len(), l.bits)];
                bitpack::pack_into_with(pool, &codes, l.bits, &mut bytes)?;
                *p = Payload::Packed(bytes);
                l.encoding = Encoding::Packed;
                l.file = format!("{li:02}_{}.qbin", l.name.replace('.', "_"));
                packed_count += 1;
            }
        }
        Ok(packed_count)
    }

    /// Write the artifact directory. Always emits the **v2 layout**
    /// regardless of where the model was loaded from, so saving a
    /// v1-loaded artifact migrates it forward. Target a fresh directory
    /// — stale files from a previous format are not cleaned up.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut layer_json = Vec::with_capacity(self.layers.len());
        for (l, p) in self.layers.iter().zip(&self.payloads) {
            let mut fields = vec![
                ("name", Json::str(l.name.clone())),
                ("bits", Json::num(l.bits as f64)),
                ("scale", Json::num(l.scale as f64)),
                (
                    "shape",
                    Json::arr(l.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                ),
                ("encoding", Json::str(l.encoding.name())),
                ("file", Json::str(l.file.clone())),
            ];
            match p {
                Payload::Packed(bytes) => {
                    fields.push(("packed_bytes", Json::num(bytes.len() as f64)));
                    fields.push((
                        "checksum",
                        Json::str(format!("{:016x}", fnv1a64(bytes))),
                    ));
                    std::fs::write(dir.join(&l.file), bytes)?;
                }
                Payload::F32(t) => {
                    npy::write_f32(&dir.join(&l.file), t)?;
                }
            }
            if let Some(cl) = l.coding_length {
                fields.push(("coding_length", Json::num(cl)));
            }
            if let Some(ss) = &l.scales {
                fields.push((
                    "scales",
                    Json::arr(ss.iter().map(|&s| Json::num(s as f64)).collect()),
                ));
                fields.push((
                    "scale_axis",
                    Json::num((l.shape.len().max(1) - 1) as f64),
                ));
            }
            layer_json.push(Json::obj(fields));
        }
        let mut fields = vec![
            ("format_version", Json::num(FORMAT_VERSION as f64)),
            ("model", Json::str(self.model.clone())),
            ("method", Json::str(self.method.clone())),
            ("acc", Json::num(self.acc)),
            ("fp_acc", Json::num(self.fp_acc)),
            ("layers", Json::arr(layer_json)),
        ];
        if let Some(ap) = &self.act_params {
            let aps = ap
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("scale", Json::num(p.scale as f64)),
                        ("zero", Json::num(p.zero as f64)),
                    ])
                })
                .collect();
            fields.push(("act_params", Json::arr(aps)));
        }
        if let Some(ab) = &self.act_bits {
            fields.push((
                "act_bits",
                Json::arr(ab.iter().map(|&b| Json::num(b as f64)).collect()),
            ));
        }
        std::fs::write(
            dir.join("qmodel.json"),
            Json::obj(fields).to_string_pretty(),
        )?;
        Ok(())
    }

    /// Write the artifact as a **v3 chunked directory**: one
    /// `qmodel.qpak` holding every layer payload back-to-back (layer
    /// order), a `manifest.json` splitting the layers into `n_chunks`
    /// contiguous balanced ranges, and a v3 `qmodel.json` header
    /// carrying per-layer `payload_bytes` + checksums (the intra-chunk
    /// offset table). `min_runnable_depth` counts chunks — the shortest
    /// verified prefix a progressive server may answer from.
    pub fn save_chunked(
        &self,
        dir: &Path,
        n_chunks: usize,
        min_runnable_depth: usize,
    ) -> Result<ArtifactManifest> {
        std::fs::create_dir_all(dir)?;
        let ranges = ArtifactManifest::plan_chunks(self.layers.len(), n_chunks)?;

        // Concatenate every layer payload; record per-layer extents.
        let mut qpak: Vec<u8> = Vec::new();
        let mut lens = Vec::with_capacity(self.payloads.len());
        let mut sums = Vec::with_capacity(self.payloads.len());
        for (l, p) in self.layers.iter().zip(&self.payloads) {
            let start = qpak.len();
            match p {
                Payload::Packed(bytes) => qpak.extend_from_slice(bytes),
                Payload::F32(t) => {
                    for v in t.data() {
                        qpak.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            let len = qpak.len() - start;
            if len != l.payload_bytes() {
                return Err(Error::invariant(format!(
                    "{}: payload is {len} bytes but the header computes {}",
                    l.name,
                    l.payload_bytes()
                )));
            }
            lens.push(len);
            sums.push(format!("{:016x}", fnv1a64(&qpak[start..])));
        }

        // Chunk table over the concatenated payloads.
        let mut chunks = Vec::with_capacity(ranges.len());
        let mut off = 0usize;
        for (id, &(s, e)) in ranges.iter().enumerate() {
            let bytes: usize = lens[s..e].iter().sum();
            chunks.push(ChunkEntry {
                id,
                layer_start: s,
                layer_end: e,
                bytes: bytes as u64,
                checksum: format!("{:016x}", fnv1a64(&qpak[off..off + bytes])),
            });
            off += bytes;
        }
        let manifest = ArtifactManifest {
            chunks,
            min_runnable_depth,
        };
        manifest.validate(self.layers.len())?;

        let mut layer_json = Vec::with_capacity(self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            let mut fields = vec![
                ("name", Json::str(l.name.clone())),
                ("bits", Json::num(l.bits as f64)),
                ("scale", Json::num(l.scale as f64)),
                (
                    "shape",
                    Json::arr(l.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                ),
                ("encoding", Json::str(l.encoding.name())),
                ("file", Json::str(QPAK_FILE)),
                ("payload_bytes", Json::num(lens[li] as f64)),
                ("checksum", Json::str(sums[li].clone())),
            ];
            if let Some(cl) = l.coding_length {
                fields.push(("coding_length", Json::num(cl)));
            }
            if let Some(ss) = &l.scales {
                fields.push((
                    "scales",
                    Json::arr(ss.iter().map(|&s| Json::num(s as f64)).collect()),
                ));
                fields.push((
                    "scale_axis",
                    Json::num((l.shape.len().max(1) - 1) as f64),
                ));
            }
            layer_json.push(Json::obj(fields));
        }
        let mut fields = vec![
            (
                "format_version",
                Json::num(CHUNKED_FORMAT_VERSION as f64),
            ),
            ("model", Json::str(self.model.clone())),
            ("method", Json::str(self.method.clone())),
            ("acc", Json::num(self.acc)),
            ("fp_acc", Json::num(self.fp_acc)),
            ("layers", Json::arr(layer_json)),
        ];
        if let Some(ap) = &self.act_params {
            let aps = ap
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("scale", Json::num(p.scale as f64)),
                        ("zero", Json::num(p.zero as f64)),
                    ])
                })
                .collect();
            fields.push(("act_params", Json::arr(aps)));
        }
        if let Some(ab) = &self.act_bits {
            fields.push((
                "act_bits",
                Json::arr(ab.iter().map(|&b| Json::num(b as f64)).collect()),
            ));
        }
        std::fs::write(dir.join(QPAK_FILE), &qpak)?;
        manifest.save(dir)?;
        std::fs::write(
            dir.join("qmodel.json"),
            Json::obj(fields).to_string_pretty(),
        )?;
        Ok(manifest)
    }

    /// Load an artifact directory — v3 chunked, v2 packed, or a legacy
    /// v1 f32 dir. v3 payloads are eager-loaded here (evaluate and
    /// non-progressive serve behave exactly as on a v2 dir); the
    /// progressive server uses [`load_v3_meta`] instead to defer chunk
    /// reads.
    pub fn load(dir: &Path) -> Result<PackedModel> {
        let j = json::parse_file(&dir.join("qmodel.json"))?;
        let version = j
            .opt("format_version")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        match version {
            1 => load_v1(&j, dir),
            2 => load_v2(&j, dir),
            3 => load_v3(&j, dir),
            other => Err(Error::parse(format!(
                "qmodel.json: unsupported format_version {other} (this build reads 1..=3)"
            ))),
        }
    }
}

/// Does `dir` look like a saved quantized-model artifact?
pub fn is_artifact_dir(dir: &Path) -> bool {
    dir.join("qmodel.json").is_file()
}

/// Recover integer codes from a quantized tensor, verifying the
/// round-trip is exact: `None` means the tensor is not exactly
/// `s · q` for in-range grid integers at this width (caller falls back
/// to the f32 encoding).
fn encode_codes(qw: &[f32], scale: f32, bits: u8) -> Option<Vec<u32>> {
    if !(bitpack::MIN_BITS..=bitpack::MAX_BITS).contains(&bits) {
        return None;
    }
    if !(scale.is_finite() && scale > 0.0) {
        return None;
    }
    let lo = grid_lo(bits);
    let hi = -lo - 1;
    let mut codes = Vec::with_capacity(qw.len());
    for &v in qw {
        let q = round_half_even(v / scale);
        if !q.is_finite() {
            return None;
        }
        let qi = q as i64;
        if qi < lo || qi > hi {
            return None;
        }
        // the exactness gate: dequant must reproduce the input
        // bit-for-bit (same `s · q` multiply as the rounding kernels)
        if scale * (qi as f32) != v {
            return None;
        }
        codes.push((qi - lo) as u32);
    }
    Some(codes)
}

/// Per-channel variant of [`encode_codes`]: element `i` is gated
/// against its own channel grid `scales[i % channels] · q`. Same
/// exactness contract — `None` means some element does not reproduce
/// bit-for-bit.
fn encode_codes_per_channel(qw: &[f32], scales: &[f32], bits: u8) -> Option<Vec<u32>> {
    if !(bitpack::MIN_BITS..=bitpack::MAX_BITS).contains(&bits) {
        return None;
    }
    if scales.is_empty()
        || qw.len() % scales.len() != 0
        || scales.iter().any(|s| !(s.is_finite() && *s > 0.0))
    {
        return None;
    }
    let lo = grid_lo(bits);
    let hi = -lo - 1;
    let m = scales.len();
    let mut codes = Vec::with_capacity(qw.len());
    for (i, &v) in qw.iter().enumerate() {
        let s = scales[i % m];
        let q = round_half_even(v / s);
        if !q.is_finite() {
            return None;
        }
        let qi = q as i64;
        if qi < lo || qi > hi {
            return None;
        }
        if s * (qi as f32) != v {
            return None;
        }
        codes.push((qi - lo) as u32);
    }
    Some(codes)
}

fn parse_scale(v: &Json, name: &str) -> Result<f32> {
    let s = v.as_f64()? as f32;
    if !(s.is_finite() && s > 0.0) {
        return Err(Error::parse(format!(
            "qmodel.json: layer {name}: scale {s} must be finite and positive"
        )));
    }
    Ok(s)
}

/// Parse the optional per-channel `scales` + `scale_axis` pair of a
/// layer record. The axis must be the last shape axis and the array
/// length must equal that axis — per-channel means per output channel.
fn parse_layer_scales(
    l: &Json,
    name: &str,
    shape: &[usize],
) -> Result<Option<Vec<f32>>> {
    let Some(v) = l.opt("scales") else {
        return Ok(None);
    };
    let axis = l.get("scale_axis")?.as_usize()?;
    if axis + 1 != shape.len().max(1) {
        return Err(Error::parse(format!(
            "qmodel.json: layer {name}: scale_axis {axis} must be the last \
             axis of shape {shape:?}"
        )));
    }
    let channels = shape.last().copied().unwrap_or(0);
    let arr = v.as_arr()?;
    if arr.len() != channels {
        return Err(Error::parse(format!(
            "qmodel.json: layer {name}: {} scales for {channels} output channels",
            arr.len()
        )));
    }
    let mut out = Vec::with_capacity(arr.len());
    for s in arr {
        out.push(parse_scale(s, name)?);
    }
    Ok(Some(out))
}

fn parse_bits(v: &Json, name: &str) -> Result<u8> {
    let b = v.as_usize()?;
    if !(1..=32).contains(&b) {
        return Err(Error::parse(format!(
            "qmodel.json: layer {name}: bits {b} out of range 1..=32"
        )));
    }
    Ok(b as u8)
}

/// Activation widths feed `(1 << bits)` grids in `fake_quant_act` /
/// `forward_actq`, so the loader bounds them to the quantizer's own
/// 1..=16 range — tighter than weight bits, which may legitimately be
/// declared wider on f32-fallback layers.
fn parse_act_width(v: &Json) -> Result<u8> {
    let b = v.as_usize()?;
    if !(1..=16).contains(&b) {
        return Err(Error::parse(format!(
            "qmodel.json: act width {b} out of range 1..=16"
        )));
    }
    Ok(b as u8)
}

fn parse_act_config(j: &Json, k: usize) -> Result<(Option<Vec<ActQuantParams>>, Option<Vec<u8>>)> {
    let act_params = match j.opt("act_params") {
        Some(ap) => {
            let arr = ap.as_arr()?;
            if arr.len() != k {
                return Err(Error::parse(format!(
                    "qmodel.json: {} act_params for {k} layers",
                    arr.len()
                )));
            }
            let mut out = Vec::with_capacity(arr.len());
            for p in arr {
                let scale = p.get("scale")?.as_f64()? as f32;
                if !(scale.is_finite() && scale > 0.0) {
                    return Err(Error::parse(format!(
                        "qmodel.json: act scale {scale} must be finite and positive"
                    )));
                }
                let zero = p.get("zero")?.as_f64()? as f32;
                if !zero.is_finite() {
                    return Err(Error::parse("qmodel.json: act zero must be finite"));
                }
                out.push(ActQuantParams { scale, zero });
            }
            Some(out)
        }
        None => None,
    };
    let act_bits = match j.opt("act_bits") {
        Some(ab) => {
            let arr = ab.as_arr()?;
            if arr.len() != k {
                return Err(Error::parse(format!(
                    "qmodel.json: {} act_bits for {k} layers",
                    arr.len()
                )));
            }
            Some(
                arr.iter()
                    .map(parse_act_width)
                    .collect::<Result<Vec<u8>>>()?,
            )
        }
        None => None,
    };
    if act_bits.is_some() && act_params.is_none() {
        return Err(Error::parse("qmodel.json: act_bits without act_params"));
    }
    Ok((act_params, act_bits))
}

fn load_v1(j: &Json, dir: &Path) -> Result<PackedModel> {
    let layers_j = j.get("layers")?.as_arr()?;
    let wfiles = j.get("weight_files")?.str_vec()?;
    if layers_j.len() != wfiles.len() {
        return Err(Error::parse(format!(
            "qmodel.json: {} layer records for {} weight files",
            layers_j.len(),
            wfiles.len()
        )));
    }
    let mut layers = Vec::with_capacity(layers_j.len());
    let mut payloads = Vec::with_capacity(layers_j.len());
    for (l, f) in layers_j.iter().zip(&wfiles) {
        let name = l.get("name")?.as_str()?.to_string();
        let bits = parse_bits(l.get("bits")?, &name)?;
        let scale = parse_scale(l.get("scale")?, &name)?;
        let t = npy::read_f32(&dir.join(f))?;
        layers.push(PackedLayer {
            name,
            bits,
            scale,
            shape: t.shape().to_vec(),
            encoding: Encoding::F32,
            file: f.clone(),
            coding_length: None,
            scales: None,
        });
        payloads.push(Payload::F32(t));
    }
    let (act_params, act_bits) = parse_act_config(j, layers.len())?;
    Ok(PackedModel {
        format_version: 1,
        model: j.get("model")?.as_str()?.to_string(),
        method: j.get("method")?.as_str()?.to_string(),
        acc: j.get("acc")?.as_f64()?,
        fp_acc: j.get("fp_acc")?.as_f64()?,
        layers,
        act_params,
        act_bits,
        payloads,
    })
}

fn load_v2(j: &Json, dir: &Path) -> Result<PackedModel> {
    let layers_j = j.get("layers")?.as_arr()?;
    let mut layers = Vec::with_capacity(layers_j.len());
    let mut payloads = Vec::with_capacity(layers_j.len());
    for l in layers_j {
        let name = l.get("name")?.as_str()?.to_string();
        let bits = parse_bits(l.get("bits")?, &name)?;
        let scale = parse_scale(l.get("scale")?, &name)?;
        let shape = l.get("shape")?.usize_vec()?;
        let n: usize = shape.iter().product();
        let file = l.get("file")?.as_str()?.to_string();
        let encoding = l.get("encoding")?.as_str()?;
        let (encoding, payload) = match encoding {
            "qpack" => {
                if !(bitpack::MIN_BITS..=bitpack::MAX_BITS).contains(&bits) {
                    return Err(Error::parse(format!(
                        "qmodel.json: layer {name}: packed width {bits} out of \
                         range {}..={}",
                        bitpack::MIN_BITS,
                        bitpack::MAX_BITS
                    )));
                }
                let declared = l.get("packed_bytes")?.as_usize()?;
                let want = bitpack::packed_len(n, bits);
                if declared != want {
                    return Err(Error::parse(format!(
                        "qmodel.json: layer {name}: packed_bytes {declared} but \
                         {n} codes at {bits}b need {want}"
                    )));
                }
                let bytes = std::fs::read(dir.join(&file)).map_err(|e| {
                    Error::parse(format!("reading {}: {e}", dir.join(&file).display()))
                })?;
                if bytes.len() != want {
                    return Err(Error::parse(format!(
                        "{file}: {} bytes on disk, header says {want}",
                        bytes.len()
                    )));
                }
                let sum = format!("{:016x}", fnv1a64(&bytes));
                let declared_sum = l.get("checksum")?.as_str()?;
                if sum != declared_sum {
                    return Err(Error::parse(format!(
                        "{file}: checksum mismatch ({sum} vs header {declared_sum})"
                    )));
                }
                bitpack::validate_padding(&bytes, n, bits)
                    .map_err(|e| Error::parse(format!("{file}: {e}")))?;
                (Encoding::Packed, Payload::Packed(bytes))
            }
            "f32" => {
                let t = npy::read_f32(&dir.join(&file))?;
                if t.shape() != shape.as_slice() {
                    return Err(Error::parse(format!(
                        "{file}: npy shape {:?} but header says {shape:?}",
                        t.shape()
                    )));
                }
                (Encoding::F32, Payload::F32(t))
            }
            other => {
                return Err(Error::parse(format!(
                    "qmodel.json: layer {name}: unknown encoding {other:?}"
                )))
            }
        };
        let scales = parse_layer_scales(l, &name, &shape)?;
        layers.push(PackedLayer {
            name,
            bits,
            scale,
            shape,
            encoding,
            file,
            coding_length: l
                .opt("coding_length")
                .map(|v| v.as_f64())
                .transpose()?,
            scales,
        });
        payloads.push(payload);
    }
    let (act_params, act_bits) = parse_act_config(j, layers.len())?;
    Ok(PackedModel {
        format_version: 2,
        model: j.get("model")?.as_str()?.to_string(),
        method: j.get("method")?.as_str()?.to_string(),
        acc: j.get("acc")?.as_f64()?,
        fp_acc: j.get("fp_acc")?.as_f64()?,
        layers,
        act_params,
        act_bits,
        payloads,
    })
}

/// Everything a v3 chunked artifact declares *without* its payload
/// bytes: the parsed header layers, the validated chunk manifest, the
/// per-layer extents inside `qmodel.qpak`, and the `.qpak` path itself.
/// The progressive server ([`crate::deploy::progressive`]) opens this
/// first, starts serving, and reads chunk extents as they verify.
#[derive(Debug)]
pub struct ChunkedMeta {
    pub model: String,
    pub method: String,
    pub acc: f64,
    pub fp_acc: f64,
    pub layers: Vec<PackedLayer>,
    pub act_params: Option<Vec<ActQuantParams>>,
    pub act_bits: Option<Vec<u8>>,
    /// Per-layer payload byte counts (layer order; the intra-chunk
    /// offset table).
    pub payload_lens: Vec<usize>,
    /// Per-layer declared FNV-1a-64 hex checksums.
    pub layer_checksums: Vec<String>,
    pub manifest: ArtifactManifest,
    /// Absolute path of the concatenated payload file.
    pub qpak: PathBuf,
}

impl ChunkedMeta {
    /// Byte offset of layer `li`'s payload inside `qmodel.qpak`.
    pub fn layer_offset(&self, li: usize) -> u64 {
        self.payload_lens[..li].iter().map(|&n| n as u64).sum()
    }

    /// The activation-quant deployment config, resolved exactly like
    /// [`PackedModel::deployment_actq`] (v3 headers always carry
    /// `act_bits` alongside `act_params`, so no v1 fallback applies).
    pub fn deployment_actq(&self) -> Result<Option<(Vec<ActQuantParams>, Vec<u8>)>> {
        match (&self.act_params, &self.act_bits) {
            (Some(p), Some(b)) => Ok(Some((p.clone(), b.clone()))),
            (Some(_), None) => Err(Error::parse(format!(
                "artifact {}: v3 header has act_params but no act_bits",
                self.model
            ))),
            _ => Ok(None),
        }
    }
}

/// Open a v3 chunked artifact's metadata without reading any payloads.
pub fn load_v3_meta(dir: &Path) -> Result<ChunkedMeta> {
    let j = json::parse_file(&dir.join("qmodel.json"))?;
    let version = j
        .opt("format_version")
        .map(|v| v.as_usize())
        .transpose()?
        .unwrap_or(1);
    if version != CHUNKED_FORMAT_VERSION as usize {
        return Err(Error::parse(format!(
            "qmodel.json: progressive serving needs a chunked v3 artifact, \
             found format_version {version} (re-pack with `pack --chunks N`)"
        )));
    }
    parse_v3_header(&j, dir)
}

fn parse_v3_header(j: &Json, dir: &Path) -> Result<ChunkedMeta> {
    let layers_j = j.get("layers")?.as_arr()?;
    let mut layers = Vec::with_capacity(layers_j.len());
    let mut payload_lens = Vec::with_capacity(layers_j.len());
    let mut layer_checksums = Vec::with_capacity(layers_j.len());
    for l in layers_j {
        let name = l.get("name")?.as_str()?.to_string();
        let bits = parse_bits(l.get("bits")?, &name)?;
        let scale = parse_scale(l.get("scale")?, &name)?;
        let shape = l.get("shape")?.usize_vec()?;
        let encoding = match l.get("encoding")?.as_str()? {
            "qpack" => {
                if !(bitpack::MIN_BITS..=bitpack::MAX_BITS).contains(&bits) {
                    return Err(Error::parse(format!(
                        "qmodel.json: layer {name}: packed width {bits} out of \
                         range {}..={}",
                        bitpack::MIN_BITS,
                        bitpack::MAX_BITS
                    )));
                }
                Encoding::Packed
            }
            "f32" => Encoding::F32,
            other => {
                return Err(Error::parse(format!(
                    "qmodel.json: layer {name}: unknown encoding {other:?}"
                )))
            }
        };
        let scales = parse_layer_scales(l, &name, &shape)?;
        let layer = PackedLayer {
            name: name.clone(),
            bits,
            scale,
            shape,
            encoding,
            file: QPAK_FILE.to_string(),
            coding_length: l
                .opt("coding_length")
                .map(|v| v.as_f64())
                .transpose()?,
            scales,
        };
        let declared = l.get("payload_bytes")?.as_usize()?;
        let want = layer.payload_bytes();
        if declared != want {
            return Err(Error::parse(format!(
                "qmodel.json: layer {name}: payload_bytes {declared} but the \
                 shape at this encoding needs {want}"
            )));
        }
        payload_lens.push(declared);
        layer_checksums.push(l.get("checksum")?.as_str()?.to_string());
        layers.push(layer);
    }
    let (act_params, act_bits) = parse_act_config(j, layers.len())?;
    let manifest = ArtifactManifest::load(dir)?;
    manifest.validate(layers.len())?;
    for c in &manifest.chunks {
        let want: u64 = payload_lens[c.layer_start..c.layer_end]
            .iter()
            .map(|&n| n as u64)
            .sum();
        if c.bytes != want {
            return Err(Error::parse(format!(
                "manifest.json: chunk {}: {} bytes but layers {}..{} occupy {want}",
                c.id, c.bytes, c.layer_start, c.layer_end
            )));
        }
    }
    Ok(ChunkedMeta {
        model: j.get("model")?.as_str()?.to_string(),
        method: j.get("method")?.as_str()?.to_string(),
        acc: j.get("acc")?.as_f64()?,
        fp_acc: j.get("fp_acc")?.as_f64()?,
        layers,
        act_params,
        act_bits,
        payload_lens,
        layer_checksums,
        manifest,
        qpak: dir.join(QPAK_FILE),
    })
}

/// Decode one layer payload slice from a v3 `.qpak` extent: checksum,
/// padding, and shape verification included. Shared by the eager v3
/// loader and the progressive chunk loader so a chunk that verifies is
/// a chunk that serves.
pub(crate) fn decode_v3_payload(
    meta: &ChunkedMeta,
    li: usize,
    bytes: &[u8],
) -> Result<Payload> {
    let l = &meta.layers[li];
    let n = l.params();
    if bytes.len() != meta.payload_lens[li] {
        return Err(Error::parse(format!(
            "qmodel.qpak: layer {}: {} bytes sliced, header says {}",
            l.name,
            bytes.len(),
            meta.payload_lens[li]
        )));
    }
    let sum = format!("{:016x}", fnv1a64(bytes));
    if sum != meta.layer_checksums[li] {
        return Err(Error::parse(format!(
            "qmodel.qpak: layer {}: checksum mismatch ({sum} vs header {})",
            l.name, meta.layer_checksums[li]
        )));
    }
    Ok(match l.encoding {
        Encoding::Packed => {
            bitpack::validate_padding(bytes, n, l.bits)
                .map_err(|e| Error::parse(format!("qmodel.qpak: layer {}: {e}", l.name)))?;
            Payload::Packed(bytes.to_vec())
        }
        Encoding::F32 => {
            let mut vals = Vec::with_capacity(n);
            for c in bytes.chunks_exact(4) {
                vals.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            Payload::F32(Tensor::new(l.shape.clone(), vals)?)
        }
    })
}

fn load_v3(j: &Json, dir: &Path) -> Result<PackedModel> {
    let meta = parse_v3_header(j, dir)?;
    let data = std::fs::read(&meta.qpak)
        .map_err(|e| Error::parse(format!("reading {}: {e}", meta.qpak.display())))?;
    if data.len() as u64 != meta.manifest.total_bytes() {
        return Err(Error::parse(format!(
            "qmodel.qpak: {} bytes on disk, manifest says {} (truncated?)",
            data.len(),
            meta.manifest.total_bytes()
        )));
    }
    for (k, c) in meta.manifest.chunks.iter().enumerate() {
        let off = meta.manifest.chunk_offset(k) as usize;
        let slice = &data[off..off + c.bytes as usize];
        let sum = format!("{:016x}", fnv1a64(slice));
        if sum != c.checksum {
            return Err(Error::parse(format!(
                "qmodel.qpak: chunk {}: checksum mismatch ({sum} vs manifest {})",
                c.id, c.checksum
            )));
        }
    }
    let mut payloads = Vec::with_capacity(meta.layers.len());
    let mut off = 0usize;
    for li in 0..meta.layers.len() {
        let len = meta.payload_lens[li];
        payloads.push(decode_v3_payload(&meta, li, &data[off..off + len])?);
        off += len;
    }
    Ok(PackedModel {
        format_version: 3,
        model: meta.model,
        method: meta.method,
        acc: meta.acc,
        fp_acc: meta.fp_acc,
        layers: meta.layers,
        act_params: meta.act_params,
        act_bits: meta.act_bits,
        payloads,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::LayerOutcome;
    use crate::quant::rounding::{nearest, Rounding};
    use crate::quant::QGrid;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ar_artifact_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// An outcome whose qweights really sit on their grids (produced by
    /// the nearest kernel, like the pipeline's static rounding path).
    /// Scales are binary-exact so the header JSON prints them verbatim
    /// (the corruption test rewrites the header by string match).
    fn grid_outcome() -> Outcome {
        let grids = [QGrid::signed(4, 0.25).unwrap(), QGrid::signed(3, 0.125).unwrap()];
        let mut rng = Rng::new(42);
        let mut w0 = vec![0.0f32; 24 * 8];
        rng.fill_gaussian(&mut w0, 0.0, 0.5);
        let mut w1 = vec![0.0f32; 13]; // ragged length: partial final byte
        rng.fill_gaussian(&mut w1, 0.0, 0.25);
        let q0 = nearest(&w0, &grids[0]);
        let q1 = nearest(&w1, &grids[1]);
        Outcome {
            model: "m".into(),
            method: Rounding::Nearest,
            acc: 0.5,
            fp_acc: 0.9,
            per_layer: vec![
                LayerOutcome {
                    name: "stem".into(),
                    bits: 4,
                    scale: 0.25,
                    first_loss: f32::NAN,
                    last_loss: f32::NAN,
                },
                LayerOutcome {
                    name: "head.fc".into(),
                    bits: 3,
                    scale: 0.125,
                    first_loss: f32::NAN,
                    last_loss: f32::NAN,
                },
            ],
            qweights: vec![
                Tensor::new(vec![24, 8], q0).unwrap(),
                Tensor::new(vec![13], q1).unwrap(),
            ],
            act_params: Some(vec![
                ActQuantParams { scale: 0.1, zero: -1.0 },
                ActQuantParams { scale: 0.2, zero: 0.0 },
            ]),
            act_bits: Some(vec![8, 4]),
            wall_s: 0.0,
        }
    }

    #[test]
    fn packed_roundtrip_is_bit_identical_and_smaller() {
        let out = grid_outcome();
        let art = PackedModel::from_outcome(&out, Some(&[12.5, 3.25])).unwrap();
        assert!(art
            .layers
            .iter()
            .all(|l| l.encoding == Encoding::Packed));
        let dir = tmpdir("roundtrip");
        art.save(&dir).unwrap();
        let back = PackedModel::load(&dir).unwrap();
        assert_eq!(back.format_version, 2);
        assert_eq!(back.model, "m");
        assert_eq!(back.method, "nearest");
        assert_eq!(back.layers[1].name, "head.fc");
        assert_eq!(back.layers[0].coding_length, Some(12.5));
        assert_eq!(back.act_bits.as_deref(), Some(&[8u8, 4][..]));
        for li in 0..2 {
            let deq = back.dequantize(li).unwrap();
            assert_eq!(deq, out.qweights[li], "layer {li} must round-trip exactly");
        }
        // real storage win: 4b + 3b vs 32b
        assert!(back.payload_bytes() * 4 < back.f32_bytes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn off_grid_layer_falls_back_to_f32_losslessly() {
        let mut out = grid_outcome();
        // clearly off-grid values
        out.qweights[0] = Tensor::new(vec![24, 8], vec![0.0137; 24 * 8]).unwrap();
        let art = PackedModel::from_outcome(&out, None).unwrap();
        assert_eq!(art.layers[0].encoding, Encoding::F32);
        assert_eq!(art.layers[1].encoding, Encoding::Packed);
        let dir = tmpdir("fallback");
        art.save(&dir).unwrap();
        let back = PackedModel::load(&dir).unwrap();
        assert_eq!(back.dequantize(0).unwrap(), out.qweights[0]);
        assert_eq!(back.dequantize(1).unwrap(), out.qweights[1]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn loader_rejects_corrupt_stream_and_bad_scales() {
        let out = grid_outcome();
        let art = PackedModel::from_outcome(&out, None).unwrap();
        let dir = tmpdir("corrupt");
        art.save(&dir).unwrap();
        // flip one payload byte -> checksum mismatch
        let f = dir.join(&art.layers[0].file);
        let mut bytes = std::fs::read(&f).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&f, &bytes).unwrap();
        let e = PackedModel::load(&dir).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");
        // restore, then poison a scale in the header
        bytes[0] ^= 0xFF;
        std::fs::write(&f, &bytes).unwrap();
        let hdr = std::fs::read_to_string(dir.join("qmodel.json")).unwrap();
        assert!(hdr.contains("\"scale\": 0.25"), "{hdr}");
        std::fs::write(
            dir.join("qmodel.json"),
            hdr.replace("\"scale\": 0.25", "\"scale\": -1"),
        )
        .unwrap();
        let e = PackedModel::load(&dir).unwrap_err();
        assert!(e.to_string().contains("scale"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn unknown_future_version_is_rejected() {
        let dir = tmpdir("future");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("qmodel.json"),
            r#"{"format_version": 4, "model": "m", "method": "x", "acc": 0,
                "fp_acc": 0, "layers": []}"#,
        )
        .unwrap();
        assert!(PackedModel::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chunked_roundtrip_is_lossless_including_f32_fallback() {
        let mut out = grid_outcome();
        // one off-grid layer so the qpak carries both encodings
        out.qweights[0] = Tensor::new(vec![24, 8], vec![0.0137; 24 * 8]).unwrap();
        let art = PackedModel::from_outcome(&out, Some(&[12.5, 3.25])).unwrap();
        assert_eq!(art.layers[0].encoding, Encoding::F32);
        assert_eq!(art.layers[1].encoding, Encoding::Packed);
        let dir = tmpdir("chunked");
        let m = art.save_chunked(&dir, 2, 1).unwrap();
        assert_eq!(m.chunks.len(), 2);
        assert_eq!(m.full_depth(), 2);
        assert!(dir.join(QPAK_FILE).is_file());
        let back = PackedModel::load(&dir).unwrap();
        assert_eq!(back.format_version, 3);
        assert_eq!(back.layers[0].coding_length, Some(12.5));
        assert_eq!(back.act_bits.as_deref(), Some(&[8u8, 4][..]));
        for li in 0..2 {
            assert_eq!(
                back.dequantize(li).unwrap(),
                out.qweights[li],
                "layer {li} must round-trip exactly through the chunked layout"
            );
        }
        // meta-only open agrees with the manifest
        let meta = load_v3_meta(&dir).unwrap();
        assert_eq!(meta.manifest, m);
        assert_eq!(meta.layer_offset(1) as usize, meta.payload_lens[0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chunked_loader_rejects_truncation_corruption_and_bad_depth() {
        let out = grid_outcome();
        let art = PackedModel::from_outcome(&out, None).unwrap();
        let dir = tmpdir("chunked_reject");
        art.save_chunked(&dir, 2, 2).unwrap();
        let qpak = dir.join(QPAK_FILE);
        let orig = std::fs::read(&qpak).unwrap();
        // truncated .qpak
        std::fs::write(&qpak, &orig[..orig.len() - 1]).unwrap();
        let e = PackedModel::load(&dir).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");
        // corrupted chunk byte -> chunk checksum mismatch
        let mut bad = orig.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&qpak, &bad).unwrap();
        let e = PackedModel::load(&dir).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch"), "{e}");
        std::fs::write(&qpak, &orig).unwrap();
        assert!(PackedModel::load(&dir).is_ok());
        // zero min_runnable_depth in the manifest
        let mf = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mf).unwrap();
        std::fs::write(
            &mf,
            text.replace("\"min_runnable_depth\": 2", "\"min_runnable_depth\": 0"),
        )
        .unwrap();
        let e = PackedModel::load(&dir).unwrap_err().to_string();
        assert!(e.contains("min_runnable_depth must be > 0"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_chunked_rejects_over_depth_min_runnable() {
        let art = PackedModel::from_outcome(&grid_outcome(), None).unwrap();
        let dir = tmpdir("chunked_depth");
        let e = art.save_chunked(&dir, 2, 3).unwrap_err().to_string();
        assert!(e.contains("exceeds"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_channel_roundtrip_is_lossless_v2_and_v3() {
        // channel c of a [6, 4] layer uses scale ss[c]; every element
        // sits exactly on its channel grid
        let ss = vec![0.25f32, 0.5, 0.125, 1.0];
        let mut w = Vec::with_capacity(6 * 4);
        for i in 0..6 * 4 {
            let q = (i as i64 % 15) - 7; // in the signed 4-bit range
            w.push(ss[i % 4] * q as f32);
        }
        let t = Tensor::new(vec![6, 4], w).unwrap();
        let art = PackedModel::from_per_channel(
            "pc",
            "perchannel",
            0.5,
            0.9,
            vec![("fc".to_string(), 4, ss.clone(), t.clone())],
        )
        .unwrap();
        assert_eq!(art.layers[0].encoding, Encoding::Packed);
        assert_eq!(art.layers[0].scales.as_deref(), Some(&ss[..]));

        let dir = tmpdir("per_channel_v2");
        art.save(&dir).unwrap();
        let hdr = std::fs::read_to_string(dir.join("qmodel.json")).unwrap();
        assert!(hdr.contains("\"scales\""), "{hdr}");
        assert!(hdr.contains("\"scale_axis\": 1"), "{hdr}");
        let back = PackedModel::load(&dir).unwrap();
        assert_eq!(back.layers[0].scales.as_deref(), Some(&ss[..]));
        assert_eq!(back.dequantize(0).unwrap(), t);
        match back.layer_view(0).unwrap() {
            LayerView::Packed { scales, .. } => {
                assert_eq!(scales, Some(&ss[..]));
            }
            LayerView::F32(_) => panic!("expected the packed encoding"),
        }
        std::fs::remove_dir_all(&dir).unwrap();

        let dir = tmpdir("per_channel_v3");
        art.save_chunked(&dir, 1, 1).unwrap();
        let back = PackedModel::load(&dir).unwrap();
        assert_eq!(back.layers[0].scales.as_deref(), Some(&ss[..]));
        assert_eq!(back.dequantize(0).unwrap(), t);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn per_channel_rejects_off_grid_and_bad_scales() {
        let t = Tensor::new(vec![2, 2], vec![0.3, 0.3, 0.3, 0.3]).unwrap();
        // off the 0.25/0.5 channel grids
        assert!(PackedModel::from_per_channel(
            "pc",
            "perchannel",
            0.0,
            0.0,
            vec![("fc".to_string(), 4, vec![0.25, 0.5], t.clone())],
        )
        .is_err());
        // arity mismatch: 3 scales for 2 channels
        assert!(PackedModel::from_per_channel(
            "pc",
            "perchannel",
            0.0,
            0.0,
            vec![("fc".to_string(), 4, vec![0.25, 0.5, 0.125], t)],
        )
        .is_err());
    }
}
