//! Chunk manifest for the v3 chunked artifact layout.
//!
//! A v3 artifact concatenates contiguous layer-range *chunks* into one
//! mmap-friendly `qmodel.qpak` file and describes them in
//! `manifest.json`:
//!
//! ```json
//! {
//!   "chunks": [
//!     {"id": 0, "layer_start": 0, "layer_end": 2, "bytes": 4096,
//!      "checksum": "af63dc4c8601ec8c"},
//!     {"id": 1, "layer_start": 2, "layer_end": 4, "bytes": 1024,
//!      "checksum": "…"}
//!   ],
//!   "min_runnable_depth": 1
//! }
//! ```
//!
//! Chunks are contiguous, non-overlapping, gap-free layer ranges in id
//! order; `bytes` is the chunk's extent in `qmodel.qpak` (chunk `k`
//! starts at the sum of all earlier chunks' `bytes` — the manifest *is*
//! the offset table), and `checksum` is the FNV-1a-64 hex digest of that
//! extent. `min_runnable_depth` counts **chunks**, not layers: a
//! progressive server may start answering truncated-depth requests once
//! the first `min_runnable_depth` chunks have verified
//! ([`crate::deploy::progressive`]).
//!
//! Every malformed shape is a typed [`Error::Parse`] so loaders fail
//! loudly instead of serving a half-wired model: empty chunk lists, zero
//! or over-depth `min_runnable_depth`, empty per-chunk layer ranges, and
//! overlapping or gapped ranges are all rejected by [`ArtifactManifest::
//! validate`]; offset/length/checksum mismatches against the actual
//! `.qpak` bytes are rejected by the v3 loader in
//! [`crate::deploy::artifact`].

use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};

/// File name of the manifest inside an artifact directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// File name of the concatenated chunk payload file.
pub const QPAK_FILE: &str = "qmodel.qpak";

/// One contiguous layer-range chunk inside `qmodel.qpak`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkEntry {
    /// Sequential chunk id (== index in [`ArtifactManifest::chunks`]).
    pub id: usize,
    /// First layer in the chunk (inclusive).
    pub layer_start: usize,
    /// One past the last layer in the chunk (exclusive).
    pub layer_end: usize,
    /// Extent of the chunk in `qmodel.qpak`, in bytes.
    pub bytes: u64,
    /// FNV-1a-64 hex digest of the chunk's bytes.
    pub checksum: String,
}

impl ChunkEntry {
    /// Number of layers covered by this chunk.
    pub fn layers(&self) -> usize {
        self.layer_end.saturating_sub(self.layer_start)
    }
}

/// Parsed, validated `manifest.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactManifest {
    /// Chunks in id order; contiguous and gap-free over `0..n_layers`.
    pub chunks: Vec<ChunkEntry>,
    /// Minimum number of **chunks** (a model prefix) that must be
    /// resident before partial-depth serving may begin.
    pub min_runnable_depth: usize,
}

impl ArtifactManifest {
    /// Split `n_layers` into `n_chunks` contiguous, balanced layer
    /// ranges (earlier chunks take the remainder, so sizes differ by at
    /// most one layer). `n_chunks` is clamped to `n_layers`.
    pub fn plan_chunks(n_layers: usize, n_chunks: usize) -> Result<Vec<(usize, usize)>> {
        if n_layers == 0 {
            return Err(Error::parse("plan_chunks: model has no layers"));
        }
        if n_chunks == 0 {
            return Err(Error::parse("plan_chunks: chunk count must be > 0"));
        }
        let k = n_chunks.min(n_layers);
        let base = n_layers / k;
        let extra = n_layers % k;
        let mut ranges = Vec::with_capacity(k);
        let mut start = 0usize;
        for i in 0..k {
            let len = base + usize::from(i < extra);
            ranges.push((start, start + len));
            start += len;
        }
        Ok(ranges)
    }

    /// Structural validation against a model with `n_layers` layers.
    ///
    /// Mirrors the reference manifest test matrix: rejects empty chunk
    /// lists, zero / over-depth `min_runnable_depth`, empty per-chunk
    /// ranges, out-of-order ids, and overlapping or gapped ranges.
    pub fn validate(&self, n_layers: usize) -> Result<()> {
        if self.chunks.is_empty() {
            return Err(Error::parse("manifest.json: chunks cannot be empty"));
        }
        if self.min_runnable_depth == 0 {
            return Err(Error::parse(
                "manifest.json: min_runnable_depth must be > 0",
            ));
        }
        if self.min_runnable_depth > self.chunks.len() {
            return Err(Error::parse(format!(
                "manifest.json: min_runnable_depth {} exceeds the {} available chunks",
                self.min_runnable_depth,
                self.chunks.len()
            )));
        }
        let mut expect_start = 0usize;
        for (i, c) in self.chunks.iter().enumerate() {
            if c.id != i {
                return Err(Error::parse(format!(
                    "manifest.json: chunk at index {i} has id {} (ids must be sequential)",
                    c.id
                )));
            }
            if c.layer_end <= c.layer_start {
                return Err(Error::parse(format!(
                    "manifest.json: chunk {} covers an empty layer range {}..{}",
                    c.id, c.layer_start, c.layer_end
                )));
            }
            if c.layer_start != expect_start {
                return Err(Error::parse(format!(
                    "manifest.json: chunk {} starts at layer {} but the previous \
                     chunk ends at {} (ranges must be contiguous, neither \
                     overlapping nor gapped)",
                    c.id, c.layer_start, expect_start
                )));
            }
            expect_start = c.layer_end;
        }
        if expect_start != n_layers {
            return Err(Error::parse(format!(
                "manifest.json: chunks cover layers 0..{expect_start} but the \
                 model has {n_layers} layers"
            )));
        }
        Ok(())
    }

    /// Byte offset of chunk `idx` inside `qmodel.qpak` (the manifest's
    /// `bytes` fields are the offset table).
    pub fn chunk_offset(&self, idx: usize) -> u64 {
        self.chunks[..idx].iter().map(|c| c.bytes).sum()
    }

    /// Total `qmodel.qpak` size implied by the manifest.
    pub fn total_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.bytes).sum()
    }

    /// Full model depth (layers) implied by the manifest.
    pub fn full_depth(&self) -> usize {
        self.chunks.last().map(|c| c.layer_end).unwrap_or(0)
    }

    /// Layer depth reached once the first `resident` chunks are loaded.
    pub fn depth_at(&self, resident: usize) -> usize {
        if resident == 0 {
            0
        } else {
            self.chunks[resident.min(self.chunks.len()) - 1].layer_end
        }
    }

    // ---- JSON codec -------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "chunks",
                Json::arr(
                    self.chunks
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("id", Json::num(c.id as f64)),
                                ("layer_start", Json::num(c.layer_start as f64)),
                                ("layer_end", Json::num(c.layer_end as f64)),
                                ("bytes", Json::num(c.bytes as f64)),
                                ("checksum", Json::str(c.checksum.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "min_runnable_depth",
                Json::num(self.min_runnable_depth as f64),
            ),
        ])
    }

    /// Parse (without structural validation — callers follow up with
    /// [`ArtifactManifest::validate`] once the layer count is known).
    pub fn from_json(j: &Json) -> Result<ArtifactManifest> {
        let min_runnable_depth = j.get("min_runnable_depth")?.as_usize()?;
        let mut chunks = Vec::new();
        for c in j.get("chunks")?.as_arr()? {
            chunks.push(ChunkEntry {
                id: c.get("id")?.as_usize()?,
                layer_start: c.get("layer_start")?.as_usize()?,
                layer_end: c.get("layer_end")?.as_usize()?,
                bytes: c.get("bytes")?.as_f64()? as u64,
                checksum: c.get("checksum")?.as_str()?.to_string(),
            });
        }
        Ok(ArtifactManifest {
            chunks,
            min_runnable_depth,
        })
    }

    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        Self::from_json(&json::parse(text)?)
    }

    /// Write `manifest.json` into an artifact directory.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = dir.join(MANIFEST_FILE);
        std::fs::write(&path, self.to_json().to_string_pretty())
            .map_err(|e| Error::parse(format!("writing {}: {e}", path.display())))?;
        Ok(())
    }

    /// Read and parse `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        Self::from_json(&json::parse_file(&dir.join(MANIFEST_FILE))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ArtifactManifest {
        ArtifactManifest {
            chunks: vec![
                ChunkEntry {
                    id: 0,
                    layer_start: 0,
                    layer_end: 2,
                    bytes: 128,
                    checksum: "00".repeat(8),
                },
                ChunkEntry {
                    id: 1,
                    layer_start: 2,
                    layer_end: 3,
                    bytes: 64,
                    checksum: "11".repeat(8),
                },
            ],
            min_runnable_depth: 1,
        }
    }

    #[test]
    fn validates_correct_manifest() {
        sample().validate(3).unwrap();
    }

    #[test]
    fn rejects_empty_chunks() {
        let m = ArtifactManifest {
            chunks: Vec::new(),
            min_runnable_depth: 1,
        };
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("chunks cannot be empty"), "{e}");
    }

    #[test]
    fn rejects_zero_min_runnable_depth() {
        let mut m = sample();
        m.min_runnable_depth = 0;
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("min_runnable_depth must be > 0"), "{e}");
    }

    #[test]
    fn rejects_over_depth_min_runnable() {
        let mut m = sample();
        m.min_runnable_depth = 3;
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("exceeds"), "{e}");
    }

    #[test]
    fn rejects_empty_layer_range() {
        let mut m = sample();
        m.chunks[1].layer_end = 2; // start == end
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("empty layer range"), "{e}");
    }

    #[test]
    fn rejects_overlapping_ranges() {
        let mut m = sample();
        m.chunks[1].layer_start = 1; // overlaps chunk 0's 0..2
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("contiguous"), "{e}");
    }

    #[test]
    fn rejects_gapped_ranges() {
        let mut m = sample();
        m.chunks[1].layer_start = 3;
        m.chunks[1].layer_end = 4;
        let e = m.validate(4).unwrap_err().to_string();
        assert!(e.contains("contiguous"), "{e}");
    }

    #[test]
    fn rejects_out_of_order_ids() {
        let mut m = sample();
        m.chunks[1].id = 5;
        let e = m.validate(3).unwrap_err().to_string();
        assert!(e.contains("sequential"), "{e}");
    }

    #[test]
    fn rejects_wrong_total_coverage() {
        let e = sample().validate(5).unwrap_err().to_string();
        assert!(e.contains("5 layers"), "{e}");
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let m = sample();
        let back = ArtifactManifest::parse(&m.to_json().to_string_pretty()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn invalid_json_is_a_parse_error() {
        assert!(ArtifactManifest::parse("{not json").is_err());
        // structurally valid JSON, wrong schema
        assert!(ArtifactManifest::parse("{\"chunks\": 3}").is_err());
        assert!(ArtifactManifest::parse("[]").is_err());
    }

    #[test]
    fn offsets_follow_the_bytes_fields() {
        let m = sample();
        assert_eq!(m.chunk_offset(0), 0);
        assert_eq!(m.chunk_offset(1), 128);
        assert_eq!(m.total_bytes(), 192);
        assert_eq!(m.full_depth(), 3);
        assert_eq!(m.depth_at(0), 0);
        assert_eq!(m.depth_at(1), 2);
        assert_eq!(m.depth_at(2), 3);
    }

    #[test]
    fn plan_chunks_is_balanced_and_contiguous() {
        assert_eq!(
            ArtifactManifest::plan_chunks(5, 3).unwrap(),
            vec![(0, 2), (2, 4), (4, 5)]
        );
        assert_eq!(ArtifactManifest::plan_chunks(2, 8).unwrap(), vec![(0, 1), (1, 2)]);
        assert_eq!(ArtifactManifest::plan_chunks(4, 1).unwrap(), vec![(0, 4)]);
        assert!(ArtifactManifest::plan_chunks(0, 2).is_err());
        assert!(ArtifactManifest::plan_chunks(4, 0).is_err());
    }
}
