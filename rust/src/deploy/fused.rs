//! Fused dequant-matmul: multiply activations straight off the packed
//! bitstream, so a forward off an artifact never materializes a whole
//! f32 layer anywhere.
//!
//! The unfused serving path was: unpack all `n·m` codes → dequantize
//! into a full f32 scratch → widen into a second full f64 `Mat` → ikj
//! matmul. For the zoo's 1152×128 layer that is ~1.7 MB of scratch
//! traffic per layer per batch before the first multiply. This kernel
//! walks the LSB-first bitstream in cache-sized **column panels** of
//! [`PANEL_ELEMS`] elements (whole weight rows at a time): each panel
//! is unpacked with the group-unrolled `bitpack::unpack_range`,
//! dequantized panel-locally, and immediately consumed by the
//! [`crate::linalg::simd::axpy`] inner loop — codes stream through L1/L2
//! and are gone.
//!
//! ## Bit-identity
//!
//! The result is `assert_eq!`-identical to unpack → dequantize →
//! [`crate::linalg::Mat::matmul_with`], because every per-element
//! operation is literally the same, in the same order:
//!
//! * dequant is the same single f32 multiply `s · ((c + lo) as f32)`
//!   the artifact path applies, then the same exact f32→f64 widening
//!   `Mat::from_rows_f32` performs (widening is exact, so doing it
//!   per-panel instead of per-layer changes nothing);
//! * the k-loop visits weight rows in ascending order — panels ascend,
//!   rows ascend within a panel — exactly like `matmul_with`'s ikj
//!   loop, and each `c[i][j]` sees one `+ a[i][k]·w[k][j]` per k with
//!   separate mul and add (no FMA);
//! * row-block parallelism partitions output rows, which are
//!   independent accumulators.
//!
//! Property-tested against the unfused path across widths 2–8, ragged
//! shapes, and pool widths in this module and rust/tests/fused_kernel.rs.

use crate::linalg::simd;
use crate::util::error::{Error, Result};
use crate::util::threadpool::{ThreadPool, MIN_PAR_CHUNK};

use super::bitpack;

/// Borrowed packed weight matrix: `n × m` codes (row-major, row =
/// input channel) at `bits` per code in an LSB-first bitstream.
#[derive(Debug, Clone, Copy)]
pub struct PackedWeight<'a> {
    pub bytes: &'a [u8],
    pub bits: u8,
    pub scale: f32,
    /// Per-output-channel scales over the last axis (length `m`).
    /// `None` = per-tensor: every code dequantizes with `scale`.
    /// `Some` overrides `scale`; column `j` uses `scales[j]`.
    pub scales: Option<&'a [f32]>,
    /// Input dimension (weight rows).
    pub n: usize,
    /// Output dimension (weight columns).
    pub m: usize,
}

/// Elements per dequant panel: 8192 codes ≈ 32 KiB unpacked + 64 KiB
/// widened — panel scratch for a worker stays L1/L2-resident while the
/// packed source bytes (2–8 KiB per panel) stream through.
const PANEL_ELEMS: usize = 8192;

/// out[rows × m] = a[rows × n] · dequant(pw), accumulated in f64 —
/// bit-identical to dequantizing the whole layer and calling
/// [`crate::linalg::Mat::matmul_with`] (see the module docs for why).
/// `out` is cleared and resized; row blocks fan out across `pool`, and
/// each worker owns its panel scratch (~96 KiB) — no shared state, no
/// lock.
pub fn matmul_packed_with(
    pool: &ThreadPool,
    a: &[f32],
    rows: usize,
    pw: &PackedWeight<'_>,
    out: &mut Vec<f64>,
) -> Result<()> {
    if !(bitpack::MIN_BITS..=bitpack::MAX_BITS).contains(&pw.bits) {
        return Err(Error::config(format!(
            "fused matmul: width {} out of range {}..={}",
            pw.bits,
            bitpack::MIN_BITS,
            bitpack::MAX_BITS
        )));
    }
    if a.len() != rows * pw.n {
        return Err(Error::shape(format!(
            "fused matmul: {} activations for {rows}x{}",
            a.len(),
            pw.n
        )));
    }
    let need = bitpack::packed_len(pw.n * pw.m, pw.bits);
    if pw.bytes.len() != need {
        return Err(Error::shape(format!(
            "fused matmul: {}x{} codes at {}b need {need} bytes, got {}",
            pw.n,
            pw.m,
            pw.bits,
            pw.bytes.len()
        )));
    }
    if let Some(ss) = pw.scales {
        if ss.len() != pw.m {
            return Err(Error::shape(format!(
                "fused matmul: {} per-channel scales for {} output channels",
                ss.len(),
                pw.m
            )));
        }
    }
    let (n, m) = (pw.n, pw.m);
    out.clear();
    out.resize(rows * m, 0.0);
    if rows == 0 || n == 0 || m == 0 {
        return Ok(());
    }
    let (s, bits) = (pw.scale, pw.bits);
    let lo = -(1i64 << (bits - 1));
    let bytes = pw.bytes;
    // Whole weight rows per panel, so each panel is a contiguous code
    // range [t0·m, t1·m) and a contiguous j-stripe of every activation
    // row.
    let panel_rows = (PANEL_ELEMS / m).clamp(1, n);
    let fill = |first_row: usize, block: &mut [f64]| {
        // per-worker panel scratch — each row block owns its own
        let mut codes = vec![0u32; panel_rows * m];
        let mut wpanel = vec![0.0f64; panel_rows * m];
        let mut t0 = 0usize;
        while t0 < n {
            let t1 = (t0 + panel_rows).min(n);
            let cnt = (t1 - t0) * m;
            bitpack::unpack_range(bytes, bits, t0 * m, &mut codes[..cnt]);
            // same f32 multiply as dequantize_layer_into, then the same
            // exact widening Mat::from_rows_f32 performs. Panels start
            // on whole-row boundaries (t0·m), so within the panel
            // element k's output channel is simply k % m.
            match pw.scales {
                None => {
                    for (wv, &c) in wpanel[..cnt].iter_mut().zip(&codes[..cnt]) {
                        *wv = (s * ((c as i64 + lo) as f32)) as f64;
                    }
                }
                Some(ss) => {
                    for (k, (wv, &c)) in
                        wpanel[..cnt].iter_mut().zip(&codes[..cnt]).enumerate()
                    {
                        *wv = (ss[k % m] * ((c as i64 + lo) as f32)) as f64;
                    }
                }
            }
            for (bi, crow) in block.chunks_mut(m).enumerate() {
                let i = first_row + bi;
                let arow = &a[i * n + t0..i * n + t1];
                for (dt, &av) in arow.iter().enumerate() {
                    simd::axpy(crow, av as f64, &wpanel[dt * m..dt * m + m]);
                }
            }
            t0 = t1;
        }
    };
    if rows * n * m < MIN_PAR_CHUNK {
        fill(0, out);
    } else {
        pool.par_row_blocks(out, m, fill);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    /// The unfused reference: unpack all codes, dequantize into a full
    /// f32 layer, widen into Mats, matmul.
    fn unfused(
        pool: &ThreadPool,
        a: &[f32],
        rows: usize,
        pw: &PackedWeight<'_>,
    ) -> Vec<f64> {
        let mut codes = vec![0u32; pw.n * pw.m];
        bitpack::unpack_into(pw.bytes, pw.bits, &mut codes).unwrap();
        let lo = -(1i64 << (pw.bits - 1));
        let w: Vec<f32> = codes
            .iter()
            .map(|&c| pw.scale * ((c as i64 + lo) as f32))
            .collect();
        let am = Mat::from_rows_f32(rows, pw.n, a).unwrap();
        let wm = Mat::from_rows_f32(pw.n, pw.m, &w).unwrap();
        am.matmul_with(pool, &wm).unwrap().data
    }

    fn random_packed(n: usize, m: usize, bits: u8, seed: u64) -> (Vec<u8>, f32) {
        let mut rng = Rng::new(seed);
        let codes: Vec<u32> = (0..n * m)
            .map(|_| rng.below(1usize << bits) as u32)
            .collect();
        (bitpack::pack(&codes, bits).unwrap(), 0.01 + bits as f32 * 0.003)
    }

    #[test]
    fn fused_matches_unfused_across_widths_and_shapes() {
        let seq = ThreadPool::seq();
        for bits in bitpack::MIN_BITS..=bitpack::MAX_BITS {
            for &(rows, n, m) in &[
                (1usize, 1usize, 1usize),
                (7, 5, 3),
                (16, 9, 4),
                (33, 17, 10),
                (8, 128, 16),
                (64, 31, 2),
            ] {
                let (bytes, scale) = random_packed(n, m, bits, 31 * n as u64 + bits as u64);
                let pw = PackedWeight { bytes: &bytes, bits, scale, scales: None, n, m };
                let mut act = vec![0.0f32; rows * n];
                Rng::new(77 + rows as u64).fill_gaussian(&mut act, 0.0, 1.0);
                let mut got = Vec::new();
                matmul_packed_with(&seq, &act, rows, &pw, &mut got).unwrap();
                let want = unfused(&seq, &act, rows, &pw);
                assert_eq!(got, want, "bits={bits} {rows}x{n}x{m}");
            }
        }
    }

    #[test]
    fn parallel_fused_bit_identical_to_sequential() {
        // big enough to cross MIN_PAR_CHUNK and fan out for real
        let (rows, n, m) = (24, 300, 40);
        let (bytes, scale) = random_packed(n, m, 4, 0xF05);
        let pw = PackedWeight { bytes: &bytes, bits: 4, scale, scales: None, n, m };
        let mut act = vec![0.0f32; rows * n];
        Rng::new(0xAC7).fill_gaussian(&mut act, 0.0, 0.5);
        let mut seq_out = Vec::new();
        matmul_packed_with(&ThreadPool::seq(), &act, rows, &pw, &mut seq_out).unwrap();
        for width in [2usize, 8] {
            let mut par_out = Vec::new();
            matmul_packed_with(&ThreadPool::new(width), &act, rows, &pw, &mut par_out)
                .unwrap();
            assert_eq!(seq_out, par_out, "pool width {width}");
        }
        assert_eq!(seq_out, unfused(&ThreadPool::seq(), &act, rows, &pw));
    }

    #[test]
    fn zero_weights_and_zero_scale() {
        let seq = ThreadPool::seq();
        let (n, m, bits) = (12usize, 5usize, 4u8);
        // code 2^(b-1) is grid point 0 at every width
        let codes = vec![1u32 << (bits - 1); n * m];
        let bytes = bitpack::pack(&codes, bits).unwrap();
        let act = vec![1.0f32; 3 * n];
        let pw = PackedWeight { bytes: &bytes, bits, scale: 0.07, scales: None, n, m };
        let mut out = Vec::new();
        matmul_packed_with(&seq, &act, 3, &pw, &mut out).unwrap();
        assert_eq!(out, unfused(&seq, &act, 3, &pw));
        assert!(out.iter().all(|&v| v == 0.0));
        // scale 0 collapses every weight to ±0.0
        let (bytes2, _) = random_packed(n, m, bits, 5);
        let pw0 = PackedWeight { bytes: &bytes2, bits, scale: 0.0, scales: None, n, m };
        let mut out0 = Vec::new();
        matmul_packed_with(&seq, &act, 3, &pw0, &mut out0).unwrap();
        assert_eq!(out0, unfused(&seq, &act, 3, &pw0));
    }

    #[test]
    fn rejects_bad_shapes_and_widths() {
        let (bytes, scale) = random_packed(4, 4, 4, 1);
        let act = vec![0.0f32; 8];
        let mut out = Vec::new();
        let bad_bits =
            PackedWeight { bytes: &bytes, bits: 9, scale, scales: None, n: 4, m: 4 };
        assert!(matmul_packed_with(&ThreadPool::seq(), &act, 2, &bad_bits, &mut out).is_err());
        let pw = PackedWeight { bytes: &bytes, bits: 4, scale, scales: None, n: 4, m: 4 };
        assert!(matmul_packed_with(&ThreadPool::seq(), &act, 3, &pw, &mut out).is_err());
        let short =
            PackedWeight { bytes: &bytes[..4], bits: 4, scale, scales: None, n: 4, m: 4 };
        assert!(matmul_packed_with(&ThreadPool::seq(), &act, 2, &short, &mut out).is_err());
        // per-channel scales must cover every output channel
        let wrong = vec![0.1f32; 3];
        let bad_ss = PackedWeight {
            bytes: &bytes,
            bits: 4,
            scale,
            scales: Some(&wrong),
            n: 4,
            m: 4,
        };
        assert!(matmul_packed_with(&ThreadPool::seq(), &act, 2, &bad_ss, &mut out).is_err());
    }

    /// Unfused reference for the per-channel path: dequantize column j
    /// with scales[j], then the plain widened matmul.
    fn unfused_per_channel(
        pool: &ThreadPool,
        a: &[f32],
        rows: usize,
        pw: &PackedWeight<'_>,
        ss: &[f32],
    ) -> Vec<f64> {
        let mut codes = vec![0u32; pw.n * pw.m];
        bitpack::unpack_into(pw.bytes, pw.bits, &mut codes).unwrap();
        let lo = -(1i64 << (pw.bits - 1));
        let w: Vec<f32> = codes
            .iter()
            .enumerate()
            .map(|(i, &c)| ss[i % pw.m] * ((c as i64 + lo) as f32))
            .collect();
        let am = Mat::from_rows_f32(rows, pw.n, a).unwrap();
        let wm = Mat::from_rows_f32(pw.n, pw.m, &w).unwrap();
        am.matmul_with(pool, &wm).unwrap().data
    }

    #[test]
    fn per_channel_fused_matches_unfused_and_parallel() {
        let (rows, n, m, bits) = (9usize, 130usize, 12usize, 4u8);
        let (bytes, _) = random_packed(n, m, bits, 0xC0DE);
        let ss: Vec<f32> = (0..m).map(|j| 0.01 + j as f32 * 0.007).collect();
        let pw = PackedWeight {
            bytes: &bytes,
            bits,
            scale: ss[0],
            scales: Some(&ss),
            n,
            m,
        };
        let mut act = vec![0.0f32; rows * n];
        Rng::new(0xBEE).fill_gaussian(&mut act, 0.0, 1.0);
        let seq = ThreadPool::seq();
        let mut got = Vec::new();
        matmul_packed_with(&seq, &act, rows, &pw, &mut got).unwrap();
        let want = unfused_per_channel(&seq, &act, rows, &pw, &ss);
        assert_eq!(got, want, "per-channel fused must match unfused reference");
        // and the row-block parallel split must not change a bit
        let mut par = Vec::new();
        matmul_packed_with(&ThreadPool::new(4), &act, rows, &pw, &mut par).unwrap();
        assert_eq!(got, par);
    }
}
