//! LSB-first bitstream packing of integer quantization codes at
//! arbitrary widths 2–8.
//!
//! A quantized layer is a vector of integer grid indices ("codes") plus
//! a scale; storing them as f32 (the v1 artifact format) wastes
//! 32 − bits bits per weight. This module packs codes back-to-back into
//! a byte stream: code `i` occupies bits `[i·b, (i+1)·b)` of the stream,
//! least-significant-bit first within each byte — the layout every
//! standard bitstream reader expects, and self-describing given `(n,
//! bits)`.
//!
//! ## Exactness and determinism
//!
//! Packing is a pure function of `(codes, bits)`: the parallel variants
//! split the code vector at [`GROUP`]-aligned element boundaries (8
//! codes at width b occupy exactly b bytes, so every block starts
//! byte-aligned for **any** width 2–8) and write disjoint output
//! ranges, making them bit-identical to the sequential form by
//! construction — property-tested in this module. Pad bits in the final
//! partial byte are always zero, which the artifact loader verifies.
//!
//! ## Control flow
//!
//! The bulk of every (un)pack runs 8 codes at a time: a whole
//! [`GROUP`] at width `b` is exactly one little-endian u64 worth of
//! `b` bytes, so the group cores do a single `to_le_bytes`/
//! `from_le_bytes` per group with fully unrolled shift/mask extracts —
//! no per-bit loop, no data-dependent branches (same discipline as
//! `quant::kernel`). Ragged heads/tails fall back to the streaming u64
//! accumulator, which also powers [`unpack_range`], the random-access
//! entry the fused dequant-matmul kernel uses to walk a packed layer
//! panel by panel from any (generally mid-byte) element offset.

use crate::util::error::{Error, Result};
use crate::util::threadpool::ThreadPool;

/// Narrowest packable width (a 1-bit grid has no sign bit to carry).
pub const MIN_BITS: u8 = 2;
/// Widest packable width (wider layers ship as f32 — see
/// `deploy::artifact`).
pub const MAX_BITS: u8 = 8;

/// Elements per byte-aligned packing group: 8 codes at width `b` occupy
/// exactly `b` bytes, so any multiple of 8 elements starts a new block
/// on a byte boundary for every width 2–8.
const GROUP: usize = 8;

/// Smallest per-block element count worth forking a scoped worker for
/// (packing is a few ops per element; mirror the pool's chunk gate).
const MIN_PACK_BLOCK: usize = 16 * 1024;

/// Packed byte length of `n` codes at `bits` per code.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

fn check_bits(bits: u8) -> Result<()> {
    if !(MIN_BITS..=MAX_BITS).contains(&bits) {
        return Err(Error::config(format!(
            "bitpack: width {bits} out of range {MIN_BITS}..={MAX_BITS}"
        )));
    }
    Ok(())
}

fn check_lens(n_codes: usize, n_bytes: usize, bits: u8) -> Result<()> {
    let need = packed_len(n_codes, bits);
    if n_bytes != need {
        return Err(Error::shape(format!(
            "bitpack: {n_codes} codes at {bits}b need {need} bytes, got {n_bytes}"
        )));
    }
    Ok(())
}

/// Streaming packing core: byte-at-a-time u64 accumulator. Handles any
/// element count; the group-unrolled fast path below handles the
/// GROUP-aligned bulk and leaves this for the ragged tail.
fn pack_stream(codes: &[u32], bits: u8, out: &mut [u8]) {
    let bits = bits as u32;
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut oi = 0usize;
    for &c in codes {
        acc |= (c as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[oi] = acc as u8;
            oi += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        // final partial byte: high pad bits are zero (acc was shifted)
        out[oi] = acc as u8;
    }
}

/// Streaming unpacking core starting at an arbitrary element index
/// `start` of the stream (the first code read begins at bit
/// `start·bits`, which is mid-byte for most offsets). Mirror of
/// [`pack_stream`] when `start == 0`.
fn unpack_stream_at(bytes: &[u8], bits: u8, start: usize, out: &mut [u32]) {
    if out.is_empty() {
        return;
    }
    let bits = bits as u32;
    let mask = (1u64 << bits) - 1;
    let bitpos = start * bits as usize;
    let lead = (bitpos % 8) as u32;
    let mut bi = bitpos / 8;
    let mut acc = (bytes[bi] as u64) >> lead;
    let mut nbits = 8 - lead;
    bi += 1;
    for o in out.iter_mut() {
        while nbits < bits {
            acc |= (bytes[bi] as u64) << nbits;
            bi += 1;
            nbits += 8;
        }
        *o = (acc & mask) as u32;
        acc >>= bits;
        nbits -= bits;
    }
}

/// 8-wide unrolled packing over whole groups: 8 codes at width `b`
/// occupy exactly `b` bytes ≤ 8, so each group assembles into one u64
/// with fully unrolled shifts and stores via a single `to_le_bytes` —
/// no per-bit loop, no data-dependent flushing. `codes.len()` must be a
/// multiple of [`GROUP`].
fn pack_groups(codes: &[u32], bits: u8, out: &mut [u8]) {
    debug_assert_eq!(codes.len() % GROUP, 0);
    let b = bits as usize;
    let bits = bits as u32;
    for (grp, ob) in codes.chunks_exact(GROUP).zip(out.chunks_exact_mut(b)) {
        let acc = (grp[0] as u64)
            | (grp[1] as u64) << bits
            | (grp[2] as u64) << (2 * bits)
            | (grp[3] as u64) << (3 * bits)
            | (grp[4] as u64) << (4 * bits)
            | (grp[5] as u64) << (5 * bits)
            | (grp[6] as u64) << (6 * bits)
            | (grp[7] as u64) << (7 * bits);
        ob.copy_from_slice(&acc.to_le_bytes()[..b]);
    }
}

/// 8-wide unrolled unpacking over whole groups, mirror of
/// [`pack_groups`]: one `from_le_bytes` load per group, fully unrolled
/// shift-and-mask extracts. `out.len()` must be a multiple of
/// [`GROUP`].
fn unpack_groups(bytes: &[u8], bits: u8, out: &mut [u32]) {
    debug_assert_eq!(out.len() % GROUP, 0);
    let b = bits as usize;
    let mask = (1u64 << bits) - 1;
    let bits = bits as u32;
    for (bb, grp) in bytes.chunks_exact(b).zip(out.chunks_exact_mut(GROUP)) {
        let mut buf = [0u8; 8];
        buf[..b].copy_from_slice(bb);
        let acc = u64::from_le_bytes(buf);
        grp[0] = (acc & mask) as u32;
        grp[1] = ((acc >> bits) & mask) as u32;
        grp[2] = ((acc >> (2 * bits)) & mask) as u32;
        grp[3] = ((acc >> (3 * bits)) & mask) as u32;
        grp[4] = ((acc >> (4 * bits)) & mask) as u32;
        grp[5] = ((acc >> (5 * bits)) & mask) as u32;
        grp[6] = ((acc >> (6 * bits)) & mask) as u32;
        grp[7] = ((acc >> (7 * bits)) & mask) as u32;
    }
}

/// Sequential packing core over one byte-aligned block: group-unrolled
/// bulk + streaming ragged tail. `out` must be exactly
/// `packed_len(codes.len(), bits)` bytes; codes must fit the width
/// (validated by the public entry points).
fn pack_block(codes: &[u32], bits: u8, out: &mut [u8]) {
    let main = codes.len() / GROUP * GROUP;
    let main_bytes = main / GROUP * bits as usize;
    pack_groups(&codes[..main], bits, &mut out[..main_bytes]);
    pack_stream(&codes[main..], bits, &mut out[main_bytes..]);
}

/// Sequential unpacking core, mirror of [`pack_block`].
fn unpack_block(bytes: &[u8], bits: u8, out: &mut [u32]) {
    let main = out.len() / GROUP * GROUP;
    let main_bytes = main / GROUP * bits as usize;
    unpack_groups(&bytes[..main_bytes], bits, &mut out[..main]);
    unpack_stream_at(bytes, bits, main, &mut out[main..]);
}

/// Unpack `out.len()` codes starting at element index `start` of the
/// stream — the random-access primitive the fused dequant-matmul kernel
/// uses to walk a packed layer in cache-sized column panels without
/// ever unpacking the whole layer. A row panel generally starts
/// mid-byte (bit `start·bits`), so this runs a streaming head up to the
/// next [`GROUP`] boundary, the unrolled group core over the aligned
/// bulk, and a streaming tail. The caller guarantees
/// `start + out.len()` codes exist in `bytes` (slice indexing panics
/// otherwise).
pub fn unpack_range(bytes: &[u8], bits: u8, start: usize, out: &mut [u32]) {
    let end = start + out.len();
    let head_end = (start + (GROUP - start % GROUP) % GROUP).min(end);
    let head = head_end - start;
    unpack_stream_at(bytes, bits, start, &mut out[..head]);
    let main = (end - head_end) / GROUP * GROUP;
    let b0 = head_end / GROUP * bits as usize;
    let b1 = b0 + main / GROUP * bits as usize;
    unpack_groups(&bytes[b0..b1], bits, &mut out[head..head + main]);
    unpack_stream_at(bytes, bits, head_end + main, &mut out[head + main..]);
}

/// Pack `codes` at `bits` per code into `out` (exactly
/// [`packed_len`] bytes). Errors if a code exceeds the width or the
/// buffer length is wrong. Sequential reference form.
pub fn pack_into(codes: &[u32], bits: u8, out: &mut [u8]) -> Result<()> {
    check_bits(bits)?;
    check_lens(codes.len(), out.len(), bits)?;
    validate_codes(codes, bits)?;
    pack_block(codes, bits, out);
    Ok(())
}

/// [`pack_into`] parallelized over byte-aligned row blocks of `pool`.
/// Bit-identical to the sequential form for every pool size.
pub fn pack_into_with(
    pool: &ThreadPool,
    codes: &[u32],
    bits: u8,
    out: &mut [u8],
) -> Result<()> {
    check_bits(bits)?;
    check_lens(codes.len(), out.len(), bits)?;
    validate_codes(codes, bits)?;
    let n = codes.len();
    let blocks = pool.width().min((n / MIN_PACK_BLOCK).max(1));
    if blocks <= 1 {
        pack_block(codes, bits, out);
        return Ok(());
    }
    // Per-block element count: a multiple of GROUP, so every block's
    // output range starts and ends on a byte boundary (only the final
    // block may be ragged).
    let per = ((n + blocks - 1) / blocks + GROUP - 1) / GROUP * GROUP;
    let per_bytes = per / GROUP * bits as usize;
    pool.scope(|s| {
        let mut rest = &mut out[..];
        for chunk in codes.chunks(per) {
            let take = if chunk.len() == per {
                per_bytes
            } else {
                packed_len(chunk.len(), bits)
            };
            let (o, rem) = rest.split_at_mut(take);
            rest = rem;
            s.spawn(move || pack_block(chunk, bits, o));
        }
    });
    Ok(())
}

/// Allocating convenience form of [`pack_into`].
pub fn pack(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out)?;
    Ok(out)
}

/// Unpack `out.len()` codes at `bits` per code from `bytes` (exactly
/// [`packed_len`] bytes). Sequential reference form.
pub fn unpack_into(bytes: &[u8], bits: u8, out: &mut [u32]) -> Result<()> {
    check_bits(bits)?;
    check_lens(out.len(), bytes.len(), bits)?;
    unpack_block(bytes, bits, out);
    Ok(())
}

/// [`unpack_into`] parallelized over byte-aligned row blocks of `pool`.
pub fn unpack_into_with(
    pool: &ThreadPool,
    bytes: &[u8],
    bits: u8,
    out: &mut [u32],
) -> Result<()> {
    check_bits(bits)?;
    check_lens(out.len(), bytes.len(), bits)?;
    let n = out.len();
    let blocks = pool.width().min((n / MIN_PACK_BLOCK).max(1));
    if blocks <= 1 {
        unpack_block(bytes, bits, out);
        return Ok(());
    }
    let per = ((n + blocks - 1) / blocks + GROUP - 1) / GROUP * GROUP;
    let per_bytes = per / GROUP * bits as usize;
    pool.scope(|s| {
        let mut rest_bytes = bytes;
        for ochunk in out.chunks_mut(per) {
            let take = if ochunk.len() == per {
                per_bytes
            } else {
                packed_len(ochunk.len(), bits)
            };
            let (b, rem) = rest_bytes.split_at(take);
            rest_bytes = rem;
            s.spawn(move || unpack_block(b, bits, ochunk));
        }
    });
    Ok(())
}

/// Allocating convenience form of [`unpack_into`].
pub fn unpack(bytes: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    let mut out = vec![0u32; n];
    unpack_into(bytes, bits, &mut out)?;
    Ok(out)
}

/// Every code must fit the declared width (codes are grid offsets
/// `q − lo`, so a valid `b`-bit layer uses exactly the range
/// `0..2^b`).
pub fn validate_codes(codes: &[u32], bits: u8) -> Result<()> {
    let mask = !((1u32 << bits) - 1);
    if let Some(c) = codes.iter().find(|&&c| c & mask != 0) {
        return Err(Error::invariant(format!(
            "bitpack: code {c} exceeds the {bits}-bit width"
        )));
    }
    Ok(())
}

/// Verify the pad bits beyond `n · bits` in the final byte are zero —
/// the loader's cheap corruption check for truncated/garbled streams.
pub fn validate_padding(bytes: &[u8], n: usize, bits: u8) -> Result<()> {
    check_bits(bits)?;
    check_lens(n, bytes.len(), bits)?;
    let used = n * bits as usize;
    let pad = bytes.len() * 8 - used;
    if pad > 0 {
        let last = bytes[bytes.len() - 1];
        if last >> (8 - pad) != 0 {
            return Err(Error::parse(
                "bitpack: nonzero pad bits in the final byte (corrupt stream)",
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threadpool;

    fn random_codes(n: usize, bits: u8, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(1usize << bits) as u32).collect()
    }

    #[test]
    fn roundtrip_all_widths_and_ragged_lengths() {
        // lengths straddle word/group boundaries on purpose: 1 element,
        // sub-group, exact group, group+1, non-multiples of 8 and 64
        for bits in MIN_BITS..=MAX_BITS {
            for &n in &[1usize, 3, 7, 8, 9, 63, 64, 65, 1000, 4099] {
                let codes = random_codes(n, bits, 7 + n as u64 + bits as u64);
                let packed = pack(&codes, bits).unwrap();
                assert_eq!(packed.len(), packed_len(n, bits));
                validate_padding(&packed, n, bits).unwrap();
                let back = unpack(&packed, n, bits).unwrap();
                assert_eq!(back, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn extreme_codes_roundtrip() {
        for bits in MIN_BITS..=MAX_BITS {
            let hi = (1u32 << bits) - 1;
            let codes = vec![0, hi, 0, hi, hi, 0, 1, hi - 1, hi];
            let packed = pack(&codes, bits).unwrap();
            assert_eq!(unpack(&packed, codes.len(), bits).unwrap(), codes);
        }
    }

    #[test]
    fn parallel_matches_sequential_bit_for_bit() {
        let pool = threadpool::global();
        for bits in [2u8, 3, 5, 8] {
            // large enough to actually fan out, not a multiple of the
            // group or the block size
            let n = MIN_PACK_BLOCK * 3 + 37;
            let codes = random_codes(n, bits, 99 + bits as u64);
            let mut seq = vec![0u8; packed_len(n, bits)];
            pack_into(&codes, bits, &mut seq).unwrap();
            let mut par = vec![0u8; packed_len(n, bits)];
            pack_into_with(pool, &codes, bits, &mut par).unwrap();
            assert_eq!(seq, par, "pack bits={bits}");
            let mut out_seq = vec![0u32; n];
            unpack_into(&seq, bits, &mut out_seq).unwrap();
            let mut out_par = vec![0u32; n];
            unpack_into_with(pool, &par, bits, &mut out_par).unwrap();
            assert_eq!(out_seq, out_par, "unpack bits={bits}");
            assert_eq!(out_par, codes);
        }
    }

    #[test]
    fn unpack_range_matches_full_unpack_at_arbitrary_offsets() {
        // starts/lengths chosen to hit mid-byte bit offsets, sub-group
        // heads, aligned bulks, and ragged tails for every width
        for bits in MIN_BITS..=MAX_BITS {
            let n = 523;
            let codes = random_codes(n, bits, 0xA11 + bits as u64);
            let packed = pack(&codes, bits).unwrap();
            let full = unpack(&packed, n, bits).unwrap();
            for &(start, len) in &[
                (0usize, 0usize),
                (0, 1),
                (0, n),
                (1, 7),
                (3, 8),
                (5, 16),
                (7, 9),
                (8, 24),
                (13, 100),
                (64, 459),
                (511, 12),
                (522, 1),
            ] {
                let mut out = vec![0u32; len];
                unpack_range(&packed, bits, start, &mut out);
                assert_eq!(
                    out,
                    &full[start..start + len],
                    "bits={bits} start={start} len={len}"
                );
            }
        }
    }

    #[test]
    fn group_core_matches_stream_core() {
        // pack_block/unpack_block route the aligned bulk through the
        // unrolled group core; pin it against the streaming core alone.
        for bits in MIN_BITS..=MAX_BITS {
            let n = 8 * 13; // whole groups only
            let codes = random_codes(n, bits, 0x6B0 + bits as u64);
            let mut grouped = vec![0u8; packed_len(n, bits)];
            pack_groups(&codes, bits, &mut grouped);
            let mut streamed = vec![0u8; packed_len(n, bits)];
            pack_stream(&codes, bits, &mut streamed);
            assert_eq!(grouped, streamed, "pack bits={bits}");
            let mut out_g = vec![0u32; n];
            unpack_groups(&grouped, bits, &mut out_g);
            let mut out_s = vec![0u32; n];
            unpack_stream_at(&grouped, bits, 0, &mut out_s);
            assert_eq!(out_g, out_s, "unpack bits={bits}");
            assert_eq!(out_g, codes);
        }
    }

    #[test]
    fn rejects_out_of_range_codes_and_widths() {
        assert!(pack(&[4], 2).is_err()); // 4 needs 3 bits
        assert!(pack(&[0], 1).is_err());
        assert!(pack(&[0], 9).is_err());
        let mut small = vec![0u8; 1];
        assert!(pack_into(&[0, 0, 0, 0, 0], 4, &mut small).is_err()); // wants 3 bytes
    }

    #[test]
    fn padding_validation_catches_corruption() {
        let codes = random_codes(5, 3, 1); // 15 bits -> 2 bytes, 1 pad bit
        let mut packed = pack(&codes, 3).unwrap();
        validate_padding(&packed, 5, 3).unwrap();
        *packed.last_mut().unwrap() |= 0x80; // flip the pad bit
        assert!(validate_padding(&packed, 5, 3).is_err());
    }

    #[test]
    fn packed_len_edges() {
        assert_eq!(packed_len(0, 4), 0);
        assert_eq!(packed_len(1, 2), 1);
        assert_eq!(packed_len(8, 3), 3);
        assert_eq!(packed_len(9, 3), 4);
        assert_eq!(packed_len(147_456, 4), 73_728);
    }
}
