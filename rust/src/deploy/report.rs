//! Compression accounting for packed artifacts: per-layer and total
//! packed bytes vs the f32 baseline, effective bits per weight, and the
//! JSON summary the CI `artifact-smoke` job asserts on.

use crate::deploy::artifact::PackedModel;
use crate::report::Table;

/// Whole-artifact compression summary.
#[derive(Debug, Clone)]
pub struct Compression {
    pub model: String,
    pub method: String,
    pub layers: usize,
    /// Weight payload at f32 (what the v1 format stored).
    pub f32_bytes: u64,
    /// Weight payload under this artifact's encodings.
    pub packed_bytes: u64,
    /// `packed_bytes / f32_bytes`.
    pub ratio: f64,
    /// `8 · packed_bytes / total_params` — the storage-weighted mean
    /// width, including any f32-fallback layers.
    pub effective_bits: f64,
}

/// Summarize an artifact's weight-storage footprint.
pub fn summarize(art: &PackedModel) -> Compression {
    let f32_bytes = art.f32_bytes();
    let packed_bytes = art.payload_bytes();
    let params: u64 = art.layers.iter().map(|l| l.params() as u64).sum();
    Compression {
        model: art.model.clone(),
        method: art.method.clone(),
        layers: art.num_layers(),
        f32_bytes,
        packed_bytes,
        ratio: if f32_bytes > 0 {
            packed_bytes as f64 / f32_bytes as f64
        } else {
            0.0
        },
        effective_bits: if params > 0 {
            packed_bytes as f64 * 8.0 / params as f64
        } else {
            0.0
        },
    }
}

/// Per-layer compression table (plus a total row).
pub fn compression_table(art: &PackedModel) -> Table {
    let c = summarize(art);
    let mut t = Table::new(
        format!("Packed artifact — {} ({})", c.model, c.method),
        &["Layer", "Bits", "Params", "f32 B", "Packed B", "Ratio", "L (bits)"],
    );
    for l in &art.layers {
        let f32b = l.params() * 4;
        let pb = l.payload_bytes();
        t.row(vec![
            l.name.clone(),
            format!("{}{}", l.bits, match l.encoding {
                crate::deploy::artifact::Encoding::Packed => "",
                crate::deploy::artifact::Encoding::F32 => " (f32)",
            }),
            l.params().to_string(),
            f32b.to_string(),
            pb.to_string(),
            format!("{:.3}", pb as f64 / f32b.max(1) as f64),
            l.coding_length
                .map(|v| format!("{v:.1}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.row(vec![
        "TOTAL".into(),
        format!("{:.2} eff", c.effective_bits),
        art.layers
            .iter()
            .map(|l| l.params())
            .sum::<usize>()
            .to_string(),
        c.f32_bytes.to_string(),
        c.packed_bytes.to_string(),
        format!("{:.3}", c.ratio),
        "-".into(),
    ]);
    t
}

impl Compression {
    /// JSON in the same hand-rolled style as `ServeReport::to_json`;
    /// round-trips through [`crate::util::json::parse`]. CI asserts
    /// `ratio < 0.5` from this object.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"pack\": {{\n",
                "    \"model\": \"{}\",\n",
                "    \"method\": \"{}\",\n",
                "    \"layers\": {},\n",
                "    \"f32_bytes\": {},\n",
                "    \"packed_bytes\": {},\n",
                "    \"ratio\": {:e},\n",
                "    \"effective_bits_per_weight\": {:e}\n",
                "  }}\n",
                "}}"
            ),
            self.model,
            self.method,
            self.layers,
            self.f32_bytes,
            self.packed_bytes,
            self.ratio,
            self.effective_bits,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{LayerOutcome, Outcome};
    use crate::quant::rounding::{nearest, Rounding};
    use crate::quant::QGrid;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn outcome(bits: u8, n: usize) -> Outcome {
        let s = 0.02f32;
        let grid = QGrid::signed(bits, s).unwrap();
        let mut w = vec![0.0f32; n];
        Rng::new(3).fill_gaussian(&mut w, 0.0, 0.05);
        Outcome {
            model: "m".into(),
            method: Rounding::Nearest,
            acc: 0.0,
            fp_acc: 0.0,
            per_layer: vec![LayerOutcome {
                name: "l0".into(),
                bits,
                scale: s,
                first_loss: f32::NAN,
                last_loss: f32::NAN,
            }],
            qweights: vec![Tensor::from_vec(nearest(&w, &grid))],
            act_params: None,
            act_bits: None,
            wall_s: 0.0,
        }
    }

    #[test]
    fn four_bit_layer_is_one_eighth_of_f32() {
        let art = PackedModel::from_outcome(&outcome(4, 1024), None).unwrap();
        let c = summarize(&art);
        assert_eq!(c.f32_bytes, 4096);
        assert_eq!(c.packed_bytes, 512);
        assert!((c.ratio - 0.125).abs() < 1e-12);
        assert!((c.effective_bits - 4.0).abs() < 1e-12);
    }

    #[test]
    fn table_and_json_shape() {
        let art = PackedModel::from_outcome(&outcome(3, 64), None).unwrap();
        let t = compression_table(&art);
        assert_eq!(t.num_rows(), 2); // one layer + total
        let j = crate::util::json::parse(&summarize(&art).to_json()).unwrap();
        let p = j.get("pack").unwrap();
        assert_eq!(p.get("layers").unwrap().as_usize().unwrap(), 1);
        assert!(p.get("ratio").unwrap().as_f64().unwrap() < 0.5);
    }
}
