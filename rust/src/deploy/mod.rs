//! Packed quantized artifacts + deployment: the storage story the
//! paper's mixed-precision allocation promises.
//!
//! The calibration pipeline's output is a mixed-precision model whose
//! layers carry 2–8 bits each (coding-length allocation, Eq. 12–14) —
//! but the v1 saved-model format persisted every weight as full f32, so
//! a "quantized" model on disk was exactly as large as the FP original.
//! This subsystem ships the real thing: integer codes at the allocated
//! width, a versioned artifact directory, and a serving path that runs
//! straight off the packed representation.
//!
//! ```text
//!  quantize_and_eval ──► PackedModel::from_outcome   (artifact)
//!        │                     │ save / load  ◄── v1 f32 dirs too
//!        │                     ▼
//!        │               <dir>/qmodel.json + *.qbin  (bitpack)
//!        │                     │
//!        │                     ▼ Backend::prepare_artifact
//!        │               PackedHostForward            (dequant)
//!        │                     │ fused panels, no full f32 layer ever
//!        ▼                     ▼
//!  direct forward  ══ bit-identical ══  serve --artifact (PR-4 queue)
//! ```
//!
//! * [`bitpack`] — LSB-first bitstream pack/unpack of integer codes at
//!   widths 2–8, `_into` variants, parallel over byte-aligned row
//!   blocks, 8-wide group-unrolled cores + random-access
//!   `unpack_range`, bit-exact roundtrip property-tested.
//! * [`artifact`] — the versioned single-directory format v2: header
//!   JSON with per-layer name/bits/scale/shape/coding-length
//!   provenance, one packed `.qbin` per layer with length + checksum,
//!   loader validates streams and still reads v1 f32 dirs.
//! * [`fused`] — the fused dequant-matmul microkernel: walks the
//!   bitstream in cache-sized column panels and applies the `s·q`
//!   multiply inside the matmul tile, so a forward off a packed
//!   artifact never materializes a whole f32 layer anywhere —
//!   bit-identical to dequantize-then-matmul by construction.
//! * [`dequant`] — the lock-free `PackedHostForward` handle wiring
//!   [`fused`] (and borrowed f32 fallback layers) into
//!   `backend::host::layer_pass`.
//! * [`report`] — per-layer and total compression accounting (packed
//!   vs f32 bytes, effective bits/weight) as table + JSON.
//! * [`manifest`] — the v3 chunk manifest: contiguous layer-range
//!   chunks over one concatenated `qmodel.qpak`, per-chunk byte
//!   extents + FNV checksums, and the `min_runnable_depth` serving
//!   floor; strict typed-Parse validation (empty chunks, zero/over
//!   depth, overlap/gap, coverage).
//! * [`progressive`] — partial-depth serving over a chunked artifact:
//!   answers at the deepest resident prefix (nearest-class-mean
//!   readout at chunk boundaries, tagged `depth_served`) while a
//!   loader thread verifies and hot-swaps chunks in lock-free,
//!   converging to bit-identical full-depth serving.
//!
//! CLI: `repro pack` quantizes and writes an artifact (`--chunks N`
//! emits the v3 chunked layout); `repro serve --artifact <dir>` loads
//! one (with its activation-quant deployment config) and serves it
//! through the `serve` queue/batcher (`--progressive` for
//! partial-depth serving off a v3 dir).

pub mod artifact;
pub mod bitpack;
pub mod dequant;
pub mod fused;
pub mod manifest;
pub mod progressive;
pub mod report;

pub use artifact::{is_artifact_dir, LayerView, PackedModel};
pub use dequant::PackedHostForward;
pub use manifest::{ArtifactManifest, ChunkEntry};
pub use progressive::{ProgressiveHandle, ProgressiveModel};
pub use report::{compression_table, summarize, Compression};
