//! Windowed serve telemetry: per-second buckets of queue depth, batch
//! occupancy, latency percentiles, and terminal outcomes.
//!
//! `ServeMetrics` feeds a [`Timeline`] from the same recording sites that
//! maintain the run totals, so the per-bucket terminal counts obey the
//! exact accounting invariant `ServeReport` enforces globally:
//! Σ (completed + rejected_final + expired + errors) == Σ submitted.
//! The flushed report lands next to `serve.json` as
//! `<out>/serve.timeline.json` (see README "Observability" for the
//! schema) and is what explains a FAIL verdict: which second the queue
//! backed up, which worker stopped taking batches, when p99 broke.
//!
//! Unlike span tracing this is *always on* — it rides the locks
//! `ServeMetrics` already takes, adding only a bucket-index computation
//! per record.

use std::time::Instant;

/// Width of one bucket. Serve smoke runs last seconds; one-second
/// windows give per-phase resolution without unbounded growth.
pub const BUCKET_SECONDS: f64 = 1.0;

/// Hard cap on bucket count (24 h); later records clamp into the final
/// bucket rather than growing without bound.
const MAX_BUCKETS: usize = 86_400;

#[derive(Default, Clone)]
struct Bucket {
    submitted: u64,
    completed: u64,
    rejected_final: u64,
    expired: u64,
    errors: u64,
    depth_sum: u64,
    depth_samples: u64,
    depth_max: u64,
    batches: u64,
    batch_rows: u64,
    padded_rows: u64,
    resident_depth_max: u64,
    worker_batches: Vec<u64>,
    latencies_s: Vec<f64>,
}

/// Accumulates per-second buckets. Owned by `ServeMetrics` behind its
/// existing mutex; `sec` is seconds since session start.
pub struct Timeline {
    start: Instant,
    buckets: Vec<Bucket>,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline {
            start: Instant::now(),
            buckets: Vec::new(),
        }
    }
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current bucket index for "now" on the timeline's own clock.
    pub fn now_sec(&self) -> u64 {
        self.start.elapsed().as_secs()
    }

    fn bucket(&mut self, sec: u64) -> &mut Bucket {
        let idx = (sec as usize).min(MAX_BUCKETS - 1);
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, Bucket::default());
        }
        &mut self.buckets[idx]
    }

    pub fn record_submitted(&mut self, sec: u64) {
        self.bucket(sec).submitted += 1;
    }

    pub fn record_completed(&mut self, sec: u64, latency_s: f64) {
        let b = self.bucket(sec);
        b.completed += 1;
        b.latencies_s.push(latency_s);
    }

    pub fn record_rejected_final(&mut self, sec: u64) {
        self.bucket(sec).rejected_final += 1;
    }

    pub fn record_expired(&mut self, sec: u64) {
        self.bucket(sec).expired += 1;
    }

    pub fn record_error(&mut self, sec: u64) {
        self.bucket(sec).errors += 1;
    }

    pub fn record_depth(&mut self, sec: u64, depth: usize) {
        let b = self.bucket(sec);
        b.depth_sum += depth as u64;
        b.depth_samples += 1;
        b.depth_max = b.depth_max.max(depth as u64);
    }

    /// Resident layer depth observed this second (progressive serving);
    /// buckets keep the max so the depth ramp is visible per second.
    pub fn record_resident_depth(&mut self, sec: u64, depth: usize) {
        let b = self.bucket(sec);
        b.resident_depth_max = b.resident_depth_max.max(depth as u64);
    }

    pub fn record_batch(&mut self, sec: u64, worker_id: usize, real: usize, padded: usize) {
        let b = self.bucket(sec);
        b.batches += 1;
        b.batch_rows += real as u64;
        b.padded_rows += padded as u64;
        if b.worker_batches.len() <= worker_id {
            b.worker_batches.resize(worker_id + 1, 0);
        }
        b.worker_batches[worker_id] += 1;
    }

    /// Flush into the immutable report form (computes per-bucket
    /// percentiles; worker vectors are padded to a common width).
    pub fn report(&self) -> TimelineReport {
        let workers = self
            .buckets
            .iter()
            .map(|b| b.worker_batches.len())
            .max()
            .unwrap_or(0);
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .map(|(second, b)| {
                let mut worker_batches = b.worker_batches.clone();
                worker_batches.resize(workers, 0);
                BucketReport {
                    second: second as u64,
                    submitted: b.submitted,
                    completed: b.completed,
                    rejected_final: b.rejected_final,
                    expired: b.expired,
                    errors: b.errors,
                    queue_depth_mean: if b.depth_samples == 0 {
                        0.0
                    } else {
                        b.depth_sum as f64 / b.depth_samples as f64
                    },
                    queue_depth_max: b.depth_max,
                    batches: b.batches,
                    batch_fill_mean: if b.batches == 0 {
                        0.0
                    } else {
                        b.batch_rows as f64 / b.batches as f64
                    },
                    padded_rows: b.padded_rows,
                    resident_depth: b.resident_depth_max,
                    worker_batches,
                    latency_p50_s: percentile(&b.latencies_s, 50.0),
                    latency_p99_s: percentile(&b.latencies_s, 99.0),
                }
            })
            .collect();
        TimelineReport {
            bucket_seconds: BUCKET_SECONDS,
            buckets,
        }
    }
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One flushed bucket (see the README schema table).
#[derive(Debug, Clone)]
pub struct BucketReport {
    pub second: u64,
    pub submitted: u64,
    pub completed: u64,
    pub rejected_final: u64,
    pub expired: u64,
    pub errors: u64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: u64,
    pub batches: u64,
    pub batch_fill_mean: f64,
    pub padded_rows: u64,
    /// Deepest resident layer prefix observed this second (progressive
    /// serving; 0 on non-progressive runs).
    pub resident_depth: u64,
    pub worker_batches: Vec<u64>,
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
}

/// The flushed timeline: what `serve.timeline.json` serializes.
#[derive(Debug, Clone, Default)]
pub struct TimelineReport {
    pub bucket_seconds: f64,
    pub buckets: Vec<BucketReport>,
}

impl TimelineReport {
    pub fn submitted_total(&self) -> u64 {
        self.buckets.iter().map(|b| b.submitted).sum()
    }

    pub fn terminal_total(&self) -> u64 {
        self.buckets
            .iter()
            .map(|b| b.completed + b.rejected_final + b.expired + b.errors)
            .sum()
    }

    /// The `ServeReport` invariant, per-bucket edition: every submitted
    /// request reached exactly one terminal state somewhere on the
    /// timeline.
    pub fn accounting_balanced(&self) -> bool {
        self.submitted_total() == self.terminal_total()
    }

    /// Hand-rolled JSON, same idiom as `ServeReport::to_json`.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"serve_timeline\": {\n");
        s.push_str(&format!(
            "    \"bucket_seconds\": {},\n",
            self.bucket_seconds
        ));
        s.push_str("    \"buckets\": [\n");
        for (i, b) in self.buckets.iter().enumerate() {
            let workers = b
                .worker_batches
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join(", ");
            s.push_str(&format!(
                "      {{\"second\": {}, \"submitted\": {}, \"completed\": {}, \
                 \"rejected_final\": {}, \"expired\": {}, \"errors\": {}, \
                 \"queue_depth_mean\": {:.3}, \"queue_depth_max\": {}, \
                 \"batches\": {}, \"batch_fill_mean\": {:.3}, \"padded_rows\": {}, \
                 \"resident_depth\": {}, \
                 \"worker_batches\": [{}], \"latency_p50_s\": {:.6}, \
                 \"latency_p99_s\": {:.6}}}{}\n",
                b.second,
                b.submitted,
                b.completed,
                b.rejected_final,
                b.expired,
                b.errors,
                b.queue_depth_mean,
                b.queue_depth_max,
                b.batches,
                b.batch_fill_mean,
                b.padded_rows,
                b.resident_depth,
                workers,
                b.latency_p50_s,
                b.latency_p99_s,
                if i + 1 == self.buckets.len() { "" } else { "," }
            ));
        }
        s.push_str("    ],\n");
        s.push_str(&format!(
            "    \"totals\": {{\"submitted\": {}, \"completed\": {}, \"rejected_final\": {}, \
             \"expired\": {}, \"errors\": {}, \"accounting_balanced\": {}}}\n",
            self.submitted_total(),
            self.buckets.iter().map(|b| b.completed).sum::<u64>(),
            self.buckets.iter().map(|b| b.rejected_final).sum::<u64>(),
            self.buckets.iter().map(|b| b.expired).sum::<u64>(),
            self.buckets.iter().map(|b| b.errors).sum::<u64>(),
            self.accounting_balanced()
        ));
        s.push_str("  }\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_timeline() -> Timeline {
        let mut t = Timeline::new();
        // second 0: 3 in, 2 done on worker 0, 1 expired later
        t.record_submitted(0);
        t.record_submitted(0);
        t.record_submitted(0);
        t.record_depth(0, 2);
        t.record_depth(0, 4);
        t.record_batch(0, 0, 2, 1);
        t.record_resident_depth(0, 1);
        t.record_resident_depth(0, 2);
        t.record_completed(0, 0.010);
        t.record_completed(0, 0.030);
        // second 2: the straggler expires, one more submit+error
        t.record_submitted(2);
        t.record_expired(2);
        t.record_error(2);
        t
    }

    #[test]
    fn buckets_accumulate_and_balance() {
        let r = sample_timeline().report();
        assert_eq!(r.buckets.len(), 3, "gap second still materializes");
        assert_eq!(r.buckets[0].submitted, 3);
        assert_eq!(r.buckets[0].completed, 2);
        assert_eq!(r.buckets[0].batches, 1);
        assert!((r.buckets[0].queue_depth_mean - 3.0).abs() < 1e-12);
        assert_eq!(r.buckets[0].queue_depth_max, 4);
        assert_eq!(r.buckets[0].resident_depth, 2, "bucket keeps the depth max");
        assert_eq!(r.buckets[1].resident_depth, 0);
        assert_eq!(r.buckets[1].submitted, 0);
        assert_eq!(r.buckets[2].expired, 1);
        assert_eq!(r.buckets[2].errors, 1);
        assert_eq!(r.submitted_total(), 4);
        assert_eq!(r.terminal_total(), 4);
        assert!(r.accounting_balanced());
    }

    #[test]
    fn unbalanced_when_a_request_is_unaccounted() {
        let mut t = sample_timeline();
        t.record_submitted(2); // submitted but never terminal
        assert!(!t.report().accounting_balanced());
    }

    #[test]
    fn worker_vectors_padded_to_common_width() {
        let mut t = Timeline::new();
        t.record_batch(0, 0, 4, 0);
        t.record_batch(1, 2, 3, 1);
        let r = t.report();
        assert_eq!(r.buckets[0].worker_batches, vec![1, 0, 0]);
        assert_eq!(r.buckets[1].worker_batches, vec![0, 0, 1]);
    }

    #[test]
    fn percentiles_from_bucket_latencies() {
        let mut t = Timeline::new();
        t.record_submitted(0);
        for i in 1..=100 {
            t.record_completed(0, i as f64 / 1000.0);
        }
        let r = t.report();
        assert!((r.buckets[0].latency_p50_s - 0.050).abs() < 2e-3);
        assert!((r.buckets[0].latency_p99_s - 0.099).abs() < 2e-3);
    }

    /// Golden-key schema test: downstream CI greps on these exact keys.
    #[test]
    fn timeline_json_golden_keys() {
        let text = sample_timeline().report().to_json();
        let j = json::parse(&text).unwrap();
        let top: Vec<&str> = match &j {
            json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(top, vec!["serve_timeline"]);
        let inner = j.get("serve_timeline").unwrap();
        let inner_keys: Vec<&str> = match inner {
            json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(inner_keys, vec!["bucket_seconds", "buckets", "totals"]);
        let bucket = &inner.get("buckets").unwrap().as_arr().unwrap()[0];
        let bucket_keys: Vec<&str> = match bucket {
            json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(
            bucket_keys,
            vec![
                "batch_fill_mean",
                "batches",
                "completed",
                "errors",
                "expired",
                "latency_p50_s",
                "latency_p99_s",
                "padded_rows",
                "queue_depth_max",
                "queue_depth_mean",
                "rejected_final",
                "resident_depth",
                "second",
                "submitted",
                "worker_batches",
            ]
        );
        let totals = inner.get("totals").unwrap();
        let totals_keys: Vec<&str> = match totals {
            json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(
            totals_keys,
            vec![
                "accounting_balanced",
                "completed",
                "errors",
                "expired",
                "rejected_final",
                "submitted",
            ]
        );
        assert!(totals
            .get("accounting_balanced")
            .unwrap()
            .as_bool()
            .unwrap());
    }
}
