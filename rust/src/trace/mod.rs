//! Unified tracing: spans, instants, counters, and the process clock.
//!
//! One subsystem replaces the repo's three disconnected timing stories
//! (stderr log lines, `util::timer` phase sums, post-hoc serve metrics):
//!
//! * a global tracer gated on **one relaxed [`AtomicBool`]** — when
//!   tracing is off every instrumentation site costs a single atomic
//!   load and an untaken branch, **no clock read**, so kernel
//!   bit-identity and the fused-vs-unfused perf gates are untouched
//!   (CI asserts the serve path stays within 3% of a binary compiled
//!   without the `trace` feature at all);
//! * **per-thread ring buffers** ([`ring::Ring`]) behind a thread-local
//!   handle — recording locks only the recording thread's own mutex
//!   (uncontended in steady state), never a global one;
//! * RAII [`span`] guards + [`instant`] / [`counter`] events with typed
//!   [`Category`] lanes (`pipeline`, `calib`, `alloc`, `pack`, `serve`,
//!   `chaos`);
//! * a Chrome trace-event JSON exporter ([`chrome`]) loadable in
//!   Perfetto, and per-second serve telemetry buckets ([`timeline`]).
//!
//! AR003 bans clock reads in the kernel modules (`quant`, `linalg`,
//! `deploy`); the tracer clock therefore lives *here* and instrumentation
//! stays at layer/batch granularity in the coordinator and serve layers —
//! no waiver needed, kernels stay clock-free.
//!
//! The `trace` cargo feature (default-on) compiles the gate; without it
//! [`enabled`] is a compile-time `false` and every site folds away — that
//! is the "no-trace binary path" CI measures overhead against.

pub mod chrome;
pub mod ring;
pub mod timeline;

use std::borrow::Cow;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

pub use ring::{Event, Kind, Ring};

/// Typed event lanes — one per layer that matters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Coordinator phases (capture → … → evaluate) and whole-run spans.
    Pipeline,
    /// Per-layer scale search / calibration.
    Calib,
    /// Eq.-12 coding length + bit allocation.
    Alloc,
    /// Artifact bit-packing and writing.
    Pack,
    /// Request lifecycle: admit → queued → batched → forward → respond.
    Serve,
    /// Fault injections from the chaos harness.
    Chaos,
}

impl Category {
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Pipeline => "pipeline",
            Category::Calib => "calib",
            Category::Alloc => "alloc",
            Category::Pack => "pack",
            Category::Serve => "serve",
            Category::Chaos => "chaos",
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Whether this binary was compiled with the tracer at all.
pub fn available() -> bool {
    cfg!(feature = "trace")
}

/// The one-branch gate every instrumentation site checks first. With the
/// `trace` feature off this is a compile-time `false` and the whole site
/// is dead code.
#[inline(always)]
pub fn enabled() -> bool {
    if cfg!(feature = "trace") {
        ENABLED.load(Ordering::Relaxed)
    } else {
        false
    }
}

/// Arm the tracer (also pins the clock epoch so timestamps start near 0).
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds on the process-wide monotonic trace clock. This is the
/// *one* clock: `util::timer` phase sums and every trace timestamp read
/// it, so EXPERIMENTS.md numbers and trace spans can never disagree.
pub fn clock_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// One registered thread's buffer. The `Arc` outlives the thread so
/// events survive scoped worker teardown until export.
struct ThreadBuf {
    tid: u64,
    label: Mutex<Option<String>>,
    ring: Mutex<Ring>,
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: RefCell<Option<Arc<ThreadBuf>>> = const { RefCell::new(None) };
}

fn with_local<R>(f: impl FnOnce(&ThreadBuf) -> R) -> R {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let buf = Arc::new(ThreadBuf {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                label: Mutex::new(None),
                ring: Mutex::new(Ring::new(ring::DEFAULT_CAPACITY)),
            });
            registry().lock().unwrap().push(Arc::clone(&buf));
            *slot = Some(buf);
        }
        f(slot.as_ref().unwrap())
    })
}

fn record(kind: Kind, cat: Category, name: Cow<'static, str>) {
    let ts_us = clock_us();
    with_local(|buf| {
        buf.ring.lock().unwrap().push(Event {
            ts_us,
            kind,
            cat,
            name,
        })
    });
}

/// Name this thread's lane in the exported trace (`worker-0`,
/// `producer-2`, …). No-op while tracing is disabled.
pub fn set_thread_label(label: &str) {
    if !enabled() {
        return;
    }
    let owned = label.to_string();
    with_local(|buf| *buf.label.lock().unwrap() = Some(owned));
}

/// Point-in-time event (shed/expired/failed annotations, chaos
/// injections).
pub fn instant(cat: Category, name: impl Into<Cow<'static, str>>) {
    if !enabled() {
        return;
    }
    record(Kind::Instant, cat, name.into());
}

/// Named sampled value (queue depth and friends).
pub fn counter(cat: Category, name: impl Into<Cow<'static, str>>, value: f64) {
    if !enabled() {
        return;
    }
    record(Kind::Counter(value), cat, name.into());
}

/// RAII span: records `Begin` now (if tracing is on) and the matching
/// `End` on drop — including drops during panic unwinding, which is what
/// keeps B/E balanced through chaos-injected worker crashes.
pub struct SpanGuard {
    open: Option<(Category, Cow<'static, str>)>,
}

impl SpanGuard {
    /// Whether this guard actually opened a span (tracing was enabled).
    pub fn is_armed(&self) -> bool {
        self.open.is_some()
    }
}

/// Open a span on this thread. Disabled tracer: one atomic load, no
/// clock read, and the returned guard is inert.
pub fn span(cat: Category, name: impl Into<Cow<'static, str>>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { open: None };
    }
    let name = name.into();
    record(Kind::Begin, cat, name.clone());
    SpanGuard { open: Some((cat, name)) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // Close unconditionally once opened (even if the tracer was
        // disabled mid-span) so every thread's B/E stream stays balanced.
        if let Some((cat, name)) = self.open.take() {
            record(Kind::End, cat, name);
        }
    }
}

/// One thread's exported view.
pub struct ThreadSnapshot {
    pub tid: u64,
    pub label: Option<String>,
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// Copy out every registered thread's buffer (threads may already have
/// exited; their events persist through the registry `Arc`).
pub fn snapshot() -> Vec<ThreadSnapshot> {
    let registry = registry().lock().unwrap();
    registry
        .iter()
        .map(|buf| {
            let (events, dropped) = buf.ring.lock().unwrap().snapshot();
            ThreadSnapshot {
                tid: buf.tid,
                label: buf.label.lock().unwrap().clone(),
                events,
                dropped,
            }
        })
        .collect()
}

/// Disable tracing and clear every thread's buffer/label (test hygiene —
/// thread registrations themselves are kept).
pub fn reset() {
    disable();
    let registry = registry().lock().unwrap();
    for buf in registry.iter() {
        buf.ring.lock().unwrap().clear();
        *buf.label.lock().unwrap() = None;
    }
}
