//! Fixed-capacity per-thread event ring.
//!
//! Each tracing thread owns one [`Ring`]; pushes never allocate past the
//! configured capacity and never block anyone else. When the ring is full
//! the *oldest* event is overwritten (recent history is what explains a
//! failure) and a dropped-events count is kept so the exporter can emit an
//! explicit counter instead of silently truncating the timeline.

use std::borrow::Cow;

use super::Category;

/// Default per-thread capacity. At ~80 bytes/event this bounds a thread's
/// trace memory to a few MiB; serve smoke runs (≤ a few thousand events
/// per thread) never wrap.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// What one trace record means (Chrome trace-event phases `B`/`E`/`i`/`C`).
#[derive(Debug, Clone, PartialEq)]
pub enum Kind {
    /// Span open — must be balanced by a later [`Kind::End`] on the same
    /// thread (RAII guards in `trace` guarantee this, panics included).
    Begin,
    /// Span close.
    End,
    /// Point-in-time annotation (shed/expired/failed, chaos injections).
    Instant,
    /// Named sampled value (queue depth, dropped events).
    Counter(f64),
}

/// One trace record. Timestamps are microseconds on the process-wide
/// monotonic trace clock (`trace::clock_us`), so they are non-negative
/// and per-thread monotone by construction.
#[derive(Debug, Clone)]
pub struct Event {
    pub ts_us: u64,
    pub kind: Kind,
    pub cat: Category,
    pub name: Cow<'static, str>,
}

/// Bounded event buffer: push overwrites oldest-first once full.
pub struct Ring {
    buf: Vec<Event>,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    capacity: usize,
    dropped: u64,
}

impl Ring {
    pub fn new(capacity: usize) -> Self {
        Ring {
            buf: Vec::new(),
            head: 0,
            capacity: capacity.max(1),
            dropped: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten so far (oldest-first).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn push(&mut self, ev: Event) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Events in insertion order (oldest surviving first) plus the
    /// dropped count.
    pub fn snapshot(&self) -> (Vec<Event>, u64) {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        (out, self.dropped)
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64, name: &'static str) -> Event {
        Event {
            ts_us: ts,
            kind: Kind::Instant,
            cat: Category::Serve,
            name: Cow::Borrowed(name),
        }
    }

    #[test]
    fn push_below_capacity_keeps_order() {
        let mut r = Ring::new(4);
        for i in 0..3 {
            r.push(ev(i, "e"));
        }
        let (events, dropped) = r.snapshot();
        assert_eq!(dropped, 0);
        assert_eq!(
            events.iter().map(|e| e.ts_us).collect::<Vec<_>>(),
            [0, 1, 2]
        );
    }

    #[test]
    fn wraparound_drops_oldest_first_and_counts() {
        let mut r = Ring::new(4);
        for i in 0..7 {
            r.push(ev(i, "e"));
        }
        let (events, dropped) = r.snapshot();
        assert_eq!(dropped, 3, "three oldest events overwritten");
        assert_eq!(
            events.iter().map(|e| e.ts_us).collect::<Vec<_>>(),
            [3, 4, 5, 6],
            "survivors are the newest, still in insertion order"
        );
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn clear_resets_dropped() {
        let mut r = Ring::new(2);
        for i in 0..5 {
            r.push(ev(i, "e"));
        }
        assert!(r.dropped() > 0);
        r.clear();
        assert_eq!(r.dropped(), 0);
        assert!(r.is_empty());
    }

    #[test]
    fn zero_capacity_clamped_to_one() {
        let mut r = Ring::new(0);
        r.push(ev(0, "a"));
        r.push(ev(1, "b"));
        let (events, dropped) = r.snapshot();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].ts_us, 1);
        assert_eq!(dropped, 1);
    }
}
