//! Chrome trace-event JSON export (the `chrome://tracing` / Perfetto
//! format).
//!
//! Emits the JSON-object form `{"traceEvents": [...]}` with:
//!
//! * `B`/`E` duration events per span (balanced per tid by the RAII
//!   guards),
//! * `i` instants (`"s": "t"`, thread-scoped),
//! * `C` counters (`args.value`),
//! * one `M` `thread_name` metadata row per labeled lane (fleet workers,
//!   producers, the coordinator), and
//! * a `trace_dropped_events` counter per thread whose ring wrapped, so
//!   truncation is visible in the timeline instead of silent.
//!
//! Timestamps are already microseconds (the format's native unit). JSON
//! is hand-rolled like `util::json` — names/labels go through the same
//! escaper via [`crate::util::json::Json::str`].

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

use crate::util::json::Json;

use super::{Kind, ThreadSnapshot};

/// Single process lane; tids are the tracer's own per-thread ids.
const PID: u64 = 1;

fn quoted(s: &str) -> String {
    Json::str(s).to_string_compact()
}

/// Render snapshots as a Chrome trace JSON string. Returns the document
/// and the number of events written (metadata rows included).
pub fn render(snapshots: &[ThreadSnapshot]) -> (String, usize) {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut count = 0usize;
    let mut first = true;
    let mut push = |out: &mut String, line: String, count: &mut usize, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&line);
        *count += 1;
    };
    for snap in snapshots {
        if let Some(label) = &snap.label {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{PID},\"tid\":{},\
                     \"args\":{{\"name\":{}}}}}",
                    snap.tid,
                    quoted(label)
                ),
                &mut count,
                &mut first,
            );
        }
        for ev in &snap.events {
            let mut line = String::new();
            let _ = write!(
                line,
                "{{\"name\":{},\"cat\":\"{}\",\"pid\":{PID},\"tid\":{},\"ts\":{}",
                quoted(&ev.name),
                ev.cat.as_str(),
                snap.tid,
                ev.ts_us
            );
            match &ev.kind {
                Kind::Begin => line.push_str(",\"ph\":\"B\"}"),
                Kind::End => line.push_str(",\"ph\":\"E\"}"),
                Kind::Instant => line.push_str(",\"ph\":\"i\",\"s\":\"t\"}"),
                Kind::Counter(v) => {
                    let _ = write!(line, ",\"ph\":\"C\",\"args\":{{\"value\":{}}}}}", Json::num(*v).to_string_compact());
                }
            }
            push(&mut out, line, &mut count, &mut first);
        }
        if snap.dropped > 0 {
            let ts = snap.events.last().map(|e| e.ts_us).unwrap_or(0);
            push(
                &mut out,
                format!(
                    "{{\"name\":\"trace_dropped_events\",\"cat\":\"serve\",\"pid\":{PID},\
                     \"tid\":{},\"ts\":{},\"ph\":\"C\",\"args\":{{\"value\":{}}}}}",
                    snap.tid, ts, snap.dropped
                ),
                &mut count,
                &mut first,
            );
        }
    }
    out.push_str("\n]}\n");
    (out, count)
}

/// Snapshot the live tracer and write the trace to `path`. Returns the
/// number of events written.
pub fn export(path: &Path) -> std::io::Result<usize> {
    let snapshots = super::snapshot();
    let (doc, count) = render(&snapshots);
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(doc.as_bytes())?;
    f.flush()?;
    Ok(count)
}

#[cfg(test)]
mod tests {
    use std::borrow::Cow;

    use super::super::{Category, Event, Kind, ThreadSnapshot};
    use super::*;
    use crate::util::json;

    fn snap(tid: u64, label: Option<&str>, events: Vec<Event>, dropped: u64) -> ThreadSnapshot {
        ThreadSnapshot {
            tid,
            label: label.map(str::to_string),
            events,
            dropped,
        }
    }

    fn ev(ts: u64, kind: Kind, name: &'static str) -> Event {
        Event {
            ts_us: ts,
            kind,
            cat: Category::Serve,
            name: Cow::Borrowed(name),
        }
    }

    #[test]
    fn render_roundtrips_through_json_parser() {
        let snaps = vec![
            snap(
                1,
                Some("worker-0"),
                vec![
                    ev(0, Kind::Begin, "batch"),
                    ev(5, Kind::Instant, "shed \"quoted\""),
                    ev(9, Kind::End, "batch"),
                    ev(10, Kind::Counter(3.0), "queue_depth"),
                ],
                0,
            ),
            snap(2, None, vec![ev(1, Kind::Instant, "admit")], 2),
        ];
        let (doc, count) = render(&snaps);
        // worker-0: metadata + 4 events; tid 2: 1 event + dropped counter
        assert_eq!(count, 7);
        let j = json::parse(&doc).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 7);
        let meta = &events[0];
        assert_eq!(meta.get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(
            meta.get("args").unwrap().get("name").unwrap().as_str().unwrap(),
            "worker-0"
        );
        let begin = &events[1];
        assert_eq!(begin.get("ph").unwrap().as_str().unwrap(), "B");
        assert_eq!(begin.get("cat").unwrap().as_str().unwrap(), "serve");
        assert_eq!(begin.get("ts").unwrap().as_f64().unwrap(), 0.0);
        let inst = &events[2];
        assert_eq!(inst.get("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(
            inst.get("name").unwrap().as_str().unwrap(),
            "shed \"quoted\"",
            "names with quotes survive escaping"
        );
        let counter = &events[4];
        assert_eq!(counter.get("ph").unwrap().as_str().unwrap(), "C");
        assert_eq!(
            counter.get("args").unwrap().get("value").unwrap().as_f64().unwrap(),
            3.0
        );
        let dropped = &events[6];
        assert_eq!(
            dropped.get("name").unwrap().as_str().unwrap(),
            "trace_dropped_events"
        );
        assert_eq!(
            dropped.get("args").unwrap().get("value").unwrap().as_f64().unwrap(),
            2.0
        );
    }

    #[test]
    fn empty_snapshot_is_valid_json() {
        let (doc, count) = render(&[]);
        assert_eq!(count, 0);
        let j = json::parse(&doc).unwrap();
        assert!(j.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }
}
