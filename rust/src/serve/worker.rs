//! The serve worker: holds a prepared model hot and drives coalesced
//! micro-batches through the backend's quantized forward until the queue
//! shuts down.
//!
//! One `forward` per batch; per-request logits rows are sliced back out
//! (sound because both backends compute rows independently — see
//! `serve::batcher`). Inner kernel parallelism runs under
//! [`threadpool::with_width_cap`], the same nested-parallelism mechanism
//! `Ctx::run_many` hands experiment cells — so fleet workers sharing the
//! pool are each bounded to their slice via [`WorkerConfig::width`]
//! (`--worker-width`, or the backend's
//! [`crate::backend::WorkerTopology`] split).
//!
//! The robustness contract, in pop order:
//! 1. **Exactly one terminal response per popped request.** Everything
//!    popped goes straight into an [`InFlight`] guard whose `Drop` sends
//!    [`ServeOutcome::Failed`] for whatever was not yet answered — so a
//!    panic anywhere in the batch path (chaos-injected or real) fails
//!    over exactly the in-flight requests: no orphan, no double-response
//!    (answered requests leave the guard first).
//! 2. **Deadline shed before compute.** Requests whose deadline already
//!    passed are answered [`ServeOutcome::Expired`] *before* grouping,
//!    padding, or forward — an expired request never wastes a batch
//!    slot (`rust/tests/serve.rs` pins `batches == 0` for all-expired
//!    traffic).
//! 3. **Shape grouping.** Mixed-size traffic is split into same-shape
//!    groups, each its own micro-batch — a well-formed request is never
//!    errored for sharing a pop with a different-sized neighbour.
//!
//! Forward *errors* (not panics) are answered per request and the loop
//! keeps serving — a poisoned batch must not wedge the queue.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::PreparedModel;
use crate::quant::observer::ActQuantParams;
use crate::serve::batcher;
use crate::serve::chaos::WorkerChaos;
use crate::serve::metrics::ServeMetrics;
use crate::serve::queue::{RequestQueue, ServeOutcome, ServeRequest, ServeResponse};
use crate::trace::{self, Category};
use crate::util::threadpool;

/// Worker knobs (a subset of `serve::ServeConfig`, copied so the worker
/// thread borrows nothing mutable).
pub struct WorkerConfig {
    /// Coalesce up to this many requests per forward; batches are padded
    /// to exactly this many rows.
    pub max_batch: usize,
    /// How long a non-full batch waits for stragglers.
    pub max_wait: Duration,
    /// Width cap for the worker's inner kernel fan-out.
    pub width: usize,
    /// When set, serve through `forward_actq` with these per-layer
    /// params/bits (the quantized-activation deployment path).
    pub actq: Option<(Vec<ActQuantParams>, Vec<u8>)>,
    /// Deterministic fault injection shared across the fleet
    /// (`serve::chaos`); `None` in production.
    pub chaos: Option<Arc<WorkerChaos>>,
}

/// Popped requests awaiting their terminal response. Dropping the guard
/// — normally via stack unwind after a panic — answers everything still
/// inside with [`ServeOutcome::Failed`]; requests that were answered
/// were first moved out via [`InFlight::take`], so nothing is ever
/// answered twice.
struct InFlight {
    requests: Vec<ServeRequest>,
}

impl InFlight {
    fn new(requests: Vec<ServeRequest>) -> Self {
        InFlight { requests }
    }

    /// Move the requests out for answering; the guard is left empty, so
    /// its `Drop` sends nothing.
    fn take(&mut self) -> Vec<ServeRequest> {
        std::mem::take(&mut self.requests)
    }

    /// Answer (with `Expired`) and remove every request whose deadline
    /// has passed; returns how many were shed.
    fn shed_expired(&mut self, now: Instant) -> usize {
        let mut shed = 0usize;
        let mut i = 0usize;
        while i < self.requests.len() {
            let expired = self.requests[i].deadline.is_some_and(|d| now >= d);
            if expired {
                let r = self.requests.remove(i);
                let _ = r.tx.send(ServeResponse {
                    id: r.id,
                    outcome: ServeOutcome::Expired,
                });
                shed += 1;
            } else {
                i += 1;
            }
        }
        shed
    }

    /// Detach the first request plus everything sharing its sample
    /// shape (arrival order preserved within the group); `None` when
    /// empty. The detached group must immediately re-enter a guard.
    fn next_shape_group(&mut self) -> Option<Vec<ServeRequest>> {
        if self.requests.is_empty() {
            return None;
        }
        let dims = self.requests[0].input.shape().to_vec();
        let mut group = Vec::new();
        let mut i = 0usize;
        while i < self.requests.len() {
            if self.requests[i].input.shape() == dims.as_slice() {
                group.push(self.requests.remove(i));
            } else {
                i += 1;
            }
        }
        Some(group)
    }
}

impl Drop for InFlight {
    fn drop(&mut self) {
        for r in self.requests.drain(..) {
            let _ = r.tx.send(ServeResponse {
                id: r.id,
                outcome: ServeOutcome::Failed(
                    "serve worker panicked mid-batch; request failed over".into(),
                ),
            });
        }
    }
}

/// Answer every request with the same `Failed` message (terminal-state
/// *counting* happens at the response collector, so failed batches don't
/// double-book metrics).
fn respond_failed(requests: Vec<ServeRequest>, msg: &str) {
    for r in requests {
        let _ = r.tx.send(ServeResponse {
            id: r.id,
            outcome: ServeOutcome::Failed(msg.to_string()),
        });
    }
}

/// Drain the queue until it closes. Every popped request gets exactly
/// one terminal response — answer, expiry, or failure.
pub fn run_worker(
    worker_id: usize,
    prepared: &dyn PreparedModel,
    queue: &RequestQueue,
    cfg: &WorkerConfig,
    metrics: &ServeMetrics,
) {
    while let Some(popped) = queue.pop_batch(cfg.max_batch, cfg.max_wait) {
        // everything popped is guarded from this point on
        let mut pending = InFlight::new(popped);
        let shed = pending.shed_expired(Instant::now());
        if shed > 0 {
            trace::instant(Category::Serve, format!("shed:{shed}-expired"));
            log::debug!("serve worker {worker_id}: shed {shed} expired requests");
        }
        while let Some(group) = pending.next_shape_group() {
            let batch = match batcher::coalesce(group, cfg.max_batch) {
                Ok(b) => b,
                Err((reqs, e)) => {
                    respond_failed(reqs, &e.to_string());
                    continue;
                }
            };
            let batcher::MicroBatch {
                requests,
                inputs,
                padded,
            } = batch;
            let mut guard = InFlight::new(requests);
            // the span guard sits above the chaos hook so an injected
            // panic closes it during unwind — B/E stay balanced per tid
            let batch_span = trace::span(
                Category::Serve,
                format!("batch:{}+{padded}pad", guard.requests.len()),
            );
            // chaos fires while the guard owns the batch: an injected
            // panic fails over exactly these requests (plus whatever
            // `pending` still holds — also in flight)
            if let Some(chaos) = &cfg.chaos {
                chaos.before_batch();
            }
            let out = threadpool::with_width_cap(cfg.width, || match &cfg.actq {
                Some((params, bits)) => prepared.forward_actq(&inputs, params, bits),
                None => prepared.forward(&inputs),
            });
            drop(batch_span);
            match out {
                Ok(logits) => {
                    let requests = guard.take();
                    metrics.record_batch(worker_id, requests.len(), padded);
                    // progressive handles report the live resident
                    // prefix — the depth that served this batch
                    if let Some(depth) = prepared.resident_depth() {
                        metrics.record_resident_depth(depth);
                    }
                    for (i, r) in requests.into_iter().enumerate() {
                        match logits.slice_axis0(i, 1) {
                            Ok(row) => {
                                // latency counts answers only: `completed`
                                // in the report is exactly the answered set
                                metrics.record_latency(r.submitted.elapsed());
                                let _ = r.tx.send(ServeResponse {
                                    id: r.id,
                                    outcome: ServeOutcome::Answer(row),
                                });
                            }
                            Err(e) => {
                                let _ = r.tx.send(ServeResponse {
                                    id: r.id,
                                    outcome: ServeOutcome::Failed(e.to_string()),
                                });
                            }
                        }
                    }
                }
                Err(e) => {
                    trace::instant(Category::Serve, "batch:forward-failed");
                    respond_failed(guard.take(), &e.to_string());
                }
            }
        }
    }
}
