//! The serve worker: holds a prepared model hot and drives coalesced
//! micro-batches through the backend's quantized forward until the queue
//! shuts down.
//!
//! One `forward` per batch; per-request logits rows are sliced back out
//! (sound because both backends compute rows independently — see
//! `serve::batcher`). Inner kernel parallelism runs under
//! [`threadpool::with_width_cap`], the same nested-parallelism mechanism
//! `Ctx::run_many` hands experiment cells — so a worker co-scheduled
//! with experiments (or future sibling workers) can be bounded to its
//! share of the pool via [`WorkerConfig::width`] (`--worker-width`); by
//! default a lone worker uses the full pool. Forward errors are answered
//! per request (stringified) and the loop keeps serving — a poisoned
//! batch must not wedge the queue.

use std::time::Duration;

use crate::backend::PreparedModel;
use crate::quant::observer::ActQuantParams;
use crate::serve::batcher;
use crate::serve::metrics::ServeMetrics;
use crate::serve::queue::{RequestQueue, ServeRequest, ServeResponse};
use crate::util::threadpool;

/// Worker knobs (a subset of `serve::ServeConfig`, copied so the worker
/// thread borrows nothing mutable).
pub struct WorkerConfig {
    /// Coalesce up to this many requests per forward; batches are padded
    /// to exactly this many rows.
    pub max_batch: usize,
    /// How long a non-full batch waits for stragglers.
    pub max_wait: Duration,
    /// Width cap for the worker's inner kernel fan-out.
    pub width: usize,
    /// When set, serve through `forward_actq` with these per-layer
    /// params/bits (the quantized-activation deployment path).
    pub actq: Option<(Vec<ActQuantParams>, Vec<u8>)>,
}

/// Answer every request with the same error (errors are *counted* by the
/// response collector, so rejected batches don't double-book metrics).
fn respond_all(requests: &[ServeRequest], msg: &str) {
    for r in requests {
        let _ = r.tx.send(ServeResponse {
            id: r.id,
            result: Err(msg.to_string()),
        });
    }
}

/// Drain the queue until it closes. Every popped request gets exactly
/// one response — a logits row or an error.
pub fn run_worker(
    prepared: &dyn PreparedModel,
    queue: &RequestQueue,
    cfg: &WorkerConfig,
    metrics: &ServeMetrics,
) {
    while let Some(requests) = queue.pop_batch(cfg.max_batch, cfg.max_wait) {
        let batch = match batcher::coalesce(requests, cfg.max_batch) {
            Ok(b) => b,
            Err((requests, e)) => {
                respond_all(&requests, &e.to_string());
                continue;
            }
        };
        let out = threadpool::with_width_cap(cfg.width, || match &cfg.actq {
            Some((params, bits)) => prepared.forward_actq(&batch.inputs, params, bits),
            None => prepared.forward(&batch.inputs),
        });
        match out {
            Ok(logits) => {
                metrics.record_batch(batch.requests.len(), batch.padded);
                for (i, r) in batch.requests.iter().enumerate() {
                    let result = logits
                        .slice_axis0(i, 1)
                        .map_err(|e| e.to_string());
                    metrics.record_latency(r.submitted.elapsed());
                    let _ = r.tx.send(ServeResponse { id: r.id, result });
                }
            }
            Err(e) => respond_all(&batch.requests, &e.to_string()),
        }
    }
}
