//! Deterministic fault injection + hostile-traffic scenarios for the
//! serve fleet.
//!
//! Production serving dies in ways a happy-path load generator never
//! exercises: a worker panics mid-batch, a downstream consumer stalls,
//! arrivals burst, request shapes mix. This module makes each of those
//! failures **injectable and reproducible**:
//!
//! * [`ChaosSpec`] — a named, seeded scenario: which batches panic,
//!   which sleep, how slow the collector is, what the arrival process
//!   looks like, whether request sizes mix, and the per-request
//!   deadline + p99 SLO target the run is judged against.
//! * [`WorkerChaos`] — the runtime half shared by every fleet worker: a
//!   global batch counter driving panic-on-Nth-batch (the worker holds
//!   the popped requests in a fail-on-drop guard, so an injected panic
//!   fails over exactly the in-flight batch) and per-batch latency
//!   spikes. The counter survives restarts, so each listed batch index
//!   fires exactly once — deterministic crash points, not a crash loop.
//! * [`ArrivalGate`] — per-producer traffic shaping: open-loop Poisson
//!   inter-arrival gaps or bursty phases, from the seeded in-repo RNG
//!   (`rand` is not offline-available, and determinism is the point).
//! * [`judge`] / [`SloVerdict`] — the per-scenario verdict: p99 vs the
//!   scenario's target and **zero lost requests** (every submitted
//!   request reached exactly one terminal state), computed from the
//!   [`ServeReport`] accounting counters.
//! * [`run_matrix`] — drives every named scenario through
//!   [`super::run_load_generator`]; `rust/tests/serve.rs` runs it
//!   no-skip on the synthetic host model, and `repro serve --chaos
//!   matrix` exposes it at the CLI.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::backend::Backend;
use crate::io::manifest::Manifest;
use crate::serve::metrics::ServeReport;
use crate::serve::ServeConfig;
use crate::trace::{self, Category};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Seed for chaos injection and arrival processes — disjoint from the
/// load-generator traffic seed (`serve::LOADGEN_SEED` = 3001), the data
/// split seeds (`data::synth`) and the model-construction seeds.
pub const CHAOS_SEED: u64 = 4001;

/// The named scenarios [`run_matrix`] drives, in run order.
pub const SCENARIOS: &[&str] = &[
    "worker-crash",
    "slow-consumer",
    "latency-spike",
    "burst",
    "mixed-size",
    "slow-loader",
];

/// How load-generator producers pace their submissions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Submit as fast as admission allows (the pre-fleet behavior).
    Greedy,
    /// Open-loop Poisson: exponential inter-arrival gaps at `rps`
    /// requests/second **per producer**, submitted regardless of
    /// completion progress (arrival rate decoupled from service rate).
    Poisson { rps: f64 },
    /// Bursty phases: `burst` back-to-back submissions, then an `idle`
    /// gap — the on/off shape that defeats naive coalescing windows.
    Bursty { burst: usize, idle: Duration },
}

/// One deterministic fault-injection scenario (plain data; the runtime
/// state lives in [`WorkerChaos`], instantiated per session).
#[derive(Debug, Clone)]
pub struct ChaosSpec {
    /// Scenario name (one of [`SCENARIOS`], or a test-local custom).
    pub name: String,
    /// Seed for arrival processes and any randomized injection.
    pub seed: u64,
    /// Global batch indices at which the executing worker panics
    /// (before its forward; the in-flight guard fails over the batch).
    /// Each index fires exactly once across the whole fleet.
    pub panic_on_batches: Vec<u64>,
    /// Every Nth batch sleeps `spike` before its forward (0 = off).
    pub spike_every: u64,
    /// Injected per-batch latency spike duration.
    pub spike: Duration,
    /// Sleep injected into the response collector per response — a slow
    /// downstream consumer must not lose responses or wedge shutdown.
    pub collector_delay: Duration,
    /// Producer arrival process.
    pub arrivals: Arrivals,
    /// Mix half-resolution samples into the traffic: the worker must
    /// batch by shape (never error a well-formed request for sharing a
    /// pop with a different-sized neighbour).
    pub mixed_sizes: bool,
    /// Sleep injected before each progressive chunk load — models a
    /// slow artifact store so the fleet must answer partial-depth
    /// requests for a while before full-depth convergence (only
    /// meaningful under `serve --artifact --progressive`).
    pub chunk_load_delay: Duration,
    /// Per-request deadline this scenario runs under (applied when the
    /// operator didn't pass `--deadline-ms` explicitly).
    pub deadline: Option<Duration>,
    /// The p99 latency SLO the verdict checks against.
    pub p99_target: Duration,
}

impl ChaosSpec {
    /// A fault-free baseline spec (useful for composing custom specs in
    /// tests: `ChaosSpec { panic_on_batches: vec![0], ..ChaosSpec::quiet(seed) }`).
    pub fn quiet(seed: u64) -> ChaosSpec {
        ChaosSpec {
            name: "quiet".into(),
            seed,
            panic_on_batches: Vec::new(),
            spike_every: 0,
            spike: Duration::ZERO,
            collector_delay: Duration::ZERO,
            chunk_load_delay: Duration::ZERO,
            arrivals: Arrivals::Greedy,
            mixed_sizes: false,
            deadline: None,
            // generous: the verdict's SLO check must not flake on a
            // loaded CI runner; the tiny models serve in microseconds
            p99_target: Duration::from_secs(1),
        }
    }

    /// Look up a named scenario. The injection points are fixed small
    /// batch indices so every scenario fires on CI-sized runs.
    pub fn scenario(name: &str, seed: u64) -> Result<ChaosSpec> {
        let base = ChaosSpec {
            name: name.to_string(),
            ..ChaosSpec::quiet(seed)
        };
        Ok(match name {
            // a worker dies early and again mid-run; the supervisor
            // must restart it with backoff and the queue must survive
            "worker-crash" => ChaosSpec {
                panic_on_batches: vec![2, 9],
                arrivals: Arrivals::Poisson { rps: 4000.0 },
                ..base
            },
            // the response consumer stalls per response; responses must
            // all still arrive and shutdown must stay clean
            "slow-consumer" => ChaosSpec {
                collector_delay: Duration::from_micros(300),
                deadline: Some(Duration::from_millis(250)),
                ..base
            },
            // periodic multi-ms stalls inside the worker hot loop
            "latency-spike" => ChaosSpec {
                spike_every: 7,
                spike: Duration::from_millis(2),
                arrivals: Arrivals::Poisson { rps: 4000.0 },
                ..base
            },
            // on/off arrival phases against the coalescing window
            "burst" => ChaosSpec {
                arrivals: Arrivals::Bursty {
                    burst: 24,
                    idle: Duration::from_millis(3),
                },
                ..base
            },
            // mixed request sizes: the shape-grouping batcher must
            // serve both sizes correctly (zero errors)
            "mixed-size" => ChaosSpec {
                mixed_sizes: true,
                ..base
            },
            // chunks arrive slowly from the artifact store: the fleet
            // must answer truncated-depth requests while loading, then
            // converge to full depth (a plain non-progressive run
            // ignores the delay and serves normally)
            "slow-loader" => ChaosSpec {
                chunk_load_delay: Duration::from_millis(25),
                arrivals: Arrivals::Poisson { rps: 600.0 },
                ..base
            },
            other => {
                return Err(Error::config(format!(
                    "unknown chaos scenario {other:?} (expected one of \
                     {SCENARIOS:?}, or \"matrix\" at the CLI)"
                )))
            }
        })
    }
}

/// Runtime injection state shared by all fleet workers (one per serve
/// session, behind an `Arc` in `WorkerConfig`).
pub struct WorkerChaos {
    batches: AtomicU64,
    panic_on: Vec<u64>,
    spike_every: u64,
    spike: Duration,
}

impl WorkerChaos {
    pub fn new(spec: &ChaosSpec) -> WorkerChaos {
        WorkerChaos {
            batches: AtomicU64::new(0),
            panic_on: spec.panic_on_batches.clone(),
            spike_every: spec.spike_every,
            spike: spec.spike,
        }
    }

    /// Batches counted so far across the fleet.
    pub fn batches_seen(&self) -> u64 {
        self.batches.load(Ordering::SeqCst)
    }

    /// Called by the worker once per batch, *after* the in-flight guard
    /// owns the popped requests and *before* the forward — an injected
    /// panic therefore fails over exactly that batch, and a spike
    /// lands inside the measured service time.
    pub fn before_batch(&self) {
        let n = self.batches.fetch_add(1, Ordering::SeqCst);
        if self.panic_on.contains(&n) {
            // named injection instant *before* the panic, so the exported
            // trace points at the exact injection behind a FAIL verdict
            trace::instant(Category::Chaos, format!("inject:panic@batch{n}"));
            panic!("chaos: injected worker panic at batch {n}");
        }
        if self.spike_every > 0
            && !self.spike.is_zero()
            && n % self.spike_every == self.spike_every - 1
        {
            trace::instant(Category::Chaos, format!("inject:spike@batch{n}"));
            std::thread::sleep(self.spike);
        }
    }
}

/// Per-producer arrival pacing (deterministic given `(arrivals, seed)`).
pub struct ArrivalGate {
    rng: Rng,
    arrivals: Arrivals,
    sent: usize,
}

impl ArrivalGate {
    pub fn new(arrivals: Arrivals, seed: u64) -> ArrivalGate {
        ArrivalGate {
            rng: Rng::new(seed),
            arrivals,
            sent: 0,
        }
    }

    /// Block until this producer's next submission instant.
    pub fn wait(&mut self) {
        match self.arrivals {
            Arrivals::Greedy => {}
            Arrivals::Poisson { rps } => {
                if rps > 0.0 {
                    // exponential inter-arrival gap: -ln(1-u)/λ, capped
                    // so one unlucky draw can't stall a CI run
                    let u = self.rng.next_f64();
                    let gap = (-(1.0 - u).ln() / rps).min(0.050);
                    std::thread::sleep(Duration::from_secs_f64(gap));
                }
            }
            Arrivals::Bursty { burst, idle } => {
                if burst > 0 && self.sent > 0 && self.sent % burst == 0 {
                    std::thread::sleep(idle);
                }
            }
        }
        self.sent += 1;
    }
}

/// The per-scenario SLO verdict: accounting (zero lost requests) and
/// p99 latency vs the scenario target.
#[derive(Debug, Clone)]
pub struct SloVerdict {
    pub scenario: String,
    pub p99_s: f64,
    pub p99_target_s: f64,
    pub p99_ok: bool,
    /// `submitted − (answered + rejected + expired + errored)`; the
    /// zero-lost-requests invariant requires exactly 0.
    pub lost: i64,
    pub accounting_balanced: bool,
    pub restarts: u64,
    pub pass: bool,
}

impl SloVerdict {
    /// One-line human summary (the `repro serve --chaos` output).
    pub fn line(&self) -> String {
        format!(
            "chaos[{}]: {} — p99 {:.3}ms (target {:.0}ms), lost {}, \
             restarts {}, accounting {}",
            self.scenario,
            if self.pass { "PASS" } else { "FAIL" },
            self.p99_s * 1e3,
            self.p99_target_s * 1e3,
            self.lost,
            self.restarts,
            if self.accounting_balanced { "balanced" } else { "UNBALANCED" },
        )
    }

    /// Hand-rolled JSON object (`util::json`-parseable).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"scenario\": \"{}\", \"pass\": {}, \"p99_s\": {:e}, ",
                "\"p99_target_s\": {:e}, \"p99_ok\": {}, \"lost\": {}, ",
                "\"accounting_balanced\": {}, \"restarts\": {}}}"
            ),
            self.scenario,
            self.pass,
            self.p99_s,
            self.p99_target_s,
            self.p99_ok,
            self.lost,
            self.accounting_balanced,
            self.restarts,
        )
    }
}

/// Judge a finished run against its scenario's SLO.
pub fn judge(spec: &ChaosSpec, report: &ServeReport) -> SloVerdict {
    let terminals =
        report.completed + report.rejected_final + report.expired + report.errors;
    let lost = report.submitted as i64 - terminals as i64;
    let accounting_balanced = lost == 0;
    let p99_target_s = spec.p99_target.as_secs_f64();
    let p99_ok = report.lat_p99_s <= p99_target_s;
    SloVerdict {
        scenario: spec.name.clone(),
        p99_s: report.lat_p99_s,
        p99_target_s,
        p99_ok,
        lost,
        accounting_balanced,
        restarts: report.restarts,
        pass: accounting_balanced && p99_ok,
    }
}

/// Run every named scenario through the load generator against one
/// backend + model and judge each. No scenario is skippable: an error
/// from any run fails the whole matrix.
pub fn run_matrix(
    backend: &dyn Backend,
    manifest: &Manifest,
    model_name: &str,
    base: &ServeConfig,
    total: usize,
    producers: usize,
    seed: u64,
) -> Result<Vec<(ChaosSpec, ServeReport, SloVerdict)>> {
    let mut out = Vec::with_capacity(SCENARIOS.len());
    for name in SCENARIOS {
        let spec = ChaosSpec::scenario(name, seed)?;
        let mut cfg = base.clone();
        cfg.chaos = Some(spec.clone());
        let report =
            super::run_load_generator(backend, manifest, model_name, &cfg, total, producers)?;
        let verdict = judge(&spec, &report);
        out.push((spec, report, verdict));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_named_scenario_resolves() {
        for name in SCENARIOS {
            let s = ChaosSpec::scenario(name, CHAOS_SEED).unwrap();
            assert_eq!(&s.name, name);
        }
        assert!(ChaosSpec::scenario("nope", 1).is_err());
    }

    #[test]
    fn worker_chaos_counts_and_fires_once() {
        let spec = ChaosSpec {
            panic_on_batches: vec![1],
            ..ChaosSpec::quiet(7)
        };
        let wc = WorkerChaos::new(&spec);
        wc.before_batch(); // batch 0: fine
        let panicked =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| wc.before_batch()));
        assert!(panicked.is_err(), "batch 1 must panic");
        // the counter advanced past the crash point: restarted workers
        // don't re-trip the same injection
        wc.before_batch();
        assert_eq!(wc.batches_seen(), 3);
    }

    #[test]
    fn arrival_gate_is_deterministic() {
        // same seed -> same gap sequence (compare the RNG draws, not
        // wall time)
        let mut a = Rng::new(CHAOS_SEED ^ 1);
        let mut b = Rng::new(CHAOS_SEED ^ 1);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // bursty gate sleeps only at phase boundaries — exercised via
        // the public API (no panic, monotone sent counter)
        let mut g = ArrivalGate::new(
            Arrivals::Bursty {
                burst: 4,
                idle: Duration::from_micros(1),
            },
            3,
        );
        for _ in 0..9 {
            g.wait();
        }
        assert_eq!(g.sent, 9);
    }

    #[test]
    fn verdict_json_roundtrips() {
        let v = SloVerdict {
            scenario: "worker-crash".into(),
            p99_s: 0.001,
            p99_target_s: 1.0,
            p99_ok: true,
            lost: 0,
            accounting_balanced: true,
            restarts: 2,
            pass: true,
        };
        let j = crate::util::json::parse(&v.to_json()).unwrap();
        assert!(j.get("pass").unwrap().as_bool().unwrap());
        assert_eq!(j.get("restarts").unwrap().as_f64().unwrap(), 2.0);
        assert!(v.line().contains("PASS"));
    }

    #[test]
    fn verdict_json_golden_keys() {
        // schema freeze: downstream tooling (validate_serve.py, the CI
        // chaos-smoke job) keys on exactly this set — adding or renaming
        // a field must update this test *and* the consumers
        let v = SloVerdict {
            scenario: "quiet".into(),
            p99_s: 0.5,
            p99_target_s: 1.0,
            p99_ok: true,
            lost: 0,
            accounting_balanced: true,
            restarts: 0,
            pass: true,
        };
        let j = crate::util::json::parse(&v.to_json()).unwrap();
        let mut keys: Vec<&str> = match &j {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("verdict must serialize to an object, got {other:?}"),
        };
        keys.sort_unstable();
        assert_eq!(
            keys,
            vec![
                "accounting_balanced",
                "lost",
                "p99_ok",
                "p99_s",
                "p99_target_s",
                "pass",
                "restarts",
                "scenario",
            ]
        );
    }
}
