//! Micro-batch assembly: stack coalesced requests into one padded batch
//! tensor and slice per-request rows back out of the batched logits.
//!
//! Every batch is padded with zero rows to a **uniform** `pad_to` rows
//! (normally the worker's `max_batch`): the PJRT path executes
//! fixed-shape AOT graphs, and a single batch shape keeps the host path
//! mirrorable. Padding is sound because both backends compute output
//! rows independently of their batch neighbours (asserted by
//! `backend::host` tests), so pad rows cost compute but never change a
//! real row — and they are never returned: responses are sliced from the
//! first `requests.len()` rows only.
//!
//! Mixed-size traffic never reaches [`coalesce`]: the worker splits each
//! pop into same-shape groups first (`serve::worker`), so the shape
//! check here is defense in depth, not the routing mechanism.

use crate::serve::queue::ServeRequest;
use crate::tensor::Tensor;
use crate::util::error::Error;

/// One coalesced batch, ready for a single `forward` call.
pub struct MicroBatch {
    /// The member requests, in arrival order = batch-row order.
    pub requests: Vec<ServeRequest>,
    /// `[pad_to, …sample dims]`: request samples stacked along axis 0,
    /// zero rows after `requests.len()`.
    pub inputs: Tensor,
    /// Number of zero pad rows (`pad_to − requests.len()`).
    pub padded: usize,
}

/// Stack `requests` into a [`MicroBatch`] padded to `pad_to` rows (or to
/// the request count, if larger). On failure the untouched requests come
/// back with the error so the caller can still answer them.
pub fn coalesce(
    requests: Vec<ServeRequest>,
    pad_to: usize,
) -> std::result::Result<MicroBatch, (Vec<ServeRequest>, Error)> {
    if requests.is_empty() {
        return Err((requests, Error::invariant("coalesce on an empty request set")));
    }
    let pad_to = pad_to.max(requests.len());
    let dims = requests[0].input.shape().to_vec();
    let mismatch = requests[1..]
        .iter()
        .find(|r| r.input.shape() != dims.as_slice())
        .map(|r| {
            format!(
                "serve batch mixes sample shapes: {:?} (request {}) vs {:?}",
                r.input.shape(),
                r.id,
                dims
            )
        });
    if let Some(msg) = mismatch {
        return Err((requests, Error::shape(msg)));
    }
    let sample_len: usize = dims.iter().product();
    let mut data = vec![0.0f32; pad_to * sample_len];
    for (i, r) in requests.iter().enumerate() {
        data[i * sample_len..(i + 1) * sample_len].copy_from_slice(r.input.data());
    }
    let mut shape = vec![pad_to];
    shape.extend(dims);
    let padded = pad_to - requests.len();
    match Tensor::new(shape, data) {
        Ok(inputs) => Ok(MicroBatch {
            requests,
            inputs,
            padded,
        }),
        Err(e) => Err((requests, e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn req(id: u64, data: Vec<f32>, shape: Vec<usize>) -> ServeRequest {
        let (tx, rx) = channel();
        drop(rx); // test requests never get responses
        ServeRequest {
            id,
            input: Tensor::new(shape, data).unwrap(),
            submitted: Instant::now(),
            deadline: None,
            tx,
        }
    }

    #[test]
    fn pads_final_batch_with_zero_rows() {
        let reqs = vec![
            req(0, vec![1.0, 2.0], vec![2]),
            req(1, vec![3.0, 4.0], vec![2]),
        ];
        let b = coalesce(reqs, 4).unwrap();
        assert_eq!(b.inputs.shape(), &[4, 2]);
        assert_eq!(b.padded, 2);
        assert_eq!(b.inputs.data(), &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(b.requests.len(), 2);
    }

    #[test]
    fn oversized_request_set_grows_past_pad_to() {
        let reqs = (0..3).map(|i| req(i, vec![i as f32], vec![1])).collect();
        let b = coalesce(reqs, 2).unwrap();
        assert_eq!(b.inputs.shape(), &[3, 1]);
        assert_eq!(b.padded, 0);
    }

    #[test]
    fn shape_mismatch_returns_requests_intact() {
        let reqs = vec![
            req(7, vec![1.0, 2.0], vec![2]),
            req(8, vec![1.0, 2.0, 3.0], vec![3]),
        ];
        let (back, err) = coalesce(reqs, 4).unwrap_err();
        assert_eq!(back.len(), 2, "requests come back for error responses");
        assert_eq!(back[0].id, 7);
        assert!(err.to_string().contains("shape"));
    }
}
