//! Batched serving: the first subsystem whose job is traffic, not
//! calibration.
//!
//! The PTQ pipeline's output — a quantized weight set — only pays off
//! behind an inference path. This module keeps
//! [`crate::backend::PreparedModel`]s **hot** (staged via
//! [`crate::backend::Backend::prepare_serving`], one handle per fleet
//! worker) and streams request batches through them:
//!
//! ```text
//!  producers ──push──► RequestQueue (bounded, reject-on-full)
//!                          │ pop_batch(max_batch, max_wait)
//!             ┌────────────┼────────────┐
//!             ▼            ▼            ▼
//!         worker 0     worker 1  …  worker N-1     (fleet: each under a
//!         (shed expired → shape-group → pad →       supervisor with
//!          one forward per micro-batch)             restart + breaker)
//!             └────────────┼────────────┘
//!                          ▼
//!              response channel + ServeMetrics
//!              (collector: single counting site
//!               for terminal states)
//! ```
//!
//! * [`queue`] — bounded MPSC admission queue; typed
//!   [`queue::AdmissionError`] on overload; [`queue::ServeOutcome`] is
//!   every request's exactly-one terminal state.
//! * [`batcher`] — request coalescing and zero-row padding.
//! * [`worker`] — the hot loop; deadline shedding *before* compute,
//!   same-shape grouping, in-flight fail-over guard; nested parallelism
//!   bounded by [`crate::util::threadpool::with_width_cap`].
//! * [`fleet`] — worker supervision: panic containment, bounded
//!   exponential restart backoff, restart-storm circuit breaker,
//!   last-worker-out shutdown.
//! * [`chaos`] — deterministic fault injection and hostile traffic
//!   shapes, with per-scenario SLO verdicts.
//! * [`metrics`] — latency percentiles (select-nth), terminal-state
//!   accounting, per-worker batch counts, restarts, throughput; JSON /
//!   table / bench-baseline reporting.
//!
//! Serve-path answers are **bit-identical** to a direct `forward` of the
//! same samples (rows are computed independently of their batch
//! neighbours; `rust/tests/serve.rs` asserts it end-to-end), so putting
//! a model behind the queue never changes what it predicts.
//!
//! [`run_load_generator`] is the self-driving mode: it generates its own
//! traffic against the synthetic host model (or any backend's model), so
//! CI exercises the full path on a bare checkout — see the `repro serve`
//! subcommand and the `--chaos` scenario matrix.

pub mod batcher;
pub mod chaos;
pub mod fleet;
pub mod metrics;
pub mod queue;
pub mod worker;

use std::path::Path;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::{Backend, PreparedModel};
use crate::data::synth;
use crate::deploy::artifact::PackedModel;
use crate::deploy::progressive::ProgressiveModel;
use crate::io::manifest::{DatasetInfo, Manifest};
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::trace::{self, Category};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::util::threadpool;

pub use chaos::{
    judge, run_matrix, Arrivals, ArrivalGate, ChaosSpec, SloVerdict, WorkerChaos,
    CHAOS_SEED, SCENARIOS,
};
pub use fleet::{supervise, FleetConfig};
pub use metrics::{ServeMetrics, ServeReport};
pub use queue::{
    AdmissionError, Rejected, RequestQueue, ServeOutcome, ServeRequest, ServeResponse,
};
pub use worker::{run_worker, WorkerConfig};

/// Seed for load-generator traffic — disjoint from the calibration /
/// eval / train split seeds (`data::synth`) and the model-construction
/// seeds (`backend::host`).
const LOADGEN_SEED: u64 = 3001;

/// How long a producer backs off after an admission rejection before
/// retrying (load-generator mode; a real client would shed or reroute).
const RETRY_BACKOFF: Duration = Duration::from_micros(100);

/// Serving knobs (the `repro serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Coalesce up to this many requests per forward (batches are padded
    /// to exactly this many rows).
    pub max_batch: usize,
    /// How long a non-full batch waits for stragglers.
    pub max_wait: Duration,
    /// Admission bound: queued requests beyond this are rejected.
    pub queue_depth: usize,
    /// Requested fleet size; the backend's
    /// [`crate::backend::WorkerTopology`] decides what it actually
    /// supports (`--workers`).
    pub workers: usize,
    /// Width cap for each worker's inner kernel fan-out; 0 = let the
    /// backend topology split the pool across the fleet.
    pub worker_width: usize,
    /// Per-request deadline (`--deadline-ms`): requests unserved past it
    /// are shed before compute and answered [`ServeOutcome::Expired`].
    /// `None` = never expire (a chaos scenario may still set one).
    pub deadline: Option<Duration>,
    /// Re-check every answered response against a direct `forward` of
    /// the same sample (bit-identity); load-generator mode only.
    pub verify: bool,
    /// Serve through `forward_actq` with these per-layer params/bits
    /// (the quantized-activation deployment path); `None` = plain
    /// `forward`.
    pub actq: Option<(Vec<ActQuantParams>, Vec<u8>)>,
    /// Deterministic fault-injection scenario (`--chaos`); `None` in
    /// production.
    pub chaos: Option<ChaosSpec>,
    /// Supervision knobs (restart backoff, circuit breaker).
    pub fleet: FleetConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            queue_depth: 64,
            workers: 1,
            worker_width: 0,
            deadline: None,
            verify: true,
            actq: None,
            chaos: None,
            fleet: FleetConfig::default(),
        }
    }
}

/// Synthetic request traffic shaped like the manifest's dataset, one
/// tensor per request (`[H, W, C]`, no batch dim — the micro-batcher
/// adds it): the class-textured generator when the dims match it,
/// seeded Gaussian noise otherwise (serving latency does not care about
/// label structure). With `mixed` every third request is half
/// resolution — the conv stack is resolution-agnostic (1×1-as-matmul +
/// spatial pooling), so these are *valid* requests the worker must
/// shape-group, not malformed ones.
fn gen_request_inputs(
    total: usize,
    ds: &DatasetInfo,
    mixed: bool,
) -> Result<Vec<Tensor>> {
    let full = if ds.image_hw == synth::IMG && ds.channels == synth::CHANNELS {
        synth::generate(total, LOADGEN_SEED).0
    } else {
        let mut data = vec![0.0f32; total * ds.image_hw * ds.image_hw * ds.channels];
        Rng::new(LOADGEN_SEED).fill_gaussian(&mut data, 0.0, 1.0);
        Tensor::new(vec![total, ds.image_hw, ds.image_hw, ds.channels], data)?
    };
    let mut rng = Rng::new(LOADGEN_SEED ^ 0x51ed);
    let mut out = Vec::with_capacity(total);
    for i in 0..total {
        if mixed && i % 3 == 2 {
            let hw = (ds.image_hw / 2).max(1);
            let mut data = vec![0.0f32; hw * hw * ds.channels];
            rng.fill_gaussian(&mut data, 0.0, 1.0);
            out.push(Tensor::new(vec![hw, hw, ds.channels], data)?);
        } else {
            let t = full.slice_axis0(i, 1)?;
            let dims = t.shape()[1..].to_vec();
            out.push(t.reshape(dims)?);
        }
    }
    Ok(out)
}

/// The queue → fleet → collector session core shared by the pipeline and
/// from-artifact load generators: `producers` threads submit one request
/// per sample (pacing per the chaos arrival process, retrying with
/// backoff on admission rejection), `prepareds.len()` supervised workers
/// serve them, and the call returns one answer slot per request after a
/// clean shutdown. Non-answer terminal states (rejected / expired /
/// failed) are counted into `serve_metrics` by the collector — the
/// single counting site — and leave their slot `None`.
fn run_session(
    prepareds: &[Box<dyn PreparedModel + '_>],
    samples: &[Tensor],
    cfg: &ServeConfig,
    worker_width: usize,
    producers: usize,
    serve_metrics: &ServeMetrics,
) -> Vec<Option<Tensor>> {
    let total = samples.len();
    let workers = prepareds.len().max(1);
    let queue = RequestQueue::new(cfg.queue_depth);
    let chaos_rt = cfg.chaos.as_ref().map(|c| Arc::new(WorkerChaos::new(c)));
    // the scenario supplies arrivals/deadline/collector-delay; an
    // operator-passed deadline wins over the scenario's
    let deadline = cfg
        .deadline
        .or(cfg.chaos.as_ref().and_then(|c| c.deadline));
    let arrivals = cfg.chaos.as_ref().map_or(Arrivals::Greedy, |c| c.arrivals);
    let collector_delay = cfg
        .chaos
        .as_ref()
        .map_or(Duration::ZERO, |c| c.collector_delay);
    let chaos_seed = cfg.chaos.as_ref().map_or(CHAOS_SEED, |c| c.seed);
    let wcfgs: Vec<WorkerConfig> = (0..workers)
        .map(|_| WorkerConfig {
            max_batch: cfg.max_batch.max(1),
            max_wait: cfg.max_wait,
            width: worker_width.max(1),
            actq: cfg.actq.clone(),
            chaos: chaos_rt.clone(),
        })
        .collect();
    let alive = AtomicUsize::new(workers);
    let (rtx, rrx) = channel::<ServeResponse>();
    let mut responses: Vec<Option<Tensor>> = vec![None; total];
    let _session_span = trace::span(
        Category::Serve,
        format!("session:{total}req:{workers}w"),
    );
    std::thread::scope(|s| {
        for (wid, (prepared, wcfg)) in prepareds.iter().zip(&wcfgs).enumerate() {
            let (queue, metrics, fleet, alive) =
                (&queue, serve_metrics, &cfg.fleet, &alive);
            s.spawn(move || {
                // one exported trace lane per fleet worker
                trace::set_thread_label(&format!("worker-{wid}"));
                supervise(wid, prepared.as_ref(), queue, wcfg, metrics, fleet, alive)
            });
        }
        let per = (total + producers - 1) / producers;
        for p in 0..producers {
            let (lo, hi) = (p * per, ((p + 1) * per).min(total));
            if lo >= hi {
                continue;
            }
            let rtx = rtx.clone();
            let (queue, metrics) = (&queue, serve_metrics);
            s.spawn(move || {
                trace::set_thread_label(&format!("producer-{p}"));
                let mut gate = ArrivalGate::new(arrivals, chaos_seed ^ p as u64);
                for i in lo..hi {
                    gate.wait();
                    metrics.record_submitted();
                    trace::instant(Category::Serve, "admit");
                    let now = Instant::now();
                    let mut req = ServeRequest {
                        id: i as u64,
                        input: samples[i].clone(),
                        submitted: now,
                        // fixed at creation; retries below never extend it
                        deadline: deadline.map(|d| now + d),
                        tx: rtx.clone(),
                    };
                    loop {
                        match queue.push(req) {
                            Ok(depth) => {
                                metrics.record_depth(depth);
                                trace::counter(
                                    Category::Serve,
                                    "queue_depth",
                                    depth as f64,
                                );
                                break;
                            }
                            Err(rej) => match rej.error {
                                AdmissionError::QueueFull { .. } => {
                                    metrics.record_rejected();
                                    trace::instant(Category::Serve, "shed:queue-full");
                                    req = rej.request;
                                    // the deadline keeps running while we
                                    // fight for admission: shed here too
                                    if req
                                        .deadline
                                        .is_some_and(|d| Instant::now() >= d)
                                    {
                                        let _ = req.tx.send(ServeResponse {
                                            id: req.id,
                                            outcome: ServeOutcome::Expired,
                                        });
                                        break;
                                    }
                                    std::thread::sleep(RETRY_BACKOFF);
                                    // reset only after the backoff:
                                    // latency measures time *in* the
                                    // system, not retry sleeps
                                    req.submitted = Instant::now();
                                }
                                AdmissionError::Closed => {
                                    let ServeRequest { id, tx, .. } = rej.request;
                                    let _ = tx.send(ServeResponse {
                                        id,
                                        outcome: ServeOutcome::Rejected(
                                            AdmissionError::Closed,
                                        ),
                                    });
                                    break;
                                }
                            },
                        }
                    }
                }
            });
        }
        drop(rtx);
        // Collect exactly one terminal response per request, then shut
        // down. This is the single counting site for non-answer
        // terminal states.
        let mut got = 0usize;
        while got < total {
            match rrx.recv() {
                Ok(resp) => {
                    got += 1;
                    if !collector_delay.is_zero() {
                        // chaos: a slow downstream consumer
                        std::thread::sleep(collector_delay);
                    }
                    match resp.outcome {
                        ServeOutcome::Answer(t) => {
                            trace::instant(Category::Serve, "respond");
                            if let Some(slot) = responses.get_mut(resp.id as usize) {
                                *slot = Some(t);
                            }
                        }
                        ServeOutcome::Rejected(e) => {
                            serve_metrics.record_rejected_final();
                            trace::instant(Category::Serve, "terminal:rejected");
                            log::debug!("serve: request {} rejected: {e}", resp.id);
                        }
                        ServeOutcome::Expired => {
                            serve_metrics.record_expired();
                            trace::instant(Category::Serve, "terminal:expired");
                        }
                        ServeOutcome::Failed(msg) => {
                            serve_metrics.record_error();
                            trace::instant(Category::Serve, "terminal:failed");
                            log::warn!("serve: request {} failed: {msg}", resp.id);
                        }
                    }
                }
                Err(_) => break, // every sender gone — nothing more can arrive
            }
        }
        queue.close();
    });
    responses
}

/// Resolve the effective fleet geometry for a backend: topology-clamped
/// worker count plus per-worker kernel width (explicit `--worker-width`
/// wins; otherwise the topology's pool split; otherwise the full pool).
fn resolve_topology(backend: &dyn Backend, cfg: &ServeConfig) -> (usize, usize) {
    let topo = backend.worker_topology(cfg.workers.max(1));
    let workers = topo.workers.max(1);
    let width = if cfg.worker_width != 0 {
        cfg.worker_width
    } else if topo.worker_width != 0 {
        topo.worker_width
    } else {
        threadpool::global().size()
    };
    log::info!(
        "serve: fleet of {workers} worker(s), width {width} ({})",
        topo.detail
    );
    (workers, width)
}

/// Re-check every *answered* response bit-for-bit against a direct
/// forward of the same sample on `direct` (through `forward_actq` when
/// an activation deployment config is set). With `require_all` (fault-
/// free runs) an unanswered request is itself an error; under chaos or
/// deadlines, non-answers are legitimate terminal states and only the
/// answers are checked — a served answer must *never* be stale, even
/// mid-fault.
fn verify_bit_identity(
    direct: &dyn PreparedModel,
    samples: &[Tensor],
    responses: &[Option<Tensor>],
    actq: &Option<(Vec<ActQuantParams>, Vec<u8>)>,
    require_all: bool,
) -> Result<()> {
    for (i, slot) in responses.iter().enumerate() {
        let got = match slot {
            Some(t) => t,
            None if require_all => {
                return Err(Error::invariant(format!(
                    "serve: request {i} got no successful response"
                )))
            }
            None => continue,
        };
        let mut shape = vec![1];
        shape.extend(samples[i].shape().iter().copied());
        let x = samples[i].clone().reshape(shape)?;
        let want = match actq {
            Some((params, bits)) => direct.forward_actq(&x, params, bits)?,
            None => direct.forward(&x)?,
        };
        if got.shape() != want.shape() || got.data() != want.data() {
            return Err(Error::invariant(format!(
                "serve: output for request {i} is not bit-identical to the \
                 direct forward"
            )));
        }
    }
    Ok(())
}

/// Self-driving serving session over a backend's own model weights:
/// loads the model, stages one `prepare_serving` handle per fleet
/// worker, and drives `total` requests through [`run_session`]. With
/// `cfg.verify` every answer is re-checked bit-for-bit against a direct
/// `forward` of the same sample — an `Err` from this function means the
/// serving path changed what the model computes (or, in a fault-free
/// run, that a request never completed).
pub fn run_load_generator(
    backend: &dyn Backend,
    manifest: &Manifest,
    model_name: &str,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
) -> Result<ServeReport> {
    if total == 0 {
        return Err(Error::config("serve: need at least one request"));
    }
    let producers = producers.clamp(1, total);
    let model = backend.load_model(manifest, model_name)?;
    let (workers, width) = resolve_topology(backend, cfg);
    let prepareds: Vec<Box<dyn PreparedModel + '_>> = (0..workers)
        .map(|_| backend.prepare_serving(&model, &model.weights))
        .collect::<Result<_>>()?;
    let mixed = cfg.chaos.as_ref().is_some_and(|c| c.mixed_sizes);
    let samples = gen_request_inputs(total, &manifest.dataset, mixed)?;
    let serve_metrics = ServeMetrics::new();
    let t0 = Instant::now();
    let responses = run_session(
        &prepareds,
        &samples,
        cfg,
        width,
        producers,
        &serve_metrics,
    );
    let wall_s = t0.elapsed().as_secs_f64();
    if cfg.verify {
        let direct = backend.prepare(&model, &model.weights)?;
        let require_all = cfg.chaos.is_none() && cfg.deadline.is_none();
        verify_bit_identity(
            direct.as_ref(),
            &samples,
            &responses,
            &cfg.actq,
            require_all,
        )?;
    }
    Ok(serve_metrics.report(
        backend.name(),
        model_name,
        cfg.max_batch.max(1),
        cfg.queue_depth.max(1),
        workers,
        wall_s,
    ))
}

/// Serve a **packed quantized artifact** (`deploy::artifact`): the
/// deployment path `repro serve --artifact <dir>` drives. The model
/// named in the artifact header supplies structure and biases; the
/// artifact supplies the packed weights (staged per worker via
/// [`Backend::prepare_artifact`] — on the host backend a lock-free
/// handle running the fused dequant-matmul kernel straight off the
/// packed codes, so workers scale without serializing on shared
/// scratch) and, when present, its activation-quant deployment config
/// ([`PackedModel::deployment_actq`]), which **overrides** `cfg.actq`
/// so a saved W+A model serves exactly the configuration it was
/// calibrated with. With `cfg.verify`, every answer is re-checked
/// bit-for-bit against a direct forward of the dequantized weights —
/// i.e. serve-from-artifact vs quantize-then-forward.
pub fn run_artifact_load_generator(
    backend: &dyn Backend,
    manifest: &Manifest,
    artifact: &PackedModel,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
) -> Result<ServeReport> {
    if total == 0 {
        return Err(Error::config("serve: need at least one request"));
    }
    let producers = producers.clamp(1, total);
    let model = backend.load_model(manifest, &artifact.model)?;
    artifact.check_matches(&model)?;
    let mut cfg = cfg.clone();
    if let Some(actq) = artifact.deployment_actq()? {
        cfg.actq = Some(actq);
    }
    let (workers, width) = resolve_topology(backend, &cfg);
    let mut stageds: Vec<Vec<Tensor>> = vec![Vec::new(); workers];
    let prepareds: Vec<Box<dyn PreparedModel + '_>> = stageds
        .iter_mut()
        .map(|staged| backend.prepare_artifact(&model, artifact, staged))
        .collect::<Result<_>>()?;
    let mixed = cfg.chaos.as_ref().is_some_and(|c| c.mixed_sizes);
    let samples = gen_request_inputs(total, &manifest.dataset, mixed)?;
    let serve_metrics = ServeMetrics::new();
    let t0 = Instant::now();
    let responses = run_session(
        &prepareds,
        &samples,
        &cfg,
        width,
        producers,
        &serve_metrics,
    );
    let wall_s = t0.elapsed().as_secs_f64();
    if cfg.verify {
        let deq = artifact.dequantize_all()?;
        let direct = backend.prepare(&model, &deq)?;
        let require_all = cfg.chaos.is_none() && cfg.deadline.is_none();
        verify_bit_identity(
            direct.as_ref(),
            &samples,
            &responses,
            &cfg.actq,
            require_all,
        )?;
    }
    Ok(serve_metrics.report(
        backend.name(),
        &artifact.model,
        cfg.max_batch.max(1),
        cfg.queue_depth.max(1),
        workers,
        wall_s,
    ))
}

/// Serve a **chunked (v3) artifact progressively** (`repro serve
/// --artifact <dir> --progressive`): open the manifest only, start the
/// fleet immediately, and stream chunks in on a loader thread while
/// workers answer. Requests arriving before full residency are served
/// at the deepest resident prefix (partial depth, nearest-class-mean
/// readout); once every chunk verifies, serving is bit-identical to
/// the non-progressive packed path — checked post-convergence against
/// [`Backend::prepare_artifact`] when `cfg.verify` is set (per-answer
/// verification is impossible mid-load: a partial-depth answer is
/// *supposed* to differ from the full-depth forward).
///
/// The chaos `slow-loader` scenario injects `chunk_load_delay` before
/// each chunk so the partial-depth phase is long enough to observe;
/// chunk loads are traced as `chunk:load:<id>` spans on the
/// `chunk-loader` lane and the resident depth lands in the metrics
/// timeline per second.
pub fn run_progressive_load_generator(
    backend: &dyn Backend,
    manifest: &Manifest,
    artifact_dir: &Path,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
) -> Result<ServeReport> {
    if total == 0 {
        return Err(Error::config("serve: need at least one request"));
    }
    if !backend.supports_progressive() {
        return Err(Error::config(format!(
            "serve: backend {:?} does not support progressive artifact \
             serving (host only for now)",
            backend.name()
        )));
    }
    let producers = producers.clamp(1, total);
    let meta = crate::deploy::artifact::load_v3_meta(artifact_dir)?;
    let model = backend.load_model(manifest, &meta.model)?;
    let mut cfg = cfg.clone();
    if let Some(actq) = meta.deployment_actq()? {
        cfg.actq = Some(actq);
    }
    let (workers, width) = resolve_topology(backend, &cfg);
    let pm = ProgressiveModel::open(&model, meta)?;
    let chunk_delay = cfg
        .chaos
        .as_ref()
        .map_or(Duration::ZERO, |c| c.chunk_load_delay);
    let mixed = cfg.chaos.as_ref().is_some_and(|c| c.mixed_sizes);
    let samples = gen_request_inputs(total, &manifest.dataset, mixed)?;
    let serve_metrics = ServeMetrics::new();
    let t0 = Instant::now();
    let (responses, loader_res) = std::thread::scope(|s| {
        let loader = s.spawn(|| -> Result<()> {
            trace::set_thread_label("chunk-loader");
            for k in 0..pm.chunk_count() {
                if !chunk_delay.is_zero() {
                    // chaos slow-loader: the artifact store is slow
                    std::thread::sleep(chunk_delay);
                }
                let span = trace::span(Category::Serve, format!("chunk:load:{k}"));
                let r = pm.load_chunk(k);
                drop(span);
                if let Err(e) = r {
                    // wake blocked readers with an error instead of a
                    // forever-nap
                    pm.mark_failed();
                    return Err(e);
                }
                serve_metrics.record_resident_depth(pm.resident_depth());
            }
            Ok(())
        });
        let prepareds: Vec<Box<dyn PreparedModel + '_>> = (0..workers)
            .map(|_| Box::new(pm.handle()) as Box<dyn PreparedModel + '_>)
            .collect();
        let responses = run_session(
            &prepareds,
            &samples,
            &cfg,
            width,
            producers,
            &serve_metrics,
        );
        let loader_res = match loader.join() {
            Ok(r) => r,
            Err(_) => Err(Error::runtime("progressive chunk loader panicked")),
        };
        (responses, loader_res)
    });
    loader_res?;
    if cfg.chaos.is_none() && cfg.deadline.is_none() {
        // fault-free run: every request must have been answered (at
        // some depth) — progressive loading is not a license to drop
        if let Some(i) = responses.iter().position(|r| r.is_none()) {
            return Err(Error::invariant(format!(
                "progressive serve: request {i} got no successful response"
            )));
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    if pm.resident_chunks() != pm.chunk_count() {
        return Err(Error::invariant(format!(
            "progressive serve: loader finished with {}/{} chunks resident",
            pm.resident_chunks(),
            pm.chunk_count()
        )));
    }
    // final state into the run totals: full depth + how much traffic
    // was answered below it
    serve_metrics.record_resident_depth(pm.resident_depth());
    serve_metrics.record_partial_rows(pm.partial_rows());
    if cfg.verify {
        // post-convergence probe: with every chunk resident, the
        // progressive forward must be bit-identical to the staged
        // artifact path on the same samples
        let artifact = PackedModel::load(artifact_dir)?;
        let mut staged = Vec::new();
        let direct = backend.prepare_artifact(&model, &artifact, &mut staged)?;
        for sample in samples.iter().take(4) {
            let mut shape = vec![1];
            shape.extend(sample.shape().iter().copied());
            let x = sample.clone().reshape(shape)?;
            let rc = pm.chunk_count();
            let (got, depth) = pm.forward_at_chunks(
                &x,
                rc,
                cfg.actq.as_ref().map(|(p, b)| (p.as_slice(), b.as_slice())),
            )?;
            if depth != pm.full_depth() {
                return Err(Error::invariant(
                    "progressive serve: converged forward not at full depth",
                ));
            }
            let want = match &cfg.actq {
                Some((params, bits)) => direct.forward_actq(&x, params, bits)?,
                None => direct.forward(&x)?,
            };
            if got.shape() != want.shape() || got.data() != want.data() {
                return Err(Error::invariant(
                    "progressive serve: converged forward is not bit-identical \
                     to the packed artifact path",
                ));
            }
        }
    }
    let model_name = pm.meta().model.clone();
    Ok(serve_metrics.report(
        backend.name(),
        &model_name,
        cfg.max_batch.max(1),
        cfg.queue_depth.max(1),
        workers,
        wall_s,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    #[test]
    fn load_generator_serves_and_verifies_small_run() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let cfg = ServeConfig {
            max_batch: 8,
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let report =
            run_load_generator(&be, &manifest, "synthnet", &cfg, 48, 3).unwrap();
        assert_eq!(report.submitted, 48);
        assert_eq!(report.completed, 48);
        assert_eq!(report.errors, 0);
        assert_eq!(report.expired, 0);
        assert!(report.accounting_balanced());
        assert!(report.batches >= 48 / 8, "at least ⌈48/8⌉ batches");
        assert!(report.throughput_rps > 0.0);
        assert!(report.lat_p99_s >= report.lat_p50_s);
    }

    #[test]
    fn zero_requests_is_a_config_error() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let cfg = ServeConfig::default();
        assert!(run_load_generator(&be, &manifest, "synthnet", &cfg, 0, 1).is_err());
    }

    #[test]
    fn gen_inputs_match_dataset_dims_and_mix_sizes() {
        let m = Manifest::synthetic();
        let xs = gen_request_inputs(5, &m.dataset, false).unwrap();
        assert_eq!(xs.len(), 5);
        for x in &xs {
            assert_eq!(
                x.shape(),
                &[m.dataset.image_hw, m.dataset.image_hw, m.dataset.channels]
            );
        }
        let mixed = gen_request_inputs(6, &m.dataset, true).unwrap();
        let half = m.dataset.image_hw / 2;
        assert_eq!(mixed[2].shape(), &[half, half, m.dataset.channels]);
        assert_eq!(
            mixed[0].shape(),
            &[m.dataset.image_hw, m.dataset.image_hw, m.dataset.channels]
        );
    }
}
