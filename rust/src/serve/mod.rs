//! Batched serving: the first subsystem whose job is traffic, not
//! calibration.
//!
//! The PTQ pipeline's output — a quantized weight set — only pays off
//! behind an inference path. This module keeps a
//! [`crate::backend::PreparedModel`] **hot** (staged once via
//! [`crate::backend::Backend::prepare_serving`]) and streams request
//! batches through it:
//!
//! ```text
//!  producers ──push──► RequestQueue (bounded, reject-on-full)
//!                          │ pop_batch(max_batch, max_wait)
//!                          ▼
//!                     micro-batcher (stack + pad to max_batch rows)
//!                          │ one forward per batch
//!                          ▼
//!                     serve worker (hot PreparedModel, width-capped)
//!                          │ per-request logits rows
//!                          ▼
//!                     response channels + ServeMetrics
//! ```
//!
//! * [`queue`] — bounded MPSC admission queue; typed
//!   [`queue::AdmissionError`] on overload.
//! * [`batcher`] — request coalescing and zero-row padding.
//! * [`worker`] — the hot loop; nested parallelism bounded by
//!   [`crate::util::threadpool::with_width_cap`].
//! * [`metrics`] — latency percentiles (select-nth), queue depth, batch
//!   sizes, throughput; JSON / table / bench-baseline reporting.
//!
//! Serve-path outputs are **bit-identical** to a direct `forward` of the
//! same samples (rows are computed independently of their batch
//! neighbours; `rust/tests/serve.rs` asserts it end-to-end), so putting
//! a model behind the queue never changes what it predicts.
//!
//! [`run_load_generator`] is the self-driving mode: it generates its own
//! traffic against the synthetic host model (or any backend's model), so
//! CI exercises the full path on a bare checkout — see the `repro serve`
//! subcommand.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod worker;

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use crate::backend::{Backend, PreparedModel};
use crate::data::synth;
use crate::deploy::artifact::PackedModel;
use crate::io::manifest::{DatasetInfo, Manifest};
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::util::threadpool;

pub use metrics::{ServeMetrics, ServeReport};
pub use queue::{AdmissionError, Rejected, RequestQueue, ServeRequest, ServeResponse};
pub use worker::{run_worker, WorkerConfig};

/// Seed for load-generator traffic — disjoint from the calibration /
/// eval / train split seeds (`data::synth`) and the model-construction
/// seeds (`backend::host`).
const LOADGEN_SEED: u64 = 3001;

/// How long a producer backs off after an admission rejection before
/// retrying (load-generator mode; a real client would shed or reroute).
const RETRY_BACKOFF: Duration = Duration::from_micros(100);

/// Serving knobs (the `repro serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Coalesce up to this many requests per forward (batches are padded
    /// to exactly this many rows).
    pub max_batch: usize,
    /// How long a non-full batch waits for stragglers.
    pub max_wait: Duration,
    /// Admission bound: queued requests beyond this are rejected.
    pub queue_depth: usize,
    /// Width cap for the worker's inner kernel fan-out; 0 = the full
    /// global pool.
    pub worker_width: usize,
    /// Re-check every response against a direct `forward` of the same
    /// sample (bit-identity); load-generator mode only.
    pub verify: bool,
    /// Serve through `forward_actq` with these per-layer params/bits
    /// (the quantized-activation deployment path); `None` = plain
    /// `forward`.
    pub actq: Option<(Vec<ActQuantParams>, Vec<u8>)>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            queue_depth: 64,
            worker_width: 0,
            verify: true,
            actq: None,
        }
    }
}

/// Synthetic request traffic shaped like the manifest's dataset: the
/// class-textured generator when the dims match it, seeded Gaussian
/// noise otherwise (serving latency does not care about label
/// structure).
fn gen_inputs(total: usize, ds: &DatasetInfo) -> Result<Tensor> {
    if ds.image_hw == synth::IMG && ds.channels == synth::CHANNELS {
        Ok(synth::generate(total, LOADGEN_SEED).0)
    } else {
        let mut data = vec![0.0f32; total * ds.image_hw * ds.image_hw * ds.channels];
        Rng::new(LOADGEN_SEED).fill_gaussian(&mut data, 0.0, 1.0);
        Tensor::new(
            vec![total, ds.image_hw, ds.image_hw, ds.channels],
            data,
        )
    }
}

/// The queue → micro-batcher → worker → collector session core shared
/// by the pipeline and from-artifact load generators: `producers`
/// threads submit `total` single-sample requests (retrying with backoff
/// on admission rejection), one worker serves them hot off `prepared`,
/// and the call returns one response slot per request after a clean
/// shutdown.
fn run_session(
    prepared: &dyn PreparedModel,
    inputs: &Tensor,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
    serve_metrics: &ServeMetrics,
) -> Vec<Option<Tensor>> {
    let queue = RequestQueue::new(cfg.queue_depth);
    let wcfg = WorkerConfig {
        max_batch: cfg.max_batch.max(1),
        max_wait: cfg.max_wait,
        width: if cfg.worker_width == 0 {
            threadpool::global().size()
        } else {
            cfg.worker_width
        },
        actq: cfg.actq.clone(),
    };
    let (rtx, rrx) = channel::<ServeResponse>();
    let mut responses: Vec<Option<Tensor>> = vec![None; total];
    std::thread::scope(|s| {
        s.spawn(|| {
            // If the worker dies — panic included — close the queue and
            // error-out whatever is still queued, so producers stop
            // retrying and the collector's recv() can terminate instead
            // of hanging the whole run (the panic still propagates when
            // the scope joins).
            struct ShutdownGuard<'a>(&'a RequestQueue);
            impl Drop for ShutdownGuard<'_> {
                fn drop(&mut self) {
                    self.0.close();
                    while let Some(reqs) = self.0.pop_batch(64, Duration::ZERO) {
                        for r in reqs {
                            let _ = r.tx.send(ServeResponse {
                                id: r.id,
                                result: Err("serve worker terminated".into()),
                            });
                        }
                    }
                }
            }
            let _guard = ShutdownGuard(&queue);
            run_worker(prepared, &queue, &wcfg, serve_metrics)
        });
        let per = (total + producers - 1) / producers;
        for p in 0..producers {
            let (lo, hi) = (p * per, ((p + 1) * per).min(total));
            if lo >= hi {
                continue;
            }
            let rtx = rtx.clone();
            let (queue, metrics) = (&queue, serve_metrics);
            s.spawn(move || {
                for i in lo..hi {
                    let sample = inputs.slice_axis0(i, 1).and_then(|t| {
                        let dims = t.shape()[1..].to_vec();
                        t.reshape(dims)
                    });
                    let input = match sample {
                        Ok(t) => t,
                        Err(e) => {
                            let _ = rtx.send(ServeResponse {
                                id: i as u64,
                                result: Err(e.to_string()),
                            });
                            continue;
                        }
                    };
                    let mut req = ServeRequest {
                        id: i as u64,
                        input,
                        submitted: Instant::now(),
                        tx: rtx.clone(),
                    };
                    loop {
                        match queue.push(req) {
                            Ok(depth) => {
                                metrics.record_depth(depth);
                                break;
                            }
                            Err(rej) => match rej.error {
                                AdmissionError::QueueFull { .. } => {
                                    metrics.record_rejected();
                                    req = rej.request;
                                    std::thread::sleep(RETRY_BACKOFF);
                                    // reset only after the backoff:
                                    // latency measures time *in* the
                                    // system, not retry sleeps
                                    req.submitted = Instant::now();
                                }
                                AdmissionError::Closed => {
                                    let ServeRequest { id, tx, .. } = rej.request;
                                    let _ = tx.send(ServeResponse {
                                        id,
                                        result: Err("queue closed".into()),
                                    });
                                    break;
                                }
                            },
                        }
                    }
                }
            });
        }
        drop(rtx);
        // Collect exactly one response per request, then shut down.
        let mut got = 0usize;
        while got < total {
            match rrx.recv() {
                Ok(resp) => {
                    got += 1;
                    match resp.result {
                        Ok(t) => {
                            if let Some(slot) = responses.get_mut(resp.id as usize) {
                                *slot = Some(t);
                            }
                        }
                        Err(msg) => {
                            serve_metrics.record_error();
                            log::warn!("serve: request {} failed: {msg}", resp.id);
                        }
                    }
                }
                Err(_) => break, // every sender gone — nothing more can arrive
            }
        }
        queue.close();
    });
    responses
}

/// Re-check every collected response bit-for-bit against a direct
/// forward of the same sample on `direct` (through `forward_actq` when
/// an activation deployment config is set). An `Err` means the serving
/// path changed what the model computes, or a request never completed.
fn verify_bit_identity(
    direct: &dyn PreparedModel,
    inputs: &Tensor,
    responses: &[Option<Tensor>],
    actq: &Option<(Vec<ActQuantParams>, Vec<u8>)>,
) -> Result<()> {
    for (i, slot) in responses.iter().enumerate() {
        let got = slot.as_ref().ok_or_else(|| {
            Error::invariant(format!("serve: request {i} got no successful response"))
        })?;
        let x = inputs.slice_axis0(i, 1)?;
        let want = match actq {
            Some((params, bits)) => direct.forward_actq(&x, params, bits)?,
            None => direct.forward(&x)?,
        };
        if got.shape() != want.shape() || got.data() != want.data() {
            return Err(Error::invariant(format!(
                "serve: output for request {i} is not bit-identical to the \
                 direct forward"
            )));
        }
    }
    Ok(())
}

/// Self-driving serving session over a backend's own model weights:
/// loads the model, stages it via `prepare_serving`, and drives `total`
/// requests through [`run_session`]. With `cfg.verify` every response
/// is re-checked bit-for-bit against a direct `forward` of the same
/// sample — an `Err` from this function means the serving path changed
/// what the model computes (or a request never completed).
pub fn run_load_generator(
    backend: &dyn Backend,
    manifest: &Manifest,
    model_name: &str,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
) -> Result<ServeReport> {
    if total == 0 {
        return Err(Error::config("serve: need at least one request"));
    }
    let producers = producers.clamp(1, total);
    let model = backend.load_model(manifest, model_name)?;
    let prepared = backend.prepare_serving(&model, &model.weights)?;
    let inputs = gen_inputs(total, &manifest.dataset)?;
    let serve_metrics = ServeMetrics::new();
    let t0 = Instant::now();
    let responses = run_session(
        prepared.as_ref(),
        &inputs,
        cfg,
        total,
        producers,
        &serve_metrics,
    );
    let wall_s = t0.elapsed().as_secs_f64();
    if cfg.verify {
        let direct = backend.prepare(&model, &model.weights)?;
        verify_bit_identity(direct.as_ref(), &inputs, &responses, &cfg.actq)?;
    }
    Ok(serve_metrics.report(
        backend.name(),
        model_name,
        cfg.max_batch.max(1),
        cfg.queue_depth.max(1),
        wall_s,
    ))
}

/// Serve a **packed quantized artifact** (`deploy::artifact`): the
/// deployment path `repro serve --artifact <dir>` drives. The model
/// named in the artifact header supplies structure and biases; the
/// artifact supplies the packed weights (staged via
/// [`Backend::prepare_artifact`] — dequant-on-the-fly on the host
/// backend) and, when present, its activation-quant deployment config,
/// which **overrides** `cfg.actq` so a saved W+A model serves exactly
/// the configuration it was calibrated with. With `cfg.verify`, every
/// response is re-checked bit-for-bit against a direct forward of the
/// dequantized weights — i.e. serve-from-artifact vs
/// quantize-then-forward.
pub fn run_artifact_load_generator(
    backend: &dyn Backend,
    manifest: &Manifest,
    artifact: &PackedModel,
    cfg: &ServeConfig,
    total: usize,
    producers: usize,
) -> Result<ServeReport> {
    if total == 0 {
        return Err(Error::config("serve: need at least one request"));
    }
    let producers = producers.clamp(1, total);
    let model = backend.load_model(manifest, &artifact.model)?;
    artifact.check_matches(&model)?;
    let mut cfg = cfg.clone();
    if let Some(params) = &artifact.act_params {
        let bits: Vec<u8> = match &artifact.act_bits {
            Some(b) => b.clone(),
            None => {
                // v1 dirs carry act_params but never recorded widths;
                // the weight widths are the documented fallback — but
                // only where they are usable activation widths (the
                // actq grids shift by them).
                let bits: Vec<u8> = artifact.layers.iter().map(|l| l.bits).collect();
                if let Some(&b) = bits.iter().find(|&&b| !(1..=16).contains(&b)) {
                    return Err(Error::config(format!(
                        "artifact {}: v1 dir has act_params but no act_bits, and \
                         weight width {b} is not a usable activation width — \
                         re-save the model to migrate it to v2",
                        artifact.model
                    )));
                }
                log::warn!(
                    "artifact {}: act_params without act_bits (v1 dir) — \
                     serving with the weight widths",
                    artifact.model
                );
                bits
            }
        };
        cfg.actq = Some((params.clone(), bits));
    }
    let mut staged = Vec::new();
    let prepared = backend.prepare_artifact(&model, artifact, &mut staged)?;
    let inputs = gen_inputs(total, &manifest.dataset)?;
    let serve_metrics = ServeMetrics::new();
    let t0 = Instant::now();
    let responses = run_session(
        prepared.as_ref(),
        &inputs,
        &cfg,
        total,
        producers,
        &serve_metrics,
    );
    let wall_s = t0.elapsed().as_secs_f64();
    if cfg.verify {
        let deq = artifact.dequantize_all()?;
        let direct = backend.prepare(&model, &deq)?;
        verify_bit_identity(direct.as_ref(), &inputs, &responses, &cfg.actq)?;
    }
    Ok(serve_metrics.report(
        backend.name(),
        &artifact.model,
        cfg.max_batch.max(1),
        cfg.queue_depth.max(1),
        wall_s,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    #[test]
    fn load_generator_serves_and_verifies_small_run() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let cfg = ServeConfig {
            max_batch: 8,
            queue_depth: 16,
            ..ServeConfig::default()
        };
        let report =
            run_load_generator(&be, &manifest, "synthnet", &cfg, 48, 3).unwrap();
        assert_eq!(report.completed, 48);
        assert_eq!(report.errors, 0);
        assert!(report.batches >= 48 / 8, "at least ⌈48/8⌉ batches");
        assert!(report.throughput_rps > 0.0);
        assert!(report.lat_p99_s >= report.lat_p50_s);
    }

    #[test]
    fn zero_requests_is_a_config_error() {
        let be = HostBackend::new();
        let manifest = Manifest::synthetic();
        let cfg = ServeConfig::default();
        assert!(run_load_generator(&be, &manifest, "synthnet", &cfg, 0, 1).is_err());
    }

    #[test]
    fn gen_inputs_matches_dataset_dims() {
        let m = Manifest::synthetic();
        let x = gen_inputs(5, &m.dataset).unwrap();
        assert_eq!(
            x.shape(),
            &[5, m.dataset.image_hw, m.dataset.image_hw, m.dataset.channels]
        );
    }
}
