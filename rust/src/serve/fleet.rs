//! Worker supervision: N serve workers off the one bounded queue, each
//! under a supervisor that catches panics, restarts with bounded
//! exponential backoff, and trips a circuit breaker on a restart storm.
//!
//! The PR-4 single-worker session tied shutdown to a `ShutdownGuard`
//! inside the one worker thread: worker dies → queue closes → the run
//! drains. With a fleet that is wrong twice over — one worker's panic
//! must *not* end the session (the supervisor restarts it and the queue
//! keeps its contents), and the queue must close only when the *last*
//! supervisor gives up or finishes. So the guard is hoisted to the fleet
//! level: [`supervise`] holds a [`LastWorkerOut`] whose `Drop`
//! decrements a shared alive-counter and, at zero, closes the queue and
//! answers everything still queued with a typed
//! [`ServeOutcome::Failed`] — no submitted request is ever silently
//! dropped, even if every worker dies.
//!
//! Failure layering (who answers what):
//! * a panic mid-batch → the worker's own in-flight guard
//!   (`serve::worker`) fails over exactly the popped requests;
//! * the supervisor catches the panic, restarts the worker after
//!   backoff — queued requests are untouched;
//! * restarts past [`FleetConfig::max_restarts`] trip the breaker: that
//!   supervisor exits, and if it was the last one alive,
//!   [`LastWorkerOut`] drain-fails the backlog.
//!
//! Supervisors never propagate panics to the session scope — a chaos
//! run with injected crashes still joins cleanly and reports.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use crate::backend::PreparedModel;
use crate::serve::metrics::ServeMetrics;
use crate::serve::queue::{RequestQueue, ServeOutcome, ServeResponse};
use crate::serve::worker::{run_worker, WorkerConfig};
use crate::trace::{self, Category};

/// Supervision knobs.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// First restart delay; doubles per consecutive restart.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
    /// Circuit breaker: give up on a worker after this many restarts.
    pub max_restarts: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            backoff_base: Duration::from_micros(500),
            backoff_max: Duration::from_millis(50),
            max_restarts: 5,
        }
    }
}

/// Render a `catch_unwind` payload (worker panics carry `&str` or
/// `String`; anything else gets a placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Last-supervisor-out shutdown guard: decrements `alive` on drop; the
/// supervisor that brings it to zero closes the queue and answers the
/// remaining backlog with `Failed`, so producers stop retrying and the
/// collector terminates instead of hanging.
struct LastWorkerOut<'a> {
    queue: &'a RequestQueue,
    alive: &'a AtomicUsize,
}

impl Drop for LastWorkerOut<'_> {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.queue.close();
            while let Some(reqs) = self.queue.pop_batch(64, Duration::ZERO) {
                for r in reqs {
                    let _ = r.tx.send(ServeResponse {
                        id: r.id,
                        outcome: ServeOutcome::Failed(
                            "serve fleet: all workers terminated".into(),
                        ),
                    });
                }
            }
        }
    }
}

/// Run one supervised worker until the queue closes cleanly or the
/// restart breaker trips. `alive` must start at the fleet's worker
/// count; every supervisor decrements it exactly once on exit (panic
/// paths included — the guard is a `Drop`).
///
/// A worker panic is *contained* here: the in-flight requests were
/// already failed over by the worker's own guard, the queue keeps its
/// contents, and the worker restarts after `backoff_base · 2ⁿ` (capped
/// at `backoff_max`). A clean `run_worker` return (queue closed and
/// drained) ends supervision without touching the queue.
pub fn supervise(
    worker_id: usize,
    prepared: &dyn PreparedModel,
    queue: &RequestQueue,
    cfg: &WorkerConfig,
    metrics: &ServeMetrics,
    fleet: &FleetConfig,
    alive: &AtomicUsize,
) {
    let _last_out = LastWorkerOut { queue, alive };
    let mut restarts = 0usize;
    let mut backoff = fleet.backoff_base;
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            run_worker(worker_id, prepared, queue, cfg, metrics)
        }));
        match run {
            Ok(()) => return, // queue closed and drained: clean exit
            Err(payload) => {
                let msg = panic_message(&payload);
                if restarts >= fleet.max_restarts {
                    trace::instant(
                        Category::Serve,
                        format!("worker-{worker_id}:breaker-open"),
                    );
                    log::error!(
                        "serve fleet: worker {worker_id} panicked ({msg}) after \
                         {restarts} restarts — circuit breaker open, giving up"
                    );
                    return; // LastWorkerOut answers the backlog if we're last
                }
                restarts += 1;
                metrics.record_restart();
                trace::instant(
                    Category::Serve,
                    format!("worker-{worker_id}:restart-{restarts}"),
                );
                log::warn!(
                    "serve fleet: worker {worker_id} panicked ({msg}); \
                     restart {restarts}/{} after {backoff:?}",
                    fleet.max_restarts
                );
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(fleet.backoff_max);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::observer::ActQuantParams;
    use crate::serve::queue::ServeRequest;
    use crate::tensor::Tensor;
    use crate::util::error::Result;
    use crate::util::threadpool;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    /// Forward either panics every call or returns `[rows, 1]` zeros.
    struct StubPrep {
        panic_always: bool,
    }

    impl PreparedModel for StubPrep {
        fn forward(&self, x: &Tensor) -> Result<Tensor> {
            if self.panic_always {
                panic!("stub: injected forward panic");
            }
            Ok(Tensor::zeros(vec![x.shape()[0], 1]))
        }
        fn forward_actq(
            &self,
            x: &Tensor,
            _p: &[ActQuantParams],
            _b: &[u8],
        ) -> Result<Tensor> {
            self.forward(x)
        }
        fn collect(&self, x: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
            Ok((Vec::new(), self.forward(x)?))
        }
    }

    fn wcfg() -> WorkerConfig {
        WorkerConfig {
            max_batch: 2,
            max_wait: Duration::from_micros(50),
            width: 1,
            actq: None,
            chaos: None,
        }
    }

    fn fast_fleet(max_restarts: usize) -> FleetConfig {
        FleetConfig {
            backoff_base: Duration::from_micros(10),
            backoff_max: Duration::from_micros(100),
            max_restarts,
        }
    }

    #[test]
    fn clean_queue_close_ends_supervision_without_restarts() {
        let prep = StubPrep { panic_always: false };
        let queue = RequestQueue::new(4);
        let metrics = ServeMetrics::new();
        let alive = AtomicUsize::new(1);
        let (tx, rx) = channel::<ServeResponse>();
        queue
            .push(ServeRequest {
                id: 0,
                input: Tensor::zeros(vec![2]),
                submitted: Instant::now(),
                deadline: None,
                tx,
            })
            .unwrap();
        queue.close();
        supervise(0, &prep, &queue, &wcfg(), &metrics, &fast_fleet(3), &alive);
        let resp = rx.recv().unwrap();
        assert!(matches!(resp.outcome, ServeOutcome::Answer(_)));
        assert_eq!(metrics.report("host", "stub", 2, 4, 1, 0.1).restarts, 0);
        assert_eq!(alive.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn restart_storm_trips_breaker_and_fails_backlog() {
        let prep = StubPrep { panic_always: true };
        let queue = RequestQueue::new(16);
        let metrics = ServeMetrics::new();
        let alive = AtomicUsize::new(1);
        let mut rxs = Vec::new();
        for id in 0..8u64 {
            let (tx, rx) = channel::<ServeResponse>();
            queue
                .push(ServeRequest {
                    id,
                    input: Tensor::zeros(vec![2]),
                    submitted: Instant::now(),
                    deadline: None,
                    tx,
                })
                .unwrap();
            rxs.push(rx);
        }
        supervise(0, &prep, &queue, &wcfg(), &metrics, &fast_fleet(2), &alive);
        // breaker: exactly max_restarts restarts were attempted, then the
        // last supervisor out closed the queue and failed the backlog —
        // every request still gets exactly one terminal response
        let report = metrics.report("host", "stub", 2, 16, 1, 0.1);
        assert_eq!(report.restarts, 2);
        assert!(queue.is_closed());
        for rx in &rxs {
            let resp = rx.recv().expect("exactly one terminal response");
            assert!(
                matches!(resp.outcome, ServeOutcome::Failed(_)),
                "panicking worker must fail requests, not answer them"
            );
            assert!(rx.try_recv().is_err(), "no double-response");
        }
    }

    #[test]
    fn width_cap_restored_after_worker_panic() {
        // `with_width_cap`'s restore is a Drop guard, so an unwinding
        // worker must put the thread-local cap back — a restarted worker
        // on the same supervisor thread sees the full pool again.
        let before = threadpool::current_width_cap();
        let r = catch_unwind(AssertUnwindSafe(|| {
            threadpool::with_width_cap(1, || -> usize { panic!("boom") })
        }));
        assert!(r.is_err());
        assert_eq!(threadpool::current_width_cap(), before);
    }

    #[test]
    fn panic_message_extracts_both_payload_kinds() {
        let s = catch_unwind(|| panic!("static")).unwrap_err();
        assert_eq!(panic_message(&*s), "static");
        let owned = catch_unwind(|| panic!("{}-{}", 1, 2)).unwrap_err();
        assert_eq!(panic_message(&*owned), "1-2");
    }
}
