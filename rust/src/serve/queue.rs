//! Bounded MPSC request queue with admission control.
//!
//! Producers [`RequestQueue::push`] single-sample requests; the serve
//! worker drains them with [`RequestQueue::pop_batch`], which coalesces
//! up to `max_batch` requests per call (micro-batching — see
//! `serve::batcher`). The queue is **bounded**: a push against a full
//! queue is rejected immediately with a typed [`AdmissionError`] and the
//! request handed back to the caller ([`Rejected`]), so overload turns
//! into fast feedback at the edge instead of unbounded memory growth and
//! tail-latency collapse. [`RequestQueue::close`] starts a clean
//! shutdown: further pushes are rejected, `pop_batch` drains what is
//! queued and then returns `None`.
//!
//! Concurrency guarantee: `push` never blocks on anything but the queue
//! mutex — it either admits or rejects immediately — so a `close()`
//! racing any number of mid-`push` producers always resolves to
//! [`AdmissionError::Closed`] with the request handed back intact;
//! there is no state in which a producer can wedge against shutdown
//! (`rust/tests/serve.rs` hammers this race).

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::tensor::Tensor;

/// One inference request: a single sample (no leading batch dimension;
/// the micro-batcher adds it) plus the response channel.
pub struct ServeRequest {
    /// Caller-assigned id, echoed on the response.
    pub id: u64,
    /// One sample, e.g. `[H, W, C]` for the image models.
    pub input: Tensor,
    /// Admission time — latency is measured from here to response send.
    pub submitted: Instant,
    /// Absolute expiry: a request still unserved at this instant is shed
    /// before any forward compute and answered with
    /// [`ServeOutcome::Expired`]. Fixed at creation — producer retries
    /// must not extend it. `None` = never expires.
    pub deadline: Option<Instant>,
    pub tx: Sender<ServeResponse>,
}

/// Every request's exactly-one terminal state. The serving contract is
/// that each submitted request gets exactly one of these — never a
/// stale answer, never a silent drop, never a hang — and the fleet
/// accounting (`ServeReport::accounting_balanced`) asserts it.
pub enum ServeOutcome {
    /// The logits row for this request (shape `[1, classes]`,
    /// bit-identical to a direct `forward` of the same sample).
    Answer(Tensor),
    /// Terminal admission rejection: the queue closed (or the producer
    /// gave up) before the request was ever admitted.
    Rejected(AdmissionError),
    /// The deadline passed before the forward ran; the request was shed
    /// pre-compute so it never wasted a batch slot.
    Expired,
    /// The worker (or its forward) failed while this request was in
    /// flight — including a worker panic mid-batch, which fails over
    /// exactly the popped requests (see `serve::worker`).
    Failed(String),
}

impl ServeOutcome {
    /// Short label for logs and accounting tables.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeOutcome::Answer(_) => "answer",
            ServeOutcome::Rejected(_) => "rejected",
            ServeOutcome::Expired => "expired",
            ServeOutcome::Failed(_) => "failed",
        }
    }
}

/// The terminal response for one request — see [`ServeOutcome`].
pub struct ServeResponse {
    pub id: u64,
    pub outcome: ServeOutcome,
}

/// Why admission control turned a request away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The queue already holds `depth` requests; shed load or retry.
    QueueFull { depth: usize },
    /// The queue is shutting down; no further requests are accepted.
    Closed,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::QueueFull { depth } => {
                write!(f, "queue full (depth {depth})")
            }
            AdmissionError::Closed => write!(f, "queue closed"),
        }
    }
}

/// A rejected push: the error plus the request, returned intact so the
/// caller can retry, reroute, or answer it directly.
pub struct Rejected {
    pub request: ServeRequest,
    pub error: AdmissionError,
}

impl fmt::Debug for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Rejected({}, request {})", self.error, self.request.id)
    }
}

struct QueueInner {
    q: VecDeque<ServeRequest>,
    closed: bool,
}

/// The bounded queue. `Mutex + Condvar` (not a channel) because the
/// consumer needs batched, deadline-bounded draining and the producers
/// need reject-on-full — neither fits `std::sync::mpsc`.
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    depth: usize,
}

impl RequestQueue {
    /// A queue admitting at most `depth` (min 1) waiting requests.
    pub fn new(depth: usize) -> Self {
        RequestQueue {
            inner: Mutex::new(QueueInner {
                q: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Configured admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Requests currently waiting (racy snapshot, for metrics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a request, or hand it back with a typed error. On success
    /// returns the queue depth *after* the push (a natural metrics
    /// sample point).
    pub fn push(&self, request: ServeRequest) -> std::result::Result<usize, Rejected> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Rejected {
                request,
                error: AdmissionError::Closed,
            });
        }
        if g.q.len() >= self.depth {
            return Err(Rejected {
                request,
                error: AdmissionError::QueueFull { depth: self.depth },
            });
        }
        g.q.push_back(request);
        let depth_now = g.q.len();
        drop(g);
        self.cv.notify_one();
        Ok(depth_now)
    }

    /// Begin shutdown: reject new pushes, wake the worker so it drains
    /// the backlog and exits.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Micro-batch drain: block until at least one request is queued
    /// (or `None` once closed and empty), then keep coalescing arrivals
    /// for up to `max_wait` — returning early as soon as `max_batch`
    /// requests are in hand or the queue closes. The wait bounds the
    /// latency a lone request pays for the *chance* of batching.
    pub fn pop_batch(
        &self,
        max_batch: usize,
        max_wait: Duration,
    ) -> Option<Vec<ServeRequest>> {
        let max_batch = max_batch.max(1);
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.q.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        let deadline = Instant::now() + max_wait;
        while g.q.len() < max_batch && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if timeout.timed_out() {
                break;
            }
        }
        let take = g.q.len().min(max_batch);
        Some(g.q.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{channel, Receiver};

    fn req(id: u64) -> (ServeRequest, Receiver<ServeResponse>) {
        let (tx, rx) = channel();
        (
            ServeRequest {
                id,
                input: Tensor::zeros(vec![2, 2, 1]),
                submitted: Instant::now(),
                deadline: None,
                tx,
            },
            rx,
        )
    }

    #[test]
    fn rejects_when_full_with_typed_error() {
        let q = RequestQueue::new(2);
        let mut rxs = Vec::new();
        for id in 0..2 {
            let (r, rx) = req(id);
            assert_eq!(q.push(r).unwrap(), id as usize + 1);
            rxs.push(rx);
        }
        let (r, _rx) = req(2);
        let rej = q.push(r).unwrap_err();
        assert_eq!(rej.error, AdmissionError::QueueFull { depth: 2 });
        assert_eq!(rej.request.id, 2, "request handed back intact");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = RequestQueue::new(4);
        let (r, _rx) = req(0);
        q.push(r).unwrap();
        q.close();
        let (r, _rx2) = req(1);
        let rej = q.push(r).unwrap_err();
        assert_eq!(rej.error, AdmissionError::Closed);
        // the backlog is still drained after close …
        let drained = q.pop_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].id, 0);
        // … and only then does the worker see shutdown
        assert!(q.pop_batch(8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pop_batch_coalesces_up_to_max_batch() {
        let q = RequestQueue::new(8);
        let mut rxs = Vec::new();
        for id in 0..5 {
            let (r, rx) = req(id);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        let first = q.pop_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), [0, 1, 2, 3]);
        let rest = q.pop_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, 4);
    }

    #[test]
    fn zero_depth_clamped() {
        assert_eq!(RequestQueue::new(0).depth(), 1);
    }
}
