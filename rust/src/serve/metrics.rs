//! Serving metrics: per-request latency histogram, queue-depth and
//! batch-size distributions, admission-control counters, and sustained
//! throughput — collected lock-cheap during the run, summarized into a
//! [`ServeReport`] at shutdown.
//!
//! Percentiles (p50/p95/p99) come from the same O(n) select-nth
//! machinery the activation observers use
//! ([`crate::tensor::ops::percentile_with`]), not a full sort. The
//! report renders three ways: a [`crate::report::Table`] for humans, a
//! hand-rolled JSON object (`util::json`-parseable — serde is not
//! offline-available), and [`crate::bench_harness::Stats`] rows so the
//! serve path lands in the committed `BENCH_host.json` baseline next to
//! the kernel benches.

use std::sync::Mutex;
use std::time::Duration;

use crate::bench_harness::{fmt_dur, Stats};
use crate::report::Table;
use crate::tensor::ops;

#[derive(Default)]
struct MetricsInner {
    latencies_s: Vec<f32>,
    batch_real: Vec<u32>,
    depth_samples: Vec<u32>,
    padded_rows: u64,
    rejected: u64,
    errors: u64,
}

/// Shared collector: producers record admission samples, the worker
/// records batches and latencies, the collector records errors.
#[derive(Default)]
pub struct ServeMetrics {
    inner: Mutex<MetricsInner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admission→response latency of one completed request.
    pub fn record_latency(&self, d: Duration) {
        self.inner
            .lock()
            .unwrap()
            .latencies_s
            .push(d.as_secs_f32());
    }

    /// One executed batch: `real` request rows and `padded` zero rows.
    pub fn record_batch(&self, real: usize, padded: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batch_real.push(real as u32);
        g.padded_rows += padded as u64;
    }

    /// Queue depth observed right after an accepted push.
    pub fn record_depth(&self, depth: usize) {
        self.inner.lock().unwrap().depth_samples.push(depth as u32);
    }

    /// One admission-control rejection (queue full).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request that came back with an error response.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Summarize into a report. `wall_s` is the whole run's wall clock
    /// (throughput = completed / wall).
    pub fn report(
        &self,
        backend: &str,
        model: &str,
        max_batch: usize,
        queue_depth: usize,
        wall_s: f64,
    ) -> ServeReport {
        let g = self.inner.lock().unwrap();
        let mut scratch = Vec::new();
        let mut pct = |p: f64| -> f64 {
            if g.latencies_s.is_empty() {
                0.0
            } else {
                ops::percentile_with(&g.latencies_s, p, &mut scratch) as f64
            }
        };
        let (lat_p50_s, lat_p95_s, lat_p99_s) = (pct(50.0), pct(95.0), pct(99.0));
        let n = g.latencies_s.len();
        let sum: f64 = g.latencies_s.iter().map(|&v| v as f64).sum();
        let lat_mean_s = if n == 0 { 0.0 } else { sum / n as f64 };
        let lat_min_s = g.latencies_s.iter().cloned().fold(f64::INFINITY, |a, v| a.min(v as f64));
        let lat_max_s = g.latencies_s.iter().cloned().fold(0.0f64, |a, v| a.max(v as f64));
        let batches = g.batch_real.len() as u64;
        let real_total: u64 = g.batch_real.iter().map(|&b| b as u64).sum();
        let batch_mean = if batches == 0 { 0.0 } else { real_total as f64 / batches as f64 };
        let batch_max = g.batch_real.iter().cloned().max().unwrap_or(0) as u64;
        let depth_n = g.depth_samples.len();
        let depth_sum: u64 = g.depth_samples.iter().map(|&d| d as u64).sum();
        let depth_mean = if depth_n == 0 { 0.0 } else { depth_sum as f64 / depth_n as f64 };
        let depth_max = g.depth_samples.iter().cloned().max().unwrap_or(0) as u64;
        ServeReport {
            backend: backend.to_string(),
            model: model.to_string(),
            max_batch,
            queue_depth,
            completed: n as u64,
            rejected: g.rejected,
            errors: g.errors,
            batches,
            padded_rows: g.padded_rows,
            batch_mean,
            batch_max,
            depth_mean,
            depth_max,
            lat_p50_s,
            lat_p95_s,
            lat_p99_s,
            lat_mean_s,
            lat_min_s: if n == 0 { 0.0 } else { lat_min_s },
            lat_max_s,
            wall_s,
            throughput_rps: if wall_s > 0.0 { n as f64 / wall_s } else { 0.0 },
            latencies_s: g.latencies_s.clone(),
        }
    }
}

/// A finished serving run, summarized.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub backend: String,
    pub model: String,
    pub max_batch: usize,
    pub queue_depth: usize,
    /// Requests that received a successful response.
    pub completed: u64,
    /// Admission-control rejections (each may have been retried).
    pub rejected: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Batches executed.
    pub batches: u64,
    /// Zero pad rows executed across all batches.
    pub padded_rows: u64,
    pub batch_mean: f64,
    pub batch_max: u64,
    pub depth_mean: f64,
    pub depth_max: u64,
    pub lat_p50_s: f64,
    pub lat_p95_s: f64,
    pub lat_p99_s: f64,
    pub lat_mean_s: f64,
    pub lat_min_s: f64,
    pub lat_max_s: f64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// Raw per-request latencies (seconds) for downstream stats.
    pub latencies_s: Vec<f32>,
}

impl ServeReport {
    /// JSON object in the same hand-rolled style as
    /// [`crate::bench_harness::write_json`]; round-trips through
    /// [`crate::util::json::parse`].
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\n",
                "  \"serve\": {{\n",
                "    \"backend\": \"{}\",\n",
                "    \"model\": \"{}\",\n",
                "    \"max_batch\": {},\n",
                "    \"queue_depth\": {},\n",
                "    \"completed\": {},\n",
                "    \"rejected\": {},\n",
                "    \"errors\": {},\n",
                "    \"batches\": {},\n",
                "    \"padded_rows\": {},\n",
                "    \"batch_size_mean\": {:e},\n",
                "    \"batch_size_max\": {},\n",
                "    \"queue_depth_mean\": {:e},\n",
                "    \"queue_depth_max\": {},\n",
                "    \"latency_s\": {{\"p50\": {:e}, \"p95\": {:e}, \"p99\": {:e}, ",
                "\"mean\": {:e}, \"min\": {:e}, \"max\": {:e}}},\n",
                "    \"wall_s\": {:e},\n",
                "    \"throughput_rps\": {:e}\n",
                "  }}\n",
                "}}"
            ),
            self.backend,
            self.model,
            self.max_batch,
            self.queue_depth,
            self.completed,
            self.rejected,
            self.errors,
            self.batches,
            self.padded_rows,
            self.batch_mean,
            self.batch_max,
            self.depth_mean,
            self.depth_max,
            self.lat_p50_s,
            self.lat_p95_s,
            self.lat_p99_s,
            self.lat_mean_s,
            self.lat_min_s,
            self.lat_max_s,
            self.wall_s,
            self.throughput_rps,
        )
    }

    /// Human-readable summary table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Serve — {} on {} (batch ≤{}, queue {})",
                self.model, self.backend, self.max_batch, self.queue_depth
            ),
            &["Metric", "Value"],
        );
        let rows: Vec<(&str, String)> = vec![
            ("completed", self.completed.to_string()),
            ("rejected (admission)", self.rejected.to_string()),
            ("errors", self.errors.to_string()),
            ("batches", self.batches.to_string()),
            ("padded rows", self.padded_rows.to_string()),
            (
                "batch size mean/max",
                format!("{:.2} / {}", self.batch_mean, self.batch_max),
            ),
            (
                "queue depth mean/max",
                format!("{:.2} / {}", self.depth_mean, self.depth_max),
            ),
            ("latency p50", fmt_dur(self.lat_p50_s)),
            ("latency p95", fmt_dur(self.lat_p95_s)),
            ("latency p99", fmt_dur(self.lat_p99_s)),
            ("latency mean", fmt_dur(self.lat_mean_s)),
            ("wall", format!("{:.3}s", self.wall_s)),
            (
                "throughput",
                format!("{:.1} req/s", self.throughput_rps),
            ),
        ];
        for (k, v) in rows {
            t.row(vec![k.to_string(), v]);
        }
        t
    }

    /// The latency distribution as a [`Stats`] row, so serve latency
    /// lands in the `BENCH_host.json` baseline alongside the kernels.
    pub fn latency_stats(&self, name: &str) -> Stats {
        let samples: Vec<f64> = self.latencies_s.iter().map(|&v| v as f64).collect();
        crate::bench_harness::stats_from_samples(name, &samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> ServeMetrics {
        let m = ServeMetrics::new();
        for i in 0..100u32 {
            m.record_latency(Duration::from_micros(100 + i as u64));
        }
        m.record_batch(16, 0);
        m.record_batch(4, 12);
        m.record_depth(3);
        m.record_depth(9);
        m.record_rejected();
        m.record_error();
        m
    }

    #[test]
    fn percentiles_ordered_and_counts_roll_up() {
        let r = filled().report("host", "synthnet", 16, 64, 0.5);
        assert_eq!(r.completed, 100);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.errors, 1);
        assert_eq!(r.batches, 2);
        assert_eq!(r.padded_rows, 12);
        assert_eq!(r.batch_max, 16);
        assert!((r.batch_mean - 10.0).abs() < 1e-9);
        assert_eq!(r.depth_max, 9);
        assert!(r.lat_p50_s <= r.lat_p95_s && r.lat_p95_s <= r.lat_p99_s);
        assert!(r.lat_min_s > 0.0 && r.lat_max_s >= r.lat_p99_s);
        assert!((r.throughput_rps - 200.0).abs() < 1e-6);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let r = filled().report("host", "synthnet", 16, 64, 0.5);
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        let s = j.get("serve").unwrap();
        assert_eq!(s.get("completed").unwrap().as_f64().unwrap(), 100.0);
        assert!(s.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        let lat = s.get("latency_s").unwrap();
        assert!(lat.get("p99").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn empty_run_reports_zeros() {
        let r = ServeMetrics::new().report("host", "m", 8, 8, 0.0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.lat_p50_s, 0.0);
        assert_eq!(r.lat_min_s, 0.0);
        // JSON stays parseable with zero samples
        assert!(crate::util::json::parse(&r.to_json()).is_ok());
    }

    #[test]
    fn latency_stats_bridge() {
        let r = filled().report("host", "m", 8, 8, 1.0);
        let s = r.latency_stats("host/serve_latency");
        assert_eq!(s.iters, 100);
        assert!(s.mean_s > 0.0 && s.min_s <= s.median_s && s.median_s <= s.max_s);
    }
}
