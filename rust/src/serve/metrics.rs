//! Serving metrics: per-request latency histogram, queue-depth and
//! batch-size distributions, terminal-state accounting, fleet restart
//! counts, and sustained throughput — collected lock-cheap during the
//! run, summarized into a [`ServeReport`] at shutdown.
//!
//! Counting discipline (one site per number, so chaos runs can assert
//! exact balances):
//! * producers record `submitted` (once per request), admission
//!   `rejected` samples (retries each count) and queue depth;
//! * workers record latencies — **answers only**, so `completed` is
//!   exactly the answered set — and per-worker batch geometry;
//! * the response collector is the single counting site for terminal
//!   `expired` / `errors` / `rejected_final`;
//! * fleet supervisors record `restarts`.
//!
//! [`ServeReport::accounting_balanced`] then checks the zero-lost
//! invariant: every submitted request reached exactly one terminal
//! state (`submitted == completed + rejected_final + expired + errors`).
//!
//! Percentiles (p50/p95/p99) come from the same O(n) select-nth
//! machinery the activation observers use
//! ([`crate::tensor::ops::percentile_with`]), not a full sort. The
//! report renders three ways: a [`crate::report::Table`] for humans, a
//! hand-rolled JSON object (`util::json`-parseable — serde is not
//! offline-available), and [`crate::bench_harness::Stats`] rows so the
//! serve path lands in the committed `BENCH_host.json` baseline next to
//! the kernel benches.

use std::sync::Mutex;
use std::time::Duration;

use crate::bench_harness::{fmt_dur, Stats};
use crate::report::Table;
use crate::tensor::ops;
use crate::trace::timeline::{Timeline, TimelineReport};

#[derive(Default)]
struct MetricsInner {
    /// Per-second telemetry buckets, fed from the same recording sites
    /// (and under the same lock) as the run totals — see
    /// `trace::timeline` for the invariant this buys.
    timeline: Timeline,
    latencies_s: Vec<f32>,
    batch_real: Vec<u32>,
    depth_samples: Vec<u32>,
    worker_batches: Vec<u64>,
    padded_rows: u64,
    submitted: u64,
    rejected: u64,
    rejected_final: u64,
    expired: u64,
    errors: u64,
    restarts: u64,
    /// Deepest resident layer prefix observed (progressive serving;
    /// stays 0 on non-progressive runs).
    resident_depth_max: u64,
    /// Rows answered at less than full depth (progressive serving).
    partial_rows: u64,
}

/// Shared collector: producers record admission samples, workers record
/// batches and latencies, the collector records terminal states, the
/// fleet records restarts.
#[derive(Default)]
pub struct ServeMetrics {
    inner: Mutex<MetricsInner>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One request entering the system (before its first push attempt).
    pub fn record_submitted(&self) {
        let mut g = self.inner.lock().unwrap();
        g.submitted += 1;
        let sec = g.timeline.now_sec();
        g.timeline.record_submitted(sec);
    }

    /// Admission→response latency of one *answered* request.
    pub fn record_latency(&self, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(d.as_secs_f32());
        let sec = g.timeline.now_sec();
        g.timeline.record_completed(sec, d.as_secs_f64());
    }

    /// One executed batch on `worker_id`: `real` request rows and
    /// `padded` zero rows.
    pub fn record_batch(&self, worker_id: usize, real: usize, padded: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batch_real.push(real as u32);
        g.padded_rows += padded as u64;
        if g.worker_batches.len() <= worker_id {
            g.worker_batches.resize(worker_id + 1, 0);
        }
        g.worker_batches[worker_id] += 1;
        let sec = g.timeline.now_sec();
        g.timeline.record_batch(sec, worker_id, real, padded);
    }

    /// Queue depth observed right after an accepted push.
    pub fn record_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.depth_samples.push(depth as u32);
        let sec = g.timeline.now_sec();
        g.timeline.record_depth(sec, depth);
    }

    /// One admission-control rejection (queue full; the producer may
    /// retry, so this counts *events*, not requests).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request whose *terminal* state is an admission rejection
    /// (queue closed before it ever got in).
    pub fn record_rejected_final(&self) {
        let mut g = self.inner.lock().unwrap();
        g.rejected_final += 1;
        let sec = g.timeline.now_sec();
        g.timeline.record_rejected_final(sec);
    }

    /// One request shed past its deadline (terminal `Expired`).
    pub fn record_expired(&self) {
        let mut g = self.inner.lock().unwrap();
        g.expired += 1;
        let sec = g.timeline.now_sec();
        g.timeline.record_expired(sec);
    }

    /// One request answered with a failure (terminal `Failed`).
    pub fn record_error(&self) {
        let mut g = self.inner.lock().unwrap();
        g.errors += 1;
        let sec = g.timeline.now_sec();
        g.timeline.record_error(sec);
    }

    /// One supervised worker restart after a panic.
    pub fn record_restart(&self) {
        self.inner.lock().unwrap().restarts += 1;
    }

    /// The resident layer depth a worker (or the progressive loader)
    /// observed — monotone max into the run total, and bucketed into
    /// the timeline so depth convergence is visible per second.
    pub fn record_resident_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.resident_depth_max = g.resident_depth_max.max(depth as u64);
        let sec = g.timeline.now_sec();
        g.timeline.record_resident_depth(sec, depth);
    }

    /// Rows answered at less than full depth (reported once by the
    /// progressive driver at shutdown).
    pub fn record_partial_rows(&self, rows: u64) {
        self.inner.lock().unwrap().partial_rows += rows;
    }

    /// Summarize into a report. `workers` is the fleet size; `wall_s` is
    /// the whole run's wall clock (throughput = completed / wall).
    pub fn report(
        &self,
        backend: &str,
        model: &str,
        max_batch: usize,
        queue_depth: usize,
        workers: usize,
        wall_s: f64,
    ) -> ServeReport {
        let g = self.inner.lock().unwrap();
        let mut scratch = Vec::new();
        let mut pct = |p: f64| -> f64 {
            if g.latencies_s.is_empty() {
                0.0
            } else {
                ops::percentile_with(&g.latencies_s, p, &mut scratch) as f64
            }
        };
        let (lat_p50_s, lat_p95_s, lat_p99_s) = (pct(50.0), pct(95.0), pct(99.0));
        let n = g.latencies_s.len();
        let sum: f64 = g.latencies_s.iter().map(|&v| v as f64).sum();
        let lat_mean_s = if n == 0 { 0.0 } else { sum / n as f64 };
        let lat_min_s = g.latencies_s.iter().cloned().fold(f64::INFINITY, |a, v| a.min(v as f64));
        let lat_max_s = g.latencies_s.iter().cloned().fold(0.0f64, |a, v| a.max(v as f64));
        let batches = g.batch_real.len() as u64;
        let real_total: u64 = g.batch_real.iter().map(|&b| b as u64).sum();
        let batch_mean = if batches == 0 { 0.0 } else { real_total as f64 / batches as f64 };
        let batch_max = g.batch_real.iter().cloned().max().unwrap_or(0) as u64;
        let depth_n = g.depth_samples.len();
        let depth_sum: u64 = g.depth_samples.iter().map(|&d| d as u64).sum();
        let depth_mean = if depth_n == 0 { 0.0 } else { depth_sum as f64 / depth_n as f64 };
        let depth_max = g.depth_samples.iter().cloned().max().unwrap_or(0) as u64;
        let mut worker_batches = g.worker_batches.clone();
        if worker_batches.len() < workers {
            worker_batches.resize(workers, 0);
        }
        ServeReport {
            backend: backend.to_string(),
            model: model.to_string(),
            max_batch,
            queue_depth,
            workers,
            submitted: g.submitted,
            completed: n as u64,
            rejected: g.rejected,
            rejected_final: g.rejected_final,
            expired: g.expired,
            errors: g.errors,
            restarts: g.restarts,
            batches,
            worker_batches,
            padded_rows: g.padded_rows,
            resident_depth: g.resident_depth_max,
            depth_served_partial: g.partial_rows,
            batch_mean,
            batch_max,
            depth_mean,
            depth_max,
            lat_p50_s,
            lat_p95_s,
            lat_p99_s,
            lat_mean_s,
            lat_min_s: if n == 0 { 0.0 } else { lat_min_s },
            lat_max_s,
            wall_s,
            throughput_rps: if wall_s > 0.0 { n as f64 / wall_s } else { 0.0 },
            latencies_s: g.latencies_s.clone(),
            timeline: g.timeline.report(),
        }
    }
}

/// A finished serving run, summarized.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub backend: String,
    pub model: String,
    pub max_batch: usize,
    pub queue_depth: usize,
    /// Fleet size (supervised workers off the one queue).
    pub workers: usize,
    /// Requests that entered the system.
    pub submitted: u64,
    /// Requests that received an answer (terminal `Answer`).
    pub completed: u64,
    /// Admission-control rejection *events* (each may have been retried).
    pub rejected: u64,
    /// Requests whose terminal state is a rejection (queue closed).
    pub rejected_final: u64,
    /// Requests shed past their deadline (terminal `Expired`).
    pub expired: u64,
    /// Requests answered with an error (terminal `Failed`).
    pub errors: u64,
    /// Supervised worker restarts (panic recoveries).
    pub restarts: u64,
    /// Batches executed, fleet-wide.
    pub batches: u64,
    /// Batches executed per worker (index = worker id).
    pub worker_batches: Vec<u64>,
    /// Zero pad rows executed across all batches.
    pub padded_rows: u64,
    /// Deepest resident layer prefix observed (0 = non-progressive run;
    /// equals the model's full depth once a progressive run converges).
    pub resident_depth: u64,
    /// Rows answered at less than full depth (0 = non-progressive run).
    pub depth_served_partial: u64,
    pub batch_mean: f64,
    pub batch_max: u64,
    pub depth_mean: f64,
    pub depth_max: u64,
    pub lat_p50_s: f64,
    pub lat_p95_s: f64,
    pub lat_p99_s: f64,
    pub lat_mean_s: f64,
    pub lat_min_s: f64,
    pub lat_max_s: f64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// Raw per-request latencies (seconds) for downstream stats.
    pub latencies_s: Vec<f32>,
    /// Per-second telemetry buckets; serialized separately as
    /// `serve.timeline.json` (never into `to_json` — the `serve.json`
    /// key set is frozen by the golden-key test below).
    pub timeline: TimelineReport,
}

impl ServeReport {
    /// The zero-lost-requests invariant: every submitted request reached
    /// exactly one terminal state.
    pub fn accounting_balanced(&self) -> bool {
        self.submitted
            == self.completed + self.rejected_final + self.expired + self.errors
    }

    /// JSON object in the same hand-rolled style as
    /// [`crate::bench_harness::write_json`]; round-trips through
    /// [`crate::util::json::parse`]. Pre-fleet keys are kept stable
    /// (CI's smoke asserts read them); fleet-era keys are additive.
    pub fn to_json(&self) -> String {
        let worker_batches = self
            .worker_batches
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            concat!(
                "{{\n",
                "  \"serve\": {{\n",
                "    \"backend\": \"{}\",\n",
                "    \"model\": \"{}\",\n",
                "    \"max_batch\": {},\n",
                "    \"queue_depth\": {},\n",
                "    \"workers\": {},\n",
                "    \"submitted\": {},\n",
                "    \"completed\": {},\n",
                "    \"rejected\": {},\n",
                "    \"rejected_final\": {},\n",
                "    \"expired\": {},\n",
                "    \"errors\": {},\n",
                "    \"restarts\": {},\n",
                "    \"accounting_balanced\": {},\n",
                "    \"batches\": {},\n",
                "    \"worker_batches\": [{}],\n",
                "    \"padded_rows\": {},\n",
                "    \"resident_depth\": {},\n",
                "    \"depth_served_partial\": {},\n",
                "    \"batch_size_mean\": {:e},\n",
                "    \"batch_size_max\": {},\n",
                "    \"queue_depth_mean\": {:e},\n",
                "    \"queue_depth_max\": {},\n",
                "    \"latency_s\": {{\"p50\": {:e}, \"p95\": {:e}, \"p99\": {:e}, ",
                "\"mean\": {:e}, \"min\": {:e}, \"max\": {:e}}},\n",
                "    \"wall_s\": {:e},\n",
                "    \"throughput_rps\": {:e}\n",
                "  }}\n",
                "}}"
            ),
            self.backend,
            self.model,
            self.max_batch,
            self.queue_depth,
            self.workers,
            self.submitted,
            self.completed,
            self.rejected,
            self.rejected_final,
            self.expired,
            self.errors,
            self.restarts,
            self.accounting_balanced(),
            self.batches,
            worker_batches,
            self.padded_rows,
            self.resident_depth,
            self.depth_served_partial,
            self.batch_mean,
            self.batch_max,
            self.depth_mean,
            self.depth_max,
            self.lat_p50_s,
            self.lat_p95_s,
            self.lat_p99_s,
            self.lat_mean_s,
            self.lat_min_s,
            self.lat_max_s,
            self.wall_s,
            self.throughput_rps,
        )
    }

    /// Human-readable summary table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Serve — {} on {} ({} worker{}, batch ≤{}, queue {})",
                self.model,
                self.backend,
                self.workers,
                if self.workers == 1 { "" } else { "s" },
                self.max_batch,
                self.queue_depth
            ),
            &["Metric", "Value"],
        );
        let rows: Vec<(&str, String)> = vec![
            ("submitted", self.submitted.to_string()),
            ("completed", self.completed.to_string()),
            ("rejected (admission events)", self.rejected.to_string()),
            ("rejected (terminal)", self.rejected_final.to_string()),
            ("expired (deadline shed)", self.expired.to_string()),
            ("errors", self.errors.to_string()),
            (
                "accounting",
                if self.accounting_balanced() {
                    "balanced".into()
                } else {
                    format!(
                        "UNBALANCED ({} submitted vs {} terminal)",
                        self.submitted,
                        self.completed + self.rejected_final + self.expired + self.errors
                    )
                },
            ),
            ("worker restarts", self.restarts.to_string()),
            ("batches", self.batches.to_string()),
            (
                "batches per worker",
                format!("{:?}", self.worker_batches),
            ),
            ("padded rows", self.padded_rows.to_string()),
            (
                "resident depth (progressive)",
                if self.resident_depth == 0 {
                    "n/a".into()
                } else {
                    format!(
                        "{} ({} partial-depth rows)",
                        self.resident_depth, self.depth_served_partial
                    )
                },
            ),
            (
                "batch size mean/max",
                format!("{:.2} / {}", self.batch_mean, self.batch_max),
            ),
            (
                "queue depth mean/max",
                format!("{:.2} / {}", self.depth_mean, self.depth_max),
            ),
            ("latency p50", fmt_dur(self.lat_p50_s)),
            ("latency p95", fmt_dur(self.lat_p95_s)),
            ("latency p99", fmt_dur(self.lat_p99_s)),
            ("latency mean", fmt_dur(self.lat_mean_s)),
            ("wall", format!("{:.3}s", self.wall_s)),
            (
                "throughput",
                format!("{:.1} req/s", self.throughput_rps),
            ),
        ];
        for (k, v) in rows {
            t.row(vec![k.to_string(), v]);
        }
        t
    }

    /// The latency distribution as a [`Stats`] row, so serve latency
    /// lands in the `BENCH_host.json` baseline alongside the kernels.
    pub fn latency_stats(&self, name: &str) -> Stats {
        let samples: Vec<f64> = self.latencies_s.iter().map(|&v| v as f64).collect();
        crate::bench_harness::stats_from_samples(name, &samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> ServeMetrics {
        let m = ServeMetrics::new();
        for _ in 0..103 {
            m.record_submitted();
        }
        for i in 0..100u32 {
            m.record_latency(Duration::from_micros(100 + i as u64));
        }
        m.record_batch(0, 16, 0);
        m.record_batch(1, 4, 12);
        m.record_depth(3);
        m.record_depth(9);
        m.record_rejected();
        m.record_rejected_final();
        m.record_expired();
        m.record_error();
        m.record_restart();
        m.record_resident_depth(2);
        m.record_resident_depth(3);
        m.record_partial_rows(5);
        m
    }

    #[test]
    fn percentiles_ordered_and_counts_roll_up() {
        let r = filled().report("host", "synthnet", 16, 64, 2, 0.5);
        assert_eq!(r.submitted, 103);
        assert_eq!(r.completed, 100);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.rejected_final, 1);
        assert_eq!(r.expired, 1);
        assert_eq!(r.errors, 1);
        assert_eq!(r.restarts, 1);
        assert_eq!(r.batches, 2);
        assert_eq!(r.worker_batches, vec![1, 1]);
        assert_eq!(r.padded_rows, 12);
        assert_eq!(r.resident_depth, 3, "resident depth is a monotone max");
        assert_eq!(r.depth_served_partial, 5);
        assert_eq!(r.batch_max, 16);
        assert!((r.batch_mean - 10.0).abs() < 1e-9);
        assert_eq!(r.depth_max, 9);
        assert!(r.lat_p50_s <= r.lat_p95_s && r.lat_p95_s <= r.lat_p99_s);
        assert!(r.lat_min_s > 0.0 && r.lat_max_s >= r.lat_p99_s);
        assert!((r.throughput_rps - 200.0).abs() < 1e-6);
        // 103 submitted = 100 answered + 1 rejected + 1 expired + 1 error
        assert!(r.accounting_balanced());
    }

    #[test]
    fn accounting_detects_lost_requests() {
        let m = ServeMetrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_latency(Duration::from_micros(5)); // only 1 of 2 terminal
        let r = m.report("host", "m", 8, 8, 1, 0.1);
        assert!(!r.accounting_balanced());
        assert!(r.to_json().contains("\"accounting_balanced\": false"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let r = filled().report("host", "synthnet", 16, 64, 2, 0.5);
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        let s = j.get("serve").unwrap();
        assert_eq!(s.get("completed").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(s.get("submitted").unwrap().as_f64().unwrap(), 103.0);
        assert_eq!(s.get("workers").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(s.get("restarts").unwrap().as_f64().unwrap(), 1.0);
        assert!(s.get("accounting_balanced").unwrap().as_bool().unwrap());
        let wb = s.get("worker_batches").unwrap().as_arr().unwrap();
        assert_eq!(wb.len(), 2);
        assert!(s.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        let lat = s.get("latency_s").unwrap();
        assert!(lat.get("p99").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn empty_run_reports_zeros() {
        let r = ServeMetrics::new().report("host", "m", 8, 8, 1, 0.0);
        assert_eq!(r.completed, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.lat_p50_s, 0.0);
        assert_eq!(r.lat_min_s, 0.0);
        assert!(r.accounting_balanced(), "0 == 0 balances");
        // worker_batches padded to the fleet size even with no batches
        assert_eq!(r.worker_batches, vec![0]);
        // JSON stays parseable with zero samples
        assert!(crate::util::json::parse(&r.to_json()).is_ok());
    }

    /// Golden-key schema test: the exact top-level key set of
    /// `serve.json`'s `"serve"` object. CI smoke jobs grep these keys;
    /// additions/removals must update this list *and* those greps
    /// deliberately.
    #[test]
    fn serve_json_golden_keys() {
        let r = filled().report("host", "synthnet", 16, 64, 2, 0.5);
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        let top: Vec<&str> = match &j {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(top, vec!["serve"]);
        let keys: Vec<&str> = match j.get("serve").unwrap() {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(
            keys,
            vec![
                "accounting_balanced",
                "backend",
                "batch_size_max",
                "batch_size_mean",
                "batches",
                "completed",
                "depth_served_partial",
                "errors",
                "expired",
                "latency_s",
                "max_batch",
                "model",
                "padded_rows",
                "queue_depth",
                "queue_depth_max",
                "queue_depth_mean",
                "rejected",
                "rejected_final",
                "resident_depth",
                "restarts",
                "submitted",
                "throughput_rps",
                "wall_s",
                "workers",
            ]
        );
        let lat_keys: Vec<&str> = match j.get("serve").unwrap().get("latency_s").unwrap() {
            crate::util::json::Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(lat_keys, vec!["max", "mean", "min", "p50", "p95", "p99"]);
    }

    /// The timeline rides the same recording sites, so its bucket totals
    /// must agree with the report's counters exactly.
    #[test]
    fn timeline_totals_match_report_counters() {
        let r = filled().report("host", "synthnet", 16, 64, 2, 0.5);
        assert_eq!(r.timeline.submitted_total(), r.submitted);
        assert_eq!(
            r.timeline.terminal_total(),
            r.completed + r.rejected_final + r.expired + r.errors
        );
        assert!(r.timeline.accounting_balanced());
        assert!(
            crate::util::json::parse(&r.timeline.to_json()).is_ok(),
            "timeline JSON stays parseable"
        );
    }

    #[test]
    fn latency_stats_bridge() {
        let r = filled().report("host", "m", 8, 8, 1, 1.0);
        let s = r.latency_stats("host/serve_latency");
        assert_eq!(s.iters, 100);
        assert!(s.mean_s > 0.0 && s.min_s <= s.median_s && s.median_s <= s.max_s);
    }
}
