//! Budgeted STE-QAT comparator (Table 3).
//!
//! The paper compares its 1,024-sample / ~10-minute PTQ against PACT, DSQ
//! and LSQ trained on the full 1.2M-image ImageNet for 100+ GPU-hours. We
//! substitute a straight-through-estimator QAT (dynamic max-abs fake-quant
//! on weights and activations, SGD-momentum) trained on the full synthetic
//! train split for a bounded step budget — the cost/accuracy trade-off the
//! table demonstrates survives the substitution (DESIGN.md §2).
//!
//! The step itself is a [`crate::backend::Backend::qat_step`]: the AOT
//! fwd+bwd executable on PJRT, a native backprop on the host backend.

use std::time::Instant;

use crate::backend::{Backend, QatState};
use crate::coordinator::evaluate::evaluate;
use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::Manifest;
use crate::quant::rounding::nearest;
use crate::quant::scale::absmax_scale;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;

#[derive(Debug)]
pub struct QatOutcome {
    pub acc: f64,
    pub fp_acc: f64,
    pub steps: usize,
    pub train_samples_seen: usize,
    pub final_loss: f32,
    pub wall_s: f64,
}

/// Run STE-QAT for `steps` SGD steps at (wbits, abits), then nearest-
/// quantize the trained weights and evaluate.
#[allow(clippy::too_many_arguments)]
pub fn run_qat(
    backend: &dyn Backend,
    manifest: &Manifest,
    model_name: &str,
    wbits: u8,
    abits: u8,
    steps: usize,
    lr: f32,
    train: &Split,
    eval: &Split,
    seed: u64,
) -> Result<QatOutcome> {
    let t0 = Instant::now();
    let model = backend.load_model(manifest, model_name)?;
    let k = model.num_layers();
    let batch = manifest.dataset.qat_batch;
    let mut rng = Rng::new(seed);
    let mut state = QatState::from_model(&model);
    let mut final_loss = f32::NAN;

    backend.metrics().time("qat.train", || -> Result<()> {
        for step in 0..steps {
            // cosine LR decay
            let lr_t =
                lr * 0.5 * (1.0 + (std::f32::consts::PI * step as f32 / steps as f32).cos());
            let (x, y) = train.sample(&mut rng, batch)?;
            final_loss = backend.qat_step(&model, &mut state, &x, &y, lr_t, wbits, abits)?;
            if step % 50 == 0 {
                log::debug!("qat {model_name} step {step} loss {final_loss:.4}");
            }
        }
        Ok(())
    })?;

    // Deploy-time quantization of the QAT weights: nearest on the dynamic
    // max-abs grid the STE trained against (first/last pinned to 8-bit).
    let mut qws = Vec::with_capacity(k);
    for (i, w) in state.ws.iter().enumerate() {
        let b = if i == 0 || i == k - 1 { 8 } else { wbits };
        let grid = QGrid::signed(b, absmax_scale(w.data(), b))?;
        qws.push(Tensor::new(w.shape().to_vec(), nearest(w.data(), &grid))?);
    }
    let eval_model = LoadedModel {
        info: model.info.clone(),
        weights: qws.clone(),
        biases: state.bs,
    };
    let acc = evaluate(backend, manifest, &eval_model, &qws, eval)?;

    Ok(QatOutcome {
        acc,
        fp_acc: model.info.fp_acc,
        steps,
        train_samples_seen: steps * batch,
        final_loss,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}
