//! Budgeted STE-QAT comparator (Table 3).
//!
//! The paper compares its 1,024-sample / ~10-minute PTQ against PACT, DSQ
//! and LSQ trained on the full 1.2M-image ImageNet for 100+ GPU-hours. We
//! substitute a straight-through-estimator QAT (dynamic max-abs fake-quant
//! on weights and activations, SGD-momentum) trained on the full synthetic
//! train split for a bounded step budget — the cost/accuracy trade-off the
//! table demonstrates survives the substitution (DESIGN.md §2).

use std::time::Instant;

use crate::coordinator::evaluate::evaluate;
use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::Manifest;
use crate::quant::rounding::nearest;
use crate::quant::scale::absmax_scale;
use crate::quant::QGrid;
use crate::runtime::{convert::literal_scalar, literal_to_tensor, Runtime};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

#[derive(Debug)]
pub struct QatOutcome {
    pub acc: f64,
    pub fp_acc: f64,
    pub steps: usize,
    pub train_samples_seen: usize,
    pub final_loss: f32,
    pub wall_s: f64,
}

/// Run STE-QAT for `steps` SGD steps at (wbits, abits), then nearest-
/// quantize the trained weights and evaluate.
#[allow(clippy::too_many_arguments)]
pub fn run_qat(
    rt: &Runtime,
    manifest: &Manifest,
    model_name: &str,
    wbits: u8,
    abits: u8,
    steps: usize,
    lr: f32,
    train: &Split,
    eval: &Split,
    seed: u64,
) -> Result<QatOutcome> {
    let t0 = Instant::now();
    let model = LoadedModel::load(manifest, model_name)?;
    let qat_path = model.info.qat_step.clone().ok_or_else(|| {
        Error::config(format!("{model_name} has no qat_step artifact"))
    })?;
    let exe = rt.load(&qat_path)?;
    let k = model.num_layers();
    let batch = manifest.dataset.qat_batch;
    let mut rng = Rng::new(seed);

    let mut ws = model.weights.clone();
    let mut bs = model.biases.clone();
    let mut mws: Vec<Tensor> = ws.iter().map(|w| Tensor::zeros(w.shape().to_vec())).collect();
    let mut mbs: Vec<Tensor> = bs.iter().map(|b| Tensor::zeros(b.shape().to_vec())).collect();

    let whi = rt.upload_scalar(((1i64 << (wbits - 1)) - 1) as f32)?;
    let ahi = rt.upload_scalar(((1i64 << abits) - 1) as f32)?;
    let mut final_loss = f32::NAN;

    rt.metrics.time("qat.train", || -> Result<()> {
        for step in 0..steps {
            // cosine LR decay
            let lr_t =
                lr * 0.5 * (1.0 + (std::f32::consts::PI * step as f32 / steps as f32).cos());
            let (x, y) = train.sample(&mut rng, batch)?;
            let xbuf = rt.upload(&x)?;
            let ybuf = rt.upload_i32(&y, &[batch])?;
            let lrbuf = rt.upload_scalar(lr_t)?;
            let mut bufs = Vec::with_capacity(4 * k);
            for t in ws.iter().chain(bs.iter()).chain(mws.iter()).chain(mbs.iter()) {
                bufs.push(rt.upload(t)?);
            }
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * k + 5);
            args.push(&xbuf);
            args.push(&ybuf);
            args.extend(bufs.iter());
            args.push(&lrbuf);
            args.push(&whi);
            args.push(&ahi);
            let outs = exe.run_b(&args)?;
            if outs.len() != 4 * k + 1 {
                return Err(Error::runtime(format!(
                    "qat_step returned {} outputs, expected {}",
                    outs.len(),
                    4 * k + 1
                )));
            }
            for i in 0..k {
                ws[i] = literal_to_tensor(&outs[i])?;
                bs[i] = literal_to_tensor(&outs[k + i])?;
                mws[i] = literal_to_tensor(&outs[2 * k + i])?;
                mbs[i] = literal_to_tensor(&outs[3 * k + i])?;
            }
            final_loss = literal_scalar(&outs[4 * k])?;
            rt.metrics.incr("qat.steps", 1);
            if step % 50 == 0 {
                log::debug!("qat {model_name} step {step} loss {final_loss:.4}");
            }
        }
        Ok(())
    })?;

    // Deploy-time quantization of the QAT weights: nearest on the dynamic
    // max-abs grid the STE trained against (first/last pinned to 8-bit).
    let mut qws = Vec::with_capacity(k);
    for (i, w) in ws.iter().enumerate() {
        let b = if i == 0 || i == k - 1 { 8 } else { wbits };
        let grid = QGrid::signed(b, absmax_scale(w.data(), b))?;
        qws.push(Tensor::new(w.shape().to_vec(), nearest(w.data(), &grid))?);
    }
    let eval_model = LoadedModel {
        info: model.info.clone(),
        weights: qws.clone(),
        biases: bs,
    };
    let acc = evaluate(rt, manifest, &eval_model, &qws, eval)?;

    Ok(QatOutcome {
        acc,
        fp_acc: model.info.fp_acc,
        steps,
        train_samples_seen: steps * batch,
        final_loss,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}
