//! The end-to-end PTQ pipeline (DESIGN.md §5): capture → scale → per-layer
//! calibration → finalize → (activation observers) → evaluate.
//!
//! Execution is backend-neutral: everything device-shaped goes through
//! [`crate::backend::Backend`] (PJRT artifacts or the pure-host
//! executor). Host-side hot paths — MSE scale search, rounding kernels,
//! observers, bit allocation (`mixed::allocate`) — all run on the one
//! process-wide [`threadpool::global`] pool (`AR_THREADS` sizes it),
//! threaded through explicitly here so calibration, allocation, and
//! evaluation share workers instead of each creating their own.
//!
//! When several pipeline runs execute concurrently (experiment table
//! cells via `Ctx::run_many`, the serve worker next to live traffic),
//! the caller wraps each run in
//! [`crate::util::threadpool::with_width_cap`]; every pool fan-out in
//! here respects that thread-local cap, so N concurrent runs split one
//! pool's width instead of each claiming all of it.

use crate::backend::Backend;
use crate::coordinator::calibrate::{calibrate_adaround, calibrate_attention};
use crate::coordinator::capture::{capture, reference_outputs, ActCache};
use crate::coordinator::config::CalibConfig;
use crate::coordinator::evaluate::{evaluate, evaluate_actq};
use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::Manifest;
use crate::quant::observer::{observe_with, ActQuantParams};
use crate::quant::rounding::{self, Rounding};
use crate::quant::scale::mse_optimal_scale_with;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::trace::{self, Category};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// What to quantize and how wide.
#[derive(Debug, Clone)]
pub struct QuantSpec {
    pub model: String,
    /// Per-layer weight bits (use [`resolve_uniform_bits`] for the single-
    /// precision setting; `mixed::allocate` for Algorithm 1).
    pub wbits: Vec<u8>,
    /// Activation bits (None = FP32 activations, the "W/32" rows).
    pub abits: Option<u8>,
}

/// Uniform `bits` everywhere except the pinned (first/last) 8-bit layers —
/// the paper's single-precision setting (§4.1).
pub fn resolve_uniform_bits(model: &LoadedModel, bits: u8) -> Vec<u8> {
    model
        .info
        .layers
        .iter()
        .map(|l| if l.pinned_8bit { 8 } else { bits })
        .collect()
}

/// Per-layer activation bits under the same pinning rule.
pub fn resolve_act_bits(model: &LoadedModel, abits: u8) -> Vec<u8> {
    model
        .info
        .layers
        .iter()
        .map(|l| if l.pinned_8bit { 8 } else { abits })
        .collect()
}

#[derive(Debug, Clone)]
pub struct LayerOutcome {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub first_loss: f32,
    pub last_loss: f32,
}

#[derive(Debug)]
pub struct Outcome {
    pub model: String,
    pub method: Rounding,
    pub acc: f64,
    pub fp_acc: f64,
    pub per_layer: Vec<LayerOutcome>,
    pub qweights: Vec<Tensor>,
    pub act_params: Option<Vec<ActQuantParams>>,
    /// Per-layer activation bit widths matching `act_params` (the
    /// pinning rule applied to `spec.abits`) — recorded so a saved
    /// artifact carries its full actq deployment config.
    pub act_bits: Option<Vec<u8>>,
    pub wall_s: f64,
}

/// Quantize a model per `spec`/`cfg` and evaluate top-1 on `eval`.
pub fn quantize_and_eval(
    backend: &dyn Backend,
    manifest: &Manifest,
    spec: &QuantSpec,
    cfg: &CalibConfig,
    calib: &Split,
    eval: &Split,
) -> Result<Outcome> {
    // one clock for every timing number (satellite of the trace PR):
    // wall_s comes off the tracer epoch, same source as every span
    let t0_us = trace::clock_us();
    let _run_span = trace::span(Category::Pipeline, format!("quantize:{}", spec.model));
    let model = backend.load_model(manifest, &spec.model)?;
    let k = model.num_layers();
    assert_eq!(spec.wbits.len(), k, "wbits arity");
    let mut rng = Rng::new(cfg.seed);
    let scan_k = manifest.scan_k.max(1);
    let cb = manifest.dataset.calib_batch;
    // One shared pool + one observer scratch buffer for the whole run.
    let pool = threadpool::global();
    let mut obs_scratch: Vec<f32> = Vec::new();

    let needs_capture = spec.abits.is_some()
        || matches!(cfg.method, Rounding::Attention | Rounding::AdaRound);
    let mut cache: Option<ActCache> = if needs_capture {
        let _span = trace::span(Category::Pipeline, "capture");
        Some(capture(
            backend,
            manifest,
            &model,
            &model.weights,
            calib,
            cfg.calib_samples,
        )?)
    } else {
        None
    };

    let mut qweights: Vec<Tensor> = Vec::with_capacity(k);
    let mut per_layer: Vec<LayerOutcome> = Vec::with_capacity(k);
    let mut act_params: Vec<ActQuantParams> = Vec::with_capacity(k);
    let act_bits = spec.abits.map(|b| resolve_act_bits(&model, b));

    for li in 0..k {
        let layer = &model.info.layers[li];
        let w_fp = &model.weights[li];
        let bits = spec.wbits[li];
        let _layer_span =
            trace::span(Category::Calib, format!("layer:{}:{bits}b", layer.name));

        // Optional quantized-prefix re-capture (config flag).
        if let (Some(c), true) = (&cache, cfg.recapture_every > 0) {
            if li > 0 && li % cfg.recapture_every == 0 && c.len() > li {
                let mut mixed: Vec<Tensor> = qweights.clone();
                mixed.extend_from_slice(&model.weights[li..]);
                cache = Some(capture(
                    backend,
                    manifest,
                    &model,
                    &mixed,
                    calib,
                    cfg.calib_samples,
                )?);
            }
        }

        let xcache = match &mut cache {
            Some(c) => Some(c.take(li)?),
            None => None,
        };

        // Activation observer on this layer's captured inputs.
        if let (Some(bits_a), Some(x)) = (&act_bits, &xcache) {
            act_params.push(observe_with(
                x.data(),
                bits_a[li],
                cfg.observer,
                &mut obs_scratch,
            )?);
        }

        let (qw, outcome) = match cfg.method {
            Rounding::Attention | Rounding::AdaRound => {
                let x = xcache.expect("capture ran for trained methods");
                let yref = backend.metrics().time("pipeline.reference_outputs", || {
                    reference_outputs(backend, layer, &x, w_fp, cb)
                })?;
                let cal = {
                    let _span = trace::span(Category::Calib, "calibrate");
                    if cfg.method == Rounding::Attention {
                        calibrate_attention(
                            backend, layer, w_fp, &x, &yref, bits, cfg, scan_k, cb, &mut rng,
                        )?
                    } else {
                        calibrate_adaround(
                            backend, layer, w_fp, &x, &yref, bits, cfg, scan_k, cb, &mut rng,
                        )?
                    }
                };
                log::debug!(
                    "{}/{}: {}b loss {:.3e} -> {:.3e}",
                    spec.model,
                    layer.name,
                    bits,
                    cal.first_loss,
                    cal.last_loss
                );
                (
                    cal.qweight,
                    LayerOutcome {
                        name: layer.name.clone(),
                        bits,
                        scale: cal.grid.scale,
                        first_loss: cal.first_loss,
                        last_loss: cal.last_loss,
                    },
                )
            }
            method => {
                let scale = {
                    let _span = trace::span(Category::Calib, "scale-search");
                    mse_optimal_scale_with(pool, w_fp.data(), bits)?
                };
                let grid = QGrid::signed(bits, scale)?;
                // The only allocation is the output buffer the Tensor
                // keeps; the kernels write into it in parallel chunks.
                let mut qdata = vec![0.0f32; w_fp.len()];
                match method {
                    Rounding::Nearest => {
                        rounding::nearest_into(pool, w_fp.data(), &grid, &mut qdata)
                    }
                    Rounding::Floor => {
                        rounding::floor_into(pool, w_fp.data(), &grid, &mut qdata)
                    }
                    Rounding::Ceil => {
                        rounding::ceil_into(pool, w_fp.data(), &grid, &mut qdata)
                    }
                    Rounding::Stochastic => rounding::stochastic_into(
                        pool,
                        w_fp.data(),
                        &grid,
                        rng.next_u64(),
                        &mut qdata,
                    ),
                    _ => unreachable!(),
                };
                (
                    Tensor::new(w_fp.shape().to_vec(), qdata)?,
                    LayerOutcome {
                        name: layer.name.clone(),
                        bits,
                        scale,
                        first_loss: f32::NAN,
                        last_loss: f32::NAN,
                    },
                )
            }
        };
        qweights.push(qw);
        per_layer.push(outcome);
    }

    let acc = {
        let _span = trace::span(Category::Pipeline, "evaluate");
        match (&act_bits, spec.abits) {
            (Some(bits_a), Some(_)) => evaluate_actq(
                backend, manifest, &model, &qweights, &act_params, bits_a, eval,
            )?,
            _ => evaluate(backend, manifest, &model, &qweights, eval)?,
        }
    };

    Ok(Outcome {
        model: spec.model.clone(),
        method: cfg.method,
        acc,
        fp_acc: model.info.fp_acc,
        per_layer,
        qweights,
        act_params: spec.abits.map(|_| act_params),
        act_bits,
        wall_s: (trace::clock_us().saturating_sub(t0_us)) as f64 / 1e6,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::manifest::LayerInfo;

    fn layer(pinned: bool) -> LayerInfo {
        LayerInfo::synthetic(0, 1, 1, pinned)
    }

    #[test]
    fn uniform_bits_pin_first_last() {
        use crate::io::manifest::ModelInfo;
        let info = ModelInfo {
            name: "m".into(),
            fp_acc: 0.9,
            layers: vec![layer(true), layer(false), layer(true)],
            w_files: vec![],
            b_files: vec![],
            forward: String::new(),
            forward_actq: String::new(),
            collect: String::new(),
            qat_step: None,
        };
        let model = LoadedModel {
            info,
            weights: vec![],
            biases: vec![],
        };
        assert_eq!(resolve_uniform_bits(&model, 4), vec![8, 4, 8]);
        assert_eq!(resolve_act_bits(&model, 3), vec![8, 3, 8]);
    }
}
