//! Batched top-1 evaluation through a backend's model forward path.

use crate::backend::{Backend, PreparedModel};
use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::deploy::artifact::PackedModel;
use crate::io::manifest::Manifest;
use crate::quant::observer::ActQuantParams;
use crate::tensor::{ops, Tensor};
use crate::util::error::{Error, Result};

/// Evaluate top-1 accuracy with the given weights (FP or fake-quantized),
/// activations in FP32.
pub fn evaluate(
    backend: &dyn Backend,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    eval: &Split,
) -> Result<f64> {
    let prepared = backend.prepare(model, weights)?;
    let batch = manifest.dataset.eval_batch;
    run_eval(backend, &model.info.name, eval, batch, |x| {
        prepared.forward(x)
    })
}

/// Evaluate with per-layer activation fake-quant (Tables 2/3/5).
pub fn evaluate_actq(
    backend: &dyn Backend,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    act_params: &[ActQuantParams],
    act_bits: &[u8],
    eval: &Split,
) -> Result<f64> {
    let k = model.num_layers();
    if act_params.len() != k || act_bits.len() != k {
        return Err(Error::shape(format!(
            "expected {k} activation params/bits, got {}/{}",
            act_params.len(),
            act_bits.len()
        )));
    }
    let prepared = backend.prepare(model, weights)?;
    let batch = manifest.dataset.eval_batch;
    run_eval(backend, &model.info.name, eval, batch, |x| {
        prepared.forward_actq(x, act_params, act_bits)
    })
}

/// Score a **packed quantized artifact** directly: top-1 through the
/// backend's artifact staging path ([`Backend::prepare_artifact`] — the
/// streaming dequant-on-the-fly `PackedHostForward` on the host
/// backend), with the artifact's own activation deployment config
/// ([`PackedModel::deployment_actq`]) when it carries one. This is what
/// `repro evaluate --artifact <dir>` runs — the same handle the serve
/// path drives, so the score measures exactly what deployment serves.
pub fn evaluate_artifact(
    backend: &dyn Backend,
    manifest: &Manifest,
    artifact: &PackedModel,
    eval: &Split,
) -> Result<f64> {
    let model = backend.load_model(manifest, &artifact.model)?;
    artifact.check_matches(&model)?;
    let actq = artifact.deployment_actq()?;
    let mut staged = Vec::new();
    let prepared = backend.prepare_artifact(&model, artifact, &mut staged)?;
    let batch = manifest.dataset.eval_batch;
    run_eval(backend, &model.info.name, eval, batch, |x| match &actq {
        Some((params, bits)) => prepared.forward_actq(x, params, bits),
        None => prepared.forward(x),
    })
}

fn run_eval(
    backend: &dyn Backend,
    name: &str,
    eval: &Split,
    batch: usize,
    mut fwd: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<f64> {
    let nb = eval.num_batches(batch);
    if nb == 0 {
        return Err(Error::config(format!(
            "{name}: eval split smaller than one batch ({batch})"
        )));
    }
    let mut correct = 0.0f64;
    let mut total = 0usize;
    backend.metrics().time("pipeline.evaluate", || -> Result<()> {
        for bi in 0..nb {
            let (x, y) = eval.batch(bi * batch, batch)?;
            let logits = fwd(&x)?;
            correct += ops::top1_accuracy(&logits, y) * y.len() as f64;
            total += y.len();
            backend.metrics().incr("pipeline.eval_images", y.len() as u64);
        }
        Ok(())
    })?;
    Ok(correct / total as f64)
}
