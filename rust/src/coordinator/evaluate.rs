//! Batched top-1 evaluation through the model forward executables.

use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::Manifest;
use crate::quant::observer::ActQuantParams;
use crate::runtime::{literal_to_tensor, Runtime};
use crate::tensor::{ops, Tensor};
use crate::util::error::{Error, Result};

/// Evaluate top-1 accuracy with the given weights (FP or fake-quantized),
/// activations in FP32.
pub fn evaluate(
    rt: &Runtime,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    eval: &Split,
) -> Result<f64> {
    let exe = rt.load(&model.info.forward)?;
    let batch = manifest.dataset.eval_batch;
    let wbufs = rt.upload_all(weights)?;
    let bbufs = rt.upload_all(&model.biases)?;
    run_eval(rt, &model.info.name, eval, batch, |xbuf| {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + wbufs.len() * 2);
        args.push(xbuf);
        args.extend(wbufs.iter());
        args.extend(bbufs.iter());
        let outs = exe.run_b(&args)?;
        literal_to_tensor(&outs[0])
    })
}

/// Evaluate with per-layer activation fake-quant (Tables 2/3/5).
pub fn evaluate_actq(
    rt: &Runtime,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    act_params: &[ActQuantParams],
    act_bits: &[u8],
    eval: &Split,
) -> Result<f64> {
    let k = model.num_layers();
    if act_params.len() != k || act_bits.len() != k {
        return Err(Error::shape(format!(
            "expected {k} activation params/bits, got {}/{}",
            act_params.len(),
            act_bits.len()
        )));
    }
    let exe = rt.load(&model.info.forward_actq)?;
    let batch = manifest.dataset.eval_batch;
    let wbufs = rt.upload_all(weights)?;
    let bbufs = rt.upload_all(&model.biases)?;
    let scales = Tensor::from_vec(act_params.iter().map(|p| p.scale).collect());
    let zeros = Tensor::from_vec(act_params.iter().map(|p| p.zero).collect());
    let his = Tensor::from_vec(
        act_bits
            .iter()
            .map(|&b| ((1u32 << b) - 1) as f32)
            .collect(),
    );
    let sbuf = rt.upload(&scales)?;
    let zbuf = rt.upload(&zeros)?;
    let hbuf = rt.upload(&his)?;
    run_eval(rt, &model.info.name, eval, batch, |xbuf| {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 + wbufs.len() * 2);
        args.push(xbuf);
        args.extend(wbufs.iter());
        args.extend(bbufs.iter());
        args.push(&sbuf);
        args.push(&zbuf);
        args.push(&hbuf);
        let outs = exe.run_b(&args)?;
        literal_to_tensor(&outs[0])
    })
}

fn run_eval(
    rt: &Runtime,
    name: &str,
    eval: &Split,
    batch: usize,
    mut fwd: impl FnMut(&xla::PjRtBuffer) -> Result<Tensor>,
) -> Result<f64> {
    let nb = eval.num_batches(batch);
    if nb == 0 {
        return Err(Error::config(format!(
            "{name}: eval split smaller than one batch ({batch})"
        )));
    }
    let mut correct = 0.0f64;
    let mut total = 0usize;
    rt.metrics.time("pipeline.evaluate", || -> Result<()> {
        for bi in 0..nb {
            let (x, y) = eval.batch(bi * batch, batch)?;
            let xbuf = rt.upload(&x)?;
            let logits = fwd(&xbuf)?;
            correct += ops::top1_accuracy(&logits, y) * y.len() as f64;
            total += y.len();
            rt.metrics.incr("pipeline.eval_images", y.len() as u64);
        }
        Ok(())
    })?;
    Ok(correct / total as f64)
}
