//! The Layer-3 coordinator: everything between "a directory of AOT
//! artifacts" and "a quantized, evaluated model".
//!
//! Pipeline (DESIGN.md §5):
//!
//! ```text
//! capture ──► scale search ──► per-layer calibration ──► finalize
//!    │                              (attention / adaround /          │
//!    │                               static rounding)                ▼
//!    └────► activation observers ─────────────────────────► evaluate
//! ```
//!
//! All device-shaped work goes through [`crate::backend::Backend`], so
//! every sub-module here is execution-backend-neutral: the same code
//! drives PJRT artifacts and the pure-host executor.
//!
//! Sub-modules:
//! * [`config`]    — run configuration (quick/paper profiles, overrides).
//! * [`model`]     — loading FP checkpoints from the manifest.
//! * [`capture`]   — activation capture over the calibration set.
//! * [`calibrate`] — the per-layer Adam loops driving backend
//!   calibration sessions (Attention Round + AdaRound).
//! * [`evaluate`]  — batched top-1 evaluation (FP / weight-only / W+A).
//! * [`pipeline`]  — the end-to-end `quantize` entry point.
//! * [`qat`]       — the budgeted STE-QAT comparator (Table 3).
//! * [`experiments`] — regenerates every paper table and figure.

pub mod calibrate;
pub mod capture;
pub mod config;
pub mod evaluate;
pub mod experiments;
pub mod model;
pub mod pipeline;
pub mod qat;
pub mod state;
