//! Quantized-model state store: persist a calibration outcome to disk and
//! reload it for serving/evaluation without re-running calibration.
//!
//! Since the deploy subsystem landed this is a thin veneer over
//! [`crate::deploy::artifact`]: [`save`] emits the **v2 packed** format
//! (integer codes bit-packed at each layer's allocated width — a real
//! storage win instead of the v1 full-f32 npy-per-layer layout), and
//! [`load`] reads both v2 and legacy v1 directories, returning the
//! dequantized [`QuantizedModel`] view. Loading validates arity
//! (layers vs weight files vs activation params) and rejects
//! non-positive/non-finite scales with a typed parse error instead of
//! silently producing a model that NaNs at forward time.

use std::path::{Path, PathBuf};

use crate::coordinator::pipeline::Outcome;
use crate::deploy::artifact::PackedModel;
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::util::error::Result;

/// A reloadable quantized model (dequantized view of an artifact).
#[derive(Debug)]
pub struct QuantizedModel {
    pub model: String,
    pub method: String,
    pub acc: f64,
    pub fp_acc: f64,
    pub bits: Vec<u8>,
    pub scales: Vec<f32>,
    pub qweights: Vec<Tensor>,
    pub act_params: Option<Vec<ActQuantParams>>,
    /// Per-layer activation widths (v2 artifacts; `None` for v1 dirs,
    /// which never recorded them).
    pub act_bits: Option<Vec<u8>>,
}

/// Persist a pipeline outcome under `dir` as a v2 packed artifact.
pub fn save(outcome: &Outcome, dir: &Path) -> Result<()> {
    PackedModel::from_outcome(outcome, None)?.save(dir)
}

/// Reload a saved quantized model (v2 packed or legacy v1 f32 dirs).
pub fn load(dir: &Path) -> Result<QuantizedModel> {
    let art = PackedModel::load(dir)?;
    let qweights = art.dequantize_all()?;
    Ok(QuantizedModel {
        model: art.model.clone(),
        method: art.method.clone(),
        acc: art.acc,
        fp_acc: art.fp_acc,
        bits: art.layers.iter().map(|l| l.bits).collect(),
        scales: art.layers.iter().map(|l| l.scale).collect(),
        qweights,
        act_params: art.act_params.clone(),
        act_bits: art.act_bits.clone(),
    })
}

/// Where the CLI stores quantized models by default.
pub fn default_dir(out_root: &Path, model: &str, tag: &str) -> PathBuf {
    out_root.join("qmodels").join(format!("{model}-{tag}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::LayerOutcome;
    use crate::quant::rounding::Rounding;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ar_state_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn fake_outcome(with_acts: bool) -> Outcome {
        Outcome {
            model: "m".into(),
            method: Rounding::Attention,
            acc: 0.5,
            fp_acc: 0.9,
            per_layer: vec![
                LayerOutcome {
                    name: "stem".into(),
                    bits: 8,
                    scale: 0.01,
                    first_loss: 1.0,
                    last_loss: 0.5,
                },
                LayerOutcome {
                    name: "fc".into(),
                    bits: 4,
                    scale: 0.02,
                    first_loss: 2.0,
                    last_loss: 0.25,
                },
            ],
            qweights: vec![
                Tensor::new(vec![2, 2], vec![0.01, -0.02, 0.0, 0.03]).unwrap(),
                Tensor::new(vec![3], vec![0.02, 0.04, -0.06]).unwrap(),
            ],
            act_params: with_acts.then(|| {
                vec![
                    ActQuantParams { scale: 0.1, zero: -1.0 },
                    ActQuantParams { scale: 0.2, zero: 0.0 },
                ]
            }),
            act_bits: with_acts.then(|| vec![8, 4]),
            wall_s: 1.0,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmp("rt");
        let out = fake_outcome(true);
        save(&out, &dir).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.model, "m");
        assert_eq!(back.method, "attention");
        assert_eq!(back.bits, vec![8, 4]);
        assert_eq!(back.qweights[0], out.qweights[0]);
        assert_eq!(back.qweights[1], out.qweights[1]);
        let ap = back.act_params.unwrap();
        assert_eq!(ap[0].scale, 0.1);
        assert_eq!(ap[0].zero, -1.0);
        assert_eq!(back.act_bits, Some(vec![8, 4]));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_emits_format_version_2() {
        let dir = tmp("v2");
        save(&fake_outcome(false), &dir).unwrap();
        let hdr = std::fs::read_to_string(dir.join("qmodel.json")).unwrap();
        assert!(hdr.contains("\"format_version\": 2"), "{hdr}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_load_without_act_params() {
        let dir = tmp("na");
        save(&fake_outcome(false), &dir).unwrap();
        let back = load(&dir).unwrap();
        assert!(back.act_params.is_none());
        assert!(back.act_bits.is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(load(Path::new("/nonexistent/qmodel")).is_err());
    }

    /// A legacy v1 directory (full-f32 npy per layer, no act_bits) must
    /// still load — the migration path for pre-deploy saves.
    #[test]
    fn loads_legacy_v1_dirs() {
        let dir = tmp("v1");
        std::fs::create_dir_all(&dir).unwrap();
        let w0 = Tensor::new(vec![2, 2], vec![0.5, -0.25, 0.0, 1.0]).unwrap();
        crate::io::npy::write_f32(&dir.join("00_stem.q.npy"), &w0).unwrap();
        std::fs::write(
            dir.join("qmodel.json"),
            r#"{
              "format_version": 1,
              "model": "legacy", "method": "nearest",
              "acc": 0.4, "fp_acc": 0.8,
              "layers": [{"name": "stem", "bits": 4, "scale": 0.25}],
              "weight_files": ["00_stem.q.npy"],
              "act_params": [{"scale": 0.1, "zero": 0.0}]
            }"#,
        )
        .unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.model, "legacy");
        assert_eq!(back.bits, vec![4]);
        assert_eq!(back.qweights[0], w0);
        assert!(back.act_params.is_some());
        assert!(back.act_bits.is_none(), "v1 never recorded act widths");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The load-validation bugfix: arity mismatches and non-positive
    /// scales are typed parse errors, not a model that NaNs at forward.
    #[test]
    fn load_rejects_arity_and_scale_garbage() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let w0 = Tensor::new(vec![1], vec![0.5]).unwrap();
        crate::io::npy::write_f32(&dir.join("w.npy"), &w0).unwrap();
        // layers/weight_files arity mismatch
        std::fs::write(
            dir.join("qmodel.json"),
            r#"{"format_version": 1, "model": "m", "method": "nearest",
                "acc": 0, "fp_acc": 0,
                "layers": [{"name": "a", "bits": 4, "scale": 0.1},
                           {"name": "b", "bits": 4, "scale": 0.1}],
                "weight_files": ["w.npy"]}"#,
        )
        .unwrap();
        assert!(load(&dir).is_err());
        // act_params arity mismatch
        std::fs::write(
            dir.join("qmodel.json"),
            r#"{"format_version": 1, "model": "m", "method": "nearest",
                "acc": 0, "fp_acc": 0,
                "layers": [{"name": "a", "bits": 4, "scale": 0.1}],
                "weight_files": ["w.npy"],
                "act_params": [{"scale": 0.1, "zero": 0}, {"scale": 0.1, "zero": 0}]}"#,
        )
        .unwrap();
        assert!(load(&dir).is_err());
        // scale <= 0
        std::fs::write(
            dir.join("qmodel.json"),
            r#"{"format_version": 1, "model": "m", "method": "nearest",
                "acc": 0, "fp_acc": 0,
                "layers": [{"name": "a", "bits": 4, "scale": 0}],
                "weight_files": ["w.npy"]}"#,
        )
        .unwrap();
        let e = load(&dir).unwrap_err();
        assert!(
            matches!(e, crate::util::error::Error::Parse(_)),
            "want a typed parse error, got {e}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
