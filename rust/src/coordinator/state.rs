//! Quantized-model state store: persist a calibration outcome to disk and
//! reload it for serving/evaluation without re-running calibration.
//!
//! Format: a directory with `qmodel.json` (metadata: model, per-layer
//! bits/scales/method, activation params, accuracy) plus one `.npy` per
//! quantized weight. Everything round-trips through the in-repo JSON and
//! npy codecs, so a saved model is loadable by any future build.

use std::path::{Path, PathBuf};

use crate::coordinator::pipeline::Outcome;
use crate::io::npy;
use crate::quant::observer::ActQuantParams;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Json};

/// A reloadable quantized model.
#[derive(Debug)]
pub struct QuantizedModel {
    pub model: String,
    pub method: String,
    pub acc: f64,
    pub fp_acc: f64,
    pub bits: Vec<u8>,
    pub scales: Vec<f32>,
    pub qweights: Vec<Tensor>,
    pub act_params: Option<Vec<ActQuantParams>>,
}

/// Persist a pipeline outcome under `dir`.
pub fn save(outcome: &Outcome, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut wfiles = Vec::new();
    for (i, (q, l)) in outcome
        .qweights
        .iter()
        .zip(&outcome.per_layer)
        .enumerate()
    {
        let fname = format!("{i:02}_{}.q.npy", l.name.replace('.', "_"));
        npy::write_f32(&dir.join(&fname), q)?;
        wfiles.push(Json::str(fname));
    }
    let layers: Vec<Json> = outcome
        .per_layer
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("name", Json::str(l.name.clone())),
                ("bits", Json::num(l.bits as f64)),
                ("scale", Json::num(l.scale as f64)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("format_version", Json::num(1.0)),
        ("model", Json::str(outcome.model.clone())),
        ("method", Json::str(outcome.method.name())),
        ("acc", Json::num(outcome.acc)),
        ("fp_acc", Json::num(outcome.fp_acc)),
        ("layers", Json::arr(layers)),
        ("weight_files", Json::arr(wfiles)),
    ];
    if let Some(ap) = &outcome.act_params {
        let aps: Vec<Json> = ap
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("scale", Json::num(p.scale as f64)),
                    ("zero", Json::num(p.zero as f64)),
                ])
            })
            .collect();
        fields.push(("act_params", Json::arr(aps)));
    }
    std::fs::write(
        dir.join("qmodel.json"),
        Json::obj(fields).to_string_pretty(),
    )?;
    Ok(())
}

/// Reload a saved quantized model.
pub fn load(dir: &Path) -> Result<QuantizedModel> {
    let j = json::parse_file(&dir.join("qmodel.json"))?;
    let layers = j.get("layers")?.as_arr()?;
    let wfiles = j.get("weight_files")?.str_vec()?;
    if layers.len() != wfiles.len() {
        return Err(Error::parse("qmodel.json: layers/weights arity mismatch"));
    }
    let mut bits = Vec::new();
    let mut scales = Vec::new();
    for l in layers {
        bits.push(l.get("bits")?.as_usize()? as u8);
        scales.push(l.get("scale")?.as_f64()? as f32);
    }
    let qweights: Vec<Tensor> = wfiles
        .iter()
        .map(|f| npy::read_f32(&dir.join(f)))
        .collect::<Result<_>>()?;
    let act_params = match j.opt("act_params") {
        Some(ap) => {
            let mut out = Vec::new();
            for p in ap.as_arr()? {
                out.push(ActQuantParams {
                    scale: p.get("scale")?.as_f64()? as f32,
                    zero: p.get("zero")?.as_f64()? as f32,
                });
            }
            Some(out)
        }
        None => None,
    };
    Ok(QuantizedModel {
        model: j.get("model")?.as_str()?.to_string(),
        method: j.get("method")?.as_str()?.to_string(),
        acc: j.get("acc")?.as_f64()?,
        fp_acc: j.get("fp_acc")?.as_f64()?,
        bits,
        scales,
        qweights,
        act_params,
    })
}

/// Where the CLI stores quantized models by default.
pub fn default_dir(out_root: &Path, model: &str, tag: &str) -> PathBuf {
    out_root.join("qmodels").join(format!("{model}-{tag}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::LayerOutcome;
    use crate::quant::rounding::Rounding;

    fn fake_outcome(with_acts: bool) -> Outcome {
        Outcome {
            model: "m".into(),
            method: Rounding::Attention,
            acc: 0.5,
            fp_acc: 0.9,
            per_layer: vec![
                LayerOutcome {
                    name: "stem".into(),
                    bits: 8,
                    scale: 0.01,
                    first_loss: 1.0,
                    last_loss: 0.5,
                },
                LayerOutcome {
                    name: "fc".into(),
                    bits: 4,
                    scale: 0.02,
                    first_loss: 2.0,
                    last_loss: 0.25,
                },
            ],
            qweights: vec![
                Tensor::new(vec![2, 2], vec![0.01, -0.02, 0.0, 0.03]).unwrap(),
                Tensor::new(vec![3], vec![0.02, 0.04, -0.06]).unwrap(),
            ],
            act_params: with_acts.then(|| {
                vec![
                    ActQuantParams { scale: 0.1, zero: -1.0 },
                    ActQuantParams { scale: 0.2, zero: 0.0 },
                ]
            }),
            wall_s: 1.0,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ar_state_{}", std::process::id()));
        let out = fake_outcome(true);
        save(&out, &dir).unwrap();
        let back = load(&dir).unwrap();
        assert_eq!(back.model, "m");
        assert_eq!(back.method, "attention");
        assert_eq!(back.bits, vec![8, 4]);
        assert_eq!(back.qweights[0], out.qweights[0]);
        assert_eq!(back.qweights[1], out.qweights[1]);
        let ap = back.act_params.unwrap();
        assert_eq!(ap[0].scale, 0.1);
        assert_eq!(ap[0].zero, -1.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_load_without_act_params() {
        let dir =
            std::env::temp_dir().join(format!("ar_state_na_{}", std::process::id()));
        save(&fake_outcome(false), &dir).unwrap();
        let back = load(&dir).unwrap();
        assert!(back.act_params.is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(load(Path::new("/nonexistent/qmodel")).is_err());
    }
}
