//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation section (§4) against this testbed.
//!
//! Each function prints the paper-shaped table/chart, writes
//! markdown + CSV into the output directory, and returns the rendered
//! [`Table`] so benches and tests can assert on rows. Iteration counts and
//! model subsets are parameters — EXPERIMENTS.md records which settings
//! produced the committed numbers (absolute ImageNet accuracies are not
//! reproducible on a synthetic testbed; orderings and gaps are the claim).
//!
//! Execution is backend-neutral: [`Ctx::new`] drives PJRT artifacts,
//! [`Ctx::synthetic`] drives the pure-host backend against the in-memory
//! toy model, and [`Ctx::auto`] picks whichever is available. Because
//! [`crate::backend::Backend`] is `Send + Sync`, independent table cells
//! fan out across the global thread pool ([`Ctx::run_many`]) instead of
//! running strictly serially.

use std::path::PathBuf;

use crate::backend::{Backend, HostBackend, PjrtBackend};
use crate::coordinator::config::CalibConfig;
use crate::coordinator::evaluate::evaluate;
use crate::coordinator::pipeline::{
    quantize_and_eval, resolve_uniform_bits, QuantSpec,
};
use crate::coordinator::qat::run_qat;
use crate::data::{synth, Split};
use crate::io::manifest::Manifest;
use crate::mixed;
use crate::quant::rounding::Rounding;
use crate::report::svg::{bar_chart_svg, line_chart_svg};
use crate::report::{bar_chart, pct, Table};
use crate::util::error::{Error, Result};
use crate::util::threadpool;

/// Synthetic split sizes (host path): the paper's 1,024-image calibration
/// budget, 512 eval images (8 batches), 2,048 train images for QAT.
const SYNTH_CALIB_N: usize = 1024;
const SYNTH_EVAL_N: usize = 512;
const SYNTH_TRAIN_N: usize = 2048;

/// Shared context for all experiments.
pub struct Ctx {
    pub backend: Box<dyn Backend>,
    pub manifest: Manifest,
    pub calib: Split,
    pub eval: Split,
    pub cfg: CalibConfig,
    pub out_dir: PathBuf,
}

impl Ctx {
    /// PJRT context over a built `artifacts/` directory.
    pub fn new(artifacts: &str, cfg: CalibConfig, out_dir: &str) -> Result<Self> {
        let backend: Box<dyn Backend> = Box::new(PjrtBackend::new(artifacts)?);
        let manifest = Manifest::load(artifacts)?;
        let data_dir = manifest.path(&manifest.dataset.dir);
        let calib = Split::load(&data_dir, "calib")?;
        let eval = Split::load(&data_dir, "eval")?;
        std::fs::create_dir_all(out_dir)?;
        Ok(Ctx {
            backend,
            manifest,
            calib,
            eval,
            cfg,
            out_dir: PathBuf::from(out_dir),
        })
    }

    /// Host-backend context with zero artifacts: the synthetic manifest,
    /// generator-backed splits, and a measured (not assumed) FP accuracy
    /// patched into the manifest.
    pub fn synthetic(cfg: CalibConfig, out_dir: &str) -> Result<Self> {
        let backend: Box<dyn Backend> = Box::new(HostBackend::new());
        let mut manifest = Manifest::synthetic();
        let calib = synth::split(SYNTH_CALIB_N, synth::CALIB_SEED);
        let eval = synth::split(SYNTH_EVAL_N, synth::EVAL_SEED);
        std::fs::create_dir_all(out_dir)?;
        let mut fp_accs = Vec::with_capacity(manifest.models.len());
        for m in &manifest.models {
            let model = backend.load_model(&manifest, &m.name)?;
            fp_accs.push(evaluate(
                backend.as_ref(),
                &manifest,
                &model,
                &model.weights,
                &eval,
            )?);
        }
        for (m, acc) in manifest.models.iter_mut().zip(fp_accs) {
            m.fp_acc = acc;
        }
        Ok(Ctx {
            backend,
            manifest,
            calib,
            eval,
            cfg,
            out_dir: PathBuf::from(out_dir),
        })
    }

    /// PJRT when artifacts exist, otherwise the host backend — every
    /// checkout gets a runnable end-to-end path.
    pub fn auto(artifacts: &str, cfg: CalibConfig, out_dir: &str) -> Result<Self> {
        if std::path::Path::new(artifacts).join("manifest.json").exists() {
            Self::new(artifacts, cfg, out_dir)
        } else {
            log::info!(
                "no artifacts at {artifacts}: running on the host backend \
                 against the synthetic model"
            );
            Self::synthetic(cfg, out_dir)
        }
    }

    /// The model subset experiments default to on this context.
    pub fn default_models(&self) -> Vec<String> {
        if self.manifest.is_synthetic() {
            self.manifest.models.iter().map(|m| m.name.clone()).collect()
        } else {
            ALL_MODELS
                .iter()
                .filter(|m| self.manifest.model(m).is_ok())
                .map(|m| m.to_string())
                .collect()
        }
    }

    /// The model a single-model run should default to: the caller's
    /// explicit request (`--model`, `REPRO_MODEL`) if any, else the
    /// first default model of this context.
    pub fn primary_model(&self, requested: Option<&str>) -> Result<String> {
        if let Some(m) = requested {
            return Ok(m.to_string());
        }
        self.default_models()
            .first()
            .cloned()
            .ok_or_else(|| Error::config("no models in manifest; pass a model name"))
    }

    /// The train split (QAT): generator-backed on the synthetic context.
    pub fn train_split(&self) -> Result<Split> {
        if self.manifest.is_synthetic() {
            Ok(synth::split(SYNTH_TRAIN_N, synth::TRAIN_SEED))
        } else {
            Split::load(&self.manifest.path(&self.manifest.dataset.dir), "train")
        }
    }

    pub fn save(&self, name: &str, t: &Table) -> Result<()> {
        std::fs::write(self.out_dir.join(format!("{name}.md")), t.render())?;
        std::fs::write(self.out_dir.join(format!("{name}.csv")), t.to_csv())?;
        Ok(())
    }

    fn run_cfg(
        &self,
        model: &str,
        wbits: u8,
        abits: Option<u8>,
        cfg: &CalibConfig,
    ) -> Result<f64> {
        let loaded = self.backend.load_model(&self.manifest, model)?;
        let spec = QuantSpec {
            model: model.to_string(),
            wbits: resolve_uniform_bits(&loaded, wbits),
            abits,
        };
        let out = quantize_and_eval(
            self.backend.as_ref(),
            &self.manifest,
            &spec,
            cfg,
            &self.calib,
            &self.eval,
        )?;
        log::info!(
            "{model} {}/{} {:?}: top-1 {:.2}% (fp {:.2}%) in {:.1}s",
            wbits,
            abits.map(|b| b.to_string()).unwrap_or_else(|| "32".into()),
            cfg.method,
            out.acc * 100.0,
            out.fp_acc * 100.0,
            out.wall_s
        );
        Ok(out.acc)
    }

    fn run(
        &self,
        model: &str,
        wbits: u8,
        abits: Option<u8>,
        method: Rounding,
    ) -> Result<f64> {
        let mut cfg = self.cfg.clone();
        cfg.method = method;
        self.run_cfg(model, wbits, abits, &cfg)
    }

    /// Run independent quantize+eval cells across the global pool.
    /// Each cell is a full pipeline run with its own RNG stream seeded
    /// from the config, so results are identical to the serial order.
    ///
    /// Nested parallelism is **bounded**: each cell runs under
    /// [`threadpool::with_width_cap`] with the pool width divided among
    /// the concurrently-running cells, so a cell's inner matmuls/kernels
    /// cannot each spawn a full pool's worth of scoped workers
    /// (transient oversubscription ≈ cells × pool size before the cap).
    /// The serve worker exposes the same mechanism
    /// (`WorkerConfig::width` / `--worker-width`) so co-scheduled
    /// serving can be bounded to its share of the pool too.
    ///
    /// Note on metrics: concurrent cells accumulate into the backend's
    /// one [`crate::util::timer::Metrics`], so per-phase durations in
    /// the final report are aggregate CPU-seconds across cells, not
    /// wall-clock, whenever cells overlap.
    pub fn run_many(
        &self,
        specs: &[(&str, u8, Option<u8>, Rounding)],
    ) -> Result<Vec<f64>> {
        let inner = inner_width(specs.len());
        threadpool::global()
            .scope_map(specs.len(), |i| {
                threadpool::with_width_cap(inner, || {
                    let (model, wbits, abits, method) = specs[i];
                    self.run(model, wbits, abits, method)
                })
            })
            .into_iter()
            .collect()
    }

    fn fp_row(&self, models: &[&str]) -> Result<Vec<String>> {
        let mut row = vec!["Full Prec.".to_string(), "32/32".to_string()];
        for m in models {
            row.push(pct(self.manifest.model(m)?.fp_acc));
        }
        Ok(row)
    }
}

/// Per-cell inner width when `cells` tasks share the global pool: the
/// **caller's** effective width — `width()`, not `size()`, so an
/// already-capped caller's budget is subdivided rather than silently
/// re-widened (scope_map's fresh threads don't inherit the caller's
/// thread-local cap; passing a width derived from it restores the
/// narrowing-only nesting contract) — split evenly among the cells that
/// can actually run at once.
fn inner_width(cells: usize) -> usize {
    let width = threadpool::global().width();
    let concurrent = width.min(cells).max(1);
    (width / concurrent).max(1)
}

pub const ALL_MODELS: [&str; 5] = [
    "resnet18t",
    "resnet50t",
    "mobilenetv2t",
    "regnett",
    "mnasnett",
];

fn header(models: &[&str]) -> Vec<String> {
    let mut h = vec!["Methods".to_string(), "Bits(W/A)".to_string()];
    h.extend(models.iter().map(|m| m.to_string()));
    h
}

/// Table 1 — weight-only PTQ across the zoo.
/// "Ours" at 6/5/4/3 bits; AdaRound / Nearest(OMSE-scale) / Stochastic at
/// 4 and 3 bits (the paper's comparison points).
pub fn table1(ctx: &Ctx, models: &[&str]) -> Result<Table> {
    let hdr = header(models);
    let hdr_refs: Vec<&str> = hdr.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Table 1 — PTQ, weights only (top-1 %)",
        &hdr_refs,
    );
    t.row(ctx.fp_row(models)?);
    for bits in [6u8, 5] {
        let specs: Vec<_> = models
            .iter()
            .map(|m| (*m, bits, None, Rounding::Attention))
            .collect();
        let accs = ctx.run_many(&specs)?;
        let mut row = vec!["Ours".into(), format!("{bits}/32")];
        row.extend(accs.iter().map(|&a| pct(a)));
        t.row(row);
    }
    const METHODS: [(&str, Rounding); 4] = [
        ("Nearest (OMSE)", Rounding::Nearest),
        ("Stochastic", Rounding::Stochastic),
        ("AdaRound", Rounding::AdaRound),
        ("Ours", Rounding::Attention),
    ];
    for bits in [4u8, 3] {
        // one parallel wave per bit width: methods × models cells
        let mut specs = Vec::new();
        for (_, method) in METHODS {
            for m in models {
                specs.push((*m, bits, None, method));
            }
        }
        let accs = ctx.run_many(&specs)?;
        for (mi, (name, _)) in METHODS.iter().enumerate() {
            let mut row = vec![name.to_string(), format!("{bits}/32")];
            row.extend(
                accs[mi * models.len()..(mi + 1) * models.len()]
                    .iter()
                    .map(|&a| pct(a)),
            );
            t.row(row);
        }
    }
    println!("{}", t.render());
    ctx.save("table1", &t)?;
    Ok(t)
}

/// Table 2 — weights + activations.
pub fn table2(ctx: &Ctx, models: &[&str]) -> Result<Table> {
    let hdr = header(models);
    let hdr_refs: Vec<&str> = hdr.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Table 2 — PTQ, weights + activations (top-1 %)",
        &hdr_refs,
    );
    t.row(ctx.fp_row(models)?);
    for (w, a) in [(6u8, 6u8), (5, 5)] {
        let specs: Vec<_> = models
            .iter()
            .map(|m| (*m, w, Some(a), Rounding::Attention))
            .collect();
        let accs = ctx.run_many(&specs)?;
        let mut row = vec!["Ours".into(), format!("{w}/{a}")];
        row.extend(accs.iter().map(|&acc| pct(acc)));
        t.row(row);
    }
    for (name, method) in [
        ("Nearest (OMSE)", Rounding::Nearest),
        ("AdaRound", Rounding::AdaRound),
        ("Ours", Rounding::Attention),
    ] {
        let specs: Vec<_> = models
            .iter()
            .map(|m| (*m, 4u8, Some(4u8), method))
            .collect();
        let accs = ctx.run_many(&specs)?;
        let mut row = vec![name.to_string(), "4/4".into()];
        row.extend(accs.iter().map(|&acc| pct(acc)));
        t.row(row);
    }
    {
        let specs: Vec<_> = models
            .iter()
            .map(|m| (*m, 3u8, Some(4u8), Rounding::Attention))
            .collect();
        let accs = ctx.run_many(&specs)?;
        let mut row = vec!["Ours".into(), "3/4".into()];
        row.extend(accs.iter().map(|&acc| pct(acc)));
        t.row(row);
    }
    println!("{}", t.render());
    ctx.save("table2", &t)?;
    Ok(t)
}

/// Table 3 — PTQ vs (budgeted) QAT. Zoo contexts compare on
/// resnet18t + mobilenetv2t; the synthetic context uses its own model.
pub fn table3(ctx: &Ctx, qat_steps: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 3 — comparison with quantization-aware training",
        &["Model", "Method", "Bits(W/A)", "Train data", "Wall(s)", "Top-1 %"],
    );
    let models: Vec<String> = if ctx.manifest.is_synthetic() {
        ctx.default_models()
    } else {
        vec!["resnet18t".into(), "mobilenetv2t".into()]
    };
    for model in models.iter().map(String::as_str) {
        let fp = ctx.manifest.model(model)?.fp_acc;
        // data-free nearest (the ZeroQ-like zero-cost row)
        let mut cfg0 = ctx.cfg.clone();
        cfg0.method = Rounding::Nearest;
        let loaded = ctx.backend.load_model(&ctx.manifest, model)?;
        let t0 = std::time::Instant::now();
        let spec = QuantSpec {
            model: model.into(),
            wbits: resolve_uniform_bits(&loaded, 4),
            abits: Some(4),
        };
        let out = quantize_and_eval(
            ctx.backend.as_ref(), &ctx.manifest, &spec, &cfg0, &ctx.calib, &ctx.eval,
        )?;
        t.row(vec![
            format!("{model} (FP {:.2})", fp * 100.0),
            "Data-free Nearest".into(),
            "4/4".into(),
            "0*".into(),
            format!("{:.1}", t0.elapsed().as_secs_f64()),
            pct(out.acc),
        ]);
        // budgeted STE-QAT
        let train = ctx.train_split()?;
        let qat = run_qat(
            ctx.backend.as_ref(), &ctx.manifest, model, 4, 4, qat_steps, 1e-3,
            &train, &ctx.eval, ctx.cfg.seed,
        )?;
        t.row(vec![
            format!("{model} (FP {:.2})", fp * 100.0),
            "STE-QAT".into(),
            "4/4".into(),
            format!("{}", qat.train_samples_seen),
            format!("{:.1}", qat.wall_s),
            pct(qat.acc),
        ]);
        // ours 4/4 and 5/5
        for (w, a) in [(4u8, 4u8), (5, 5)] {
            let t1 = std::time::Instant::now();
            let acc = ctx.run(model, w, Some(a), Rounding::Attention)?;
            t.row(vec![
                format!("{model} (FP {:.2})", fp * 100.0),
                "Ours (PTQ)".into(),
                format!("{w}/{a}"),
                format!("{}", ctx.cfg.calib_samples),
                format!("{:.1}", t1.elapsed().as_secs_f64()),
                pct(acc),
            ]);
        }
    }
    println!("{}", t.render());
    ctx.save("table3", &t)?;
    Ok(t)
}

/// Table 4 — mixed precision (Algorithm 1) vs single precision.
pub fn table4(ctx: &Ctx, models: &[&str], eps2: f64) -> Result<Table> {
    let mut t = Table::new(
        "Table 4 — mixed-precision quantization (weights only)",
        &["Model", "Single/Mixed", "Bits", "Model size", "Top-1 %"],
    );
    for model in models {
        let loaded = ctx.backend.load_model(&ctx.manifest, model)?;
        let fp = ctx.manifest.model(model)?.fp_acc;
        for bit_list in [vec![3u8, 4, 5, 6], vec![3, 4, 5]] {
            // Algorithm 1 on the same shared pool the pipeline uses.
            let alloc = mixed::allocate_with(
                threadpool::global(),
                &loaded.info.layers,
                &loaded.weights,
                &bit_list,
                eps2,
            )?;
            let spec = QuantSpec {
                model: model.to_string(),
                wbits: alloc.bits.clone(),
                abits: None,
            };
            let out = quantize_and_eval(
                ctx.backend.as_ref(), &ctx.manifest, &spec, &ctx.cfg, &ctx.calib,
                &ctx.eval,
            )?;
            t.row(vec![
                format!("{model} (FP {:.2})", fp * 100.0),
                "Mixed".into(),
                format!("{bit_list:?}"),
                mixed::format_size_mb(alloc.size_bytes),
                pct(out.acc),
            ]);
        }
        let specs: Vec<_> = [3u8, 4, 5, 6]
            .iter()
            .map(|&b| (*model, b, None, Rounding::Attention))
            .collect();
        let accs = ctx.run_many(&specs)?;
        for (&bits, &acc) in [3u8, 4, 5, 6].iter().zip(&accs) {
            let alloc = mixed::uniform_allocation(&loaded.info.layers, bits);
            t.row(vec![
                format!("{model} (FP {:.2})", fp * 100.0),
                "Single".into(),
                format!("{bits}"),
                mixed::format_size_mb(alloc.size_bytes),
                pct(acc),
            ]);
        }
    }
    println!("{}", t.render());
    ctx.save("table4", &t)?;
    Ok(t)
}

/// Table 5 — the rounding-function ablation (4/32 and 4/4).
pub fn table5(ctx: &Ctx) -> Result<Table> {
    let methods = [
        Rounding::Nearest,
        Rounding::Floor,
        Rounding::Ceil,
        Rounding::Stochastic,
        Rounding::AdaRound,
        Rounding::Attention,
    ];
    let model_owned = ctx
        .default_models()
        .first()
        .cloned()
        .unwrap_or_else(|| "resnet18t".into());
    let model = model_owned.as_str();
    let mut hdr = vec!["Bits(W/A)".to_string()];
    hdr.extend(methods.iter().map(|m| m.name().to_string()));
    let hdr_refs: Vec<&str> = hdr.iter().map(String::as_str).collect();
    let mut t = Table::new(
        format!("Table 5 — rounding functions, {model} (top-1 %)"),
        &hdr_refs,
    );
    for abits in [None, Some(4u8)] {
        let specs: Vec<_> = methods.iter().map(|&m| (model, 4u8, abits, m)).collect();
        let accs = ctx.run_many(&specs)?;
        let mut row = vec![format!(
            "4/{}",
            abits.map(|b| b.to_string()).unwrap_or_else(|| "32".into())
        )];
        row.extend(accs.iter().map(|&a| pct(a)));
        t.row(row);
    }
    println!("{}", t.render());
    ctx.save("table5", &t)?;
    Ok(t)
}

/// Figure 2 — τ sweep (robustness of the single hyperparameter).
pub fn fig2(ctx: &Ctx, models: &[&str], taus: &[f32]) -> Result<Table> {
    let mut hdr = vec!["Model".to_string(), "W/A".to_string()];
    hdr.extend(taus.iter().map(|t| format!("τ={t}")));
    let hdr_refs: Vec<&str> = hdr.iter().map(String::as_str).collect();
    let mut t = Table::new("Figure 2 — effect of τ on top-1 %", &hdr_refs);
    let mut svg_series: Vec<(String, Vec<f64>)> = Vec::new();
    for model in models {
        for abits in [None, Some(4u8)] {
            // the τ points are independent runs: fan them out, each
            // under the same width cap run_many hands its cells
            let inner = inner_width(taus.len());
            let accs: Vec<f64> = threadpool::global()
                .scope_map(taus.len(), |i| {
                    threadpool::with_width_cap(inner, || {
                        let mut cfg = ctx.cfg.clone();
                        cfg.tau = taus[i];
                        cfg.method = Rounding::Attention;
                        ctx.run_cfg(model, 4, abits, &cfg)
                    })
                })
                .into_iter()
                .collect::<Result<_>>()?;
            let wa = abits.map(|b| b.to_string()).unwrap_or_else(|| "32".into());
            let mut row = vec![model.to_string(), format!("4/{wa}")];
            row.extend(accs.iter().map(|&a| pct(a)));
            // terminal chart per series
            let labels: Vec<String> = taus.iter().map(|t| format!("τ={t}")).collect();
            println!(
                "{}",
                bar_chart(
                    &format!("Fig 2 — {model} 4/{wa}"),
                    &labels,
                    &accs.iter().map(|&a| a * 100.0).collect::<Vec<_>>(),
                    48,
                )
            );
            svg_series.push((
                format!("{model} 4/{wa}"),
                accs.iter().map(|&a| a * 100.0).collect(),
            ));
            t.row(row);
        }
    }
    let xs: Vec<f64> = taus.iter().map(|&t| t as f64).collect();
    std::fs::write(
        ctx.out_dir.join("fig2.svg"),
        line_chart_svg("Figure 2 — effect of τ on top-1 %", &xs, &svg_series),
    )?;
    println!("{}", t.render());
    ctx.save("fig2", &t)?;
    Ok(t)
}

/// Figures 3/4/5 — per-layer bit allocation under bits [3..8].
pub fn fig_alloc(ctx: &Ctx, model: &str, eps2: f64) -> Result<Table> {
    let loaded = ctx.backend.load_model(&ctx.manifest, model)?;
    let alloc = mixed::allocate_with(
        threadpool::global(),
        &loaded.info.layers,
        &loaded.weights,
        &[3, 4, 5, 6, 7, 8],
        eps2,
    )?;
    let mut t = Table::new(
        format!("Figure (alloc) — per-layer bits, {model}"),
        &["Layer", "Kind", "Params", "CodingLen(bits)", "Assigned"],
    );
    let labels: Vec<String> = loaded
        .info
        .layers
        .iter()
        .map(|l| {
            if l.downsample {
                format!("{}*", l.name)
            } else {
                l.name.clone()
            }
        })
        .collect();
    for (i, l) in loaded.info.layers.iter().enumerate() {
        t.row(vec![
            labels[i].clone(),
            l.kind.clone(),
            l.params.to_string(),
            format!("{:.1}", alloc.lengths[i]),
            alloc.bits[i].to_string(),
        ]);
    }
    let bit_values: Vec<f64> = alloc.bits.iter().map(|&b| b as f64).collect();
    println!(
        "{}",
        bar_chart(
            &format!("Per-layer bit width — {model} (* = downsample)"),
            &labels,
            &bit_values,
            32,
        )
    );
    std::fs::write(
        ctx.out_dir.join(format!("fig_alloc_{model}.svg")),
        bar_chart_svg(
            &format!("Per-layer bit width — {model} (* = downsample)"),
            &labels,
            &bit_values,
        ),
    )?;
    println!("{}", t.render());
    ctx.save(&format!("fig_alloc_{model}"), &t)?;
    Ok(t)
}
