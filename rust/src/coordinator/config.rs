//! Run configuration.
//!
//! Two built-in profiles mirror the paper's setup at different costs:
//! * `paper` — 2,000 Adam iterations per module, lr 4e-4, τ = 0.5
//!   (paper §4.1). Hours on this single-CPU testbed.
//! * `quick` — 200 iterations, same hyperparameters otherwise; the
//!   default for the experiment harness (EXPERIMENTS.md reports which
//!   profile produced each number).
//!
//! A simple `key = value` config file (INI subset) plus CLI overrides
//! feed into [`CalibConfig`]; unknown keys are an error so typos fail
//! loudly.

use crate::quant::observer::ObserverKind;
use crate::quant::rounding::Rounding;
use crate::util::error::{Error, Result};

#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Adam iterations per module (paper: 2k).
    pub iters: usize,
    /// Adam learning rate (paper: 4e-4).
    pub lr: f32,
    /// Attention Round's Gaussian σ (paper Fig. 2: best ≈ 0.5). In units
    /// of the integer grid (the executable receives τ/s ≡ τ because α
    /// already lives on the grid).
    pub tau: f32,
    /// AdaRound regularizer weight λ.
    pub ada_lambda: f32,
    /// AdaRound β annealing range (high → low over the run).
    pub ada_beta_hi: f32,
    pub ada_beta_lo: f32,
    /// Rounding method under calibration.
    pub method: Rounding,
    /// Activation observer for W+A runs.
    pub observer: ObserverKind,
    /// RNG seed (α init, batch sampling, stochastic rounding).
    pub seed: u64,
    /// Re-capture activations through the partially quantized prefix
    /// every N layers (0 = capture once through the FP model).
    pub recapture_every: usize,
    /// Cap on calibration samples (paper: 1,024 — the full calib split).
    pub calib_samples: usize,
}

impl Default for CalibConfig {
    fn default() -> Self {
        Self::quick()
    }
}

impl CalibConfig {
    pub fn quick() -> Self {
        CalibConfig {
            iters: 200,
            lr: 4e-4 * 4.0, // fewer steps, slightly hotter — tuned on resnet18t
            tau: 0.5,
            ada_lambda: 0.01,
            ada_beta_hi: 20.0,
            ada_beta_lo: 2.0,
            method: Rounding::Attention,
            observer: ObserverKind::Mse,
            seed: 0xA11CE,
            recapture_every: 0,
            calib_samples: 1024,
        }
    }

    pub fn paper() -> Self {
        CalibConfig {
            iters: 2000,
            lr: 4e-4,
            ..Self::quick()
        }
    }

    pub fn profile(name: &str) -> Result<Self> {
        match name {
            "quick" => Ok(Self::quick()),
            "paper" => Ok(Self::paper()),
            other => Err(Error::config(format!(
                "unknown profile {other:?} (expected quick|paper)"
            ))),
        }
    }

    /// Apply one `key = value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |k: &str, v: &str| Error::config(format!("bad value {v:?} for {k}"));
        match key {
            "iters" => self.iters = value.parse().map_err(|_| bad(key, value))?,
            "lr" => self.lr = value.parse().map_err(|_| bad(key, value))?,
            "tau" => self.tau = value.parse().map_err(|_| bad(key, value))?,
            "ada_lambda" => {
                self.ada_lambda = value.parse().map_err(|_| bad(key, value))?
            }
            "ada_beta_hi" => {
                self.ada_beta_hi = value.parse().map_err(|_| bad(key, value))?
            }
            "ada_beta_lo" => {
                self.ada_beta_lo = value.parse().map_err(|_| bad(key, value))?
            }
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "recapture_every" => {
                self.recapture_every = value.parse().map_err(|_| bad(key, value))?
            }
            "calib_samples" => {
                self.calib_samples = value.parse().map_err(|_| bad(key, value))?
            }
            "method" => {
                self.method = Rounding::parse(value)
                    .ok_or_else(|| bad(key, value))?
            }
            "observer" => {
                self.observer = match value {
                    "minmax" => ObserverKind::MinMax,
                    "percentile" => ObserverKind::Percentile,
                    "mse" => ObserverKind::Mse,
                    _ => return Err(bad(key, value)),
                }
            }
            other => return Err(Error::config(format!("unknown config key {other:?}"))),
        }
        Ok(())
    }

    /// Parse an INI-subset config file: `key = value` lines, `#` comments.
    pub fn load_file(&mut self, path: &std::path::Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("{}:{}: expected key = value", path.display(), lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles() {
        assert_eq!(CalibConfig::profile("paper").unwrap().iters, 2000);
        assert_eq!(CalibConfig::profile("quick").unwrap().iters, 200);
        assert!(CalibConfig::profile("warp").is_err());
    }

    #[test]
    fn overrides() {
        let mut c = CalibConfig::quick();
        c.set("iters", "500").unwrap();
        c.set("tau", "0.25").unwrap();
        c.set("method", "adaround").unwrap();
        c.set("observer", "minmax").unwrap();
        assert_eq!(c.iters, 500);
        assert_eq!(c.tau, 0.25);
        assert_eq!(c.method, Rounding::AdaRound);
        assert!(c.set("iters", "abc").is_err());
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let p = std::env::temp_dir().join(format!("ar_cfg_{}.ini", std::process::id()));
        std::fs::write(&p, "# comment\niters = 42\n tau=0.1 # inline\n\n").unwrap();
        let mut c = CalibConfig::quick();
        c.load_file(&p).unwrap();
        assert_eq!(c.iters, 42);
        assert_eq!(c.tau, 0.1);
        std::fs::write(&p, "no_equals_here\n").unwrap();
        assert!(c.load_file(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
