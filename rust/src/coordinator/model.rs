//! Loading FP32 checkpoints described by the manifest.

use crate::io::manifest::{Manifest, ModelInfo};
use crate::io::npy;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// A model's folded FP weights + biases, in manifest layer order.
#[derive(Debug, Clone)]
pub struct LoadedModel {
    pub info: ModelInfo,
    pub weights: Vec<Tensor>,
    pub biases: Vec<Tensor>,
}

impl LoadedModel {
    pub fn load(manifest: &Manifest, name: &str) -> Result<Self> {
        let info = manifest.model(name)?.clone();
        if info.w_files.len() != info.layers.len() {
            return Err(Error::invariant(format!(
                "{name}: {} weight files vs {} layers",
                info.w_files.len(),
                info.layers.len()
            )));
        }
        let mut weights = Vec::with_capacity(info.layers.len());
        let mut biases = Vec::with_capacity(info.layers.len());
        for (li, layer) in info.layers.iter().enumerate() {
            let w = npy::read_f32(&manifest.path(&info.w_files[li]))?;
            if w.shape() != layer.wshape.as_slice() {
                return Err(Error::shape(format!(
                    "{name}/{}: weight file shape {:?} != manifest {:?}",
                    layer.name,
                    w.shape(),
                    layer.wshape
                )));
            }
            let b = npy::read_f32(&manifest.path(&info.b_files[li]))?;
            weights.push(w);
            biases.push(b);
        }
        Ok(LoadedModel {
            info,
            weights,
            biases,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.info.layers.len()
    }

    /// Total parameter count over quantizable layers.
    pub fn total_params(&self) -> usize {
        self.info.layers.iter().map(|l| l.params).sum()
    }
}
