//! Activation capture — phase 1 of the pipeline.
//!
//! Runs the model's collect path (one [`crate::backend::PreparedModel`]
//! per weight set, so device backends upload weights once per pass) over
//! the calibration split in CALIB_BATCH chunks and materializes every
//! quantizable layer's input tensor for all N calibration samples.
//! Weights are supplied per capture, so the same path serves FP capture
//! (paper default) and quantized-prefix re-capture (`recapture_every`
//! config).
//!
//! Memory: per-layer caches are taken (moved out) by the calibration loop
//! as it walks the layers, so peak usage is one full capture plus one
//! layer's reference outputs.

use crate::backend::Backend;
use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::{LayerInfo, Manifest};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-layer activation caches for the calibration set.
pub struct ActCache {
    slots: Vec<Option<Tensor>>,
    pub samples: usize,
}

impl ActCache {
    /// Take layer `li`'s cache (freeing it from the pool).
    pub fn take(&mut self, li: usize) -> Result<Tensor> {
        self.slots
            .get_mut(li)
            .and_then(Option::take)
            .ok_or_else(|| Error::invariant(format!("activation cache for layer {li} already taken")))
    }

    /// Borrow without consuming (observers need a look before calibration).
    pub fn peek(&self, li: usize) -> Result<&Tensor> {
        self.slots
            .get(li)
            .and_then(Option::as_ref)
            .ok_or_else(|| Error::invariant(format!("activation cache for layer {li} missing")))
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// Capture all layer inputs with the given weights (usually FP).
pub fn capture(
    backend: &dyn Backend,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    calib: &Split,
    samples: usize,
) -> Result<ActCache> {
    let cb = manifest.dataset.calib_batch;
    let samples = samples.min(calib.len()) / cb * cb;
    if samples == 0 {
        return Err(Error::config(format!(
            "need at least {cb} calibration samples"
        )));
    }
    let k = model.num_layers();
    let prepared = backend.prepare(model, weights)?;

    let mut slots: Vec<Option<Tensor>> = vec![None; k];
    backend.metrics().time("pipeline.capture", || -> Result<()> {
        for start in (0..samples).step_by(cb) {
            let (x, _) = calib.batch(start, cb)?;
            let (ins, _logits) = prepared.collect(&x)?;
            if ins.len() != k {
                return Err(Error::runtime(format!(
                    "collect returned {} layer inputs, expected {k}",
                    ins.len()
                )));
            }
            for (li, t) in ins.into_iter().enumerate() {
                let slot = &mut slots[li];
                if slot.is_none() {
                    let mut shape = t.shape().to_vec();
                    shape[0] = samples;
                    *slot = Some(Tensor::zeros(shape));
                }
                slot.as_mut().unwrap().write_axis0(start, &t)?;
            }
        }
        Ok(())
    })?;

    Ok(ActCache { slots, samples })
}

/// Reference outputs y_ref = layer_fwd(x, w_fp) for a whole cache, in
/// calib-batch chunks (phase 2 input for the reconstruction loss).
pub fn reference_outputs(
    backend: &dyn Backend,
    layer: &LayerInfo,
    xcache: &Tensor,
    w_fp: &Tensor,
    batch: usize,
) -> Result<Tensor> {
    let staged = backend.prepare_layer(layer, w_fp)?;
    let samples = xcache.shape()[0];
    let mut out: Option<Tensor> = None;
    for start in (0..samples).step_by(batch) {
        let x = xcache.slice_axis0(start, batch.min(samples - start))?;
        let y = staged.fwd(&x)?;
        if out.is_none() {
            let mut shape = y.shape().to_vec();
            shape[0] = samples;
            out = Some(Tensor::zeros(shape));
        }
        out.as_mut().unwrap().write_axis0(start, &y)?;
    }
    out.ok_or_else(|| Error::invariant("empty activation cache"))
}
