//! Activation capture — phase 1 of the pipeline.
//!
//! Runs the model's `collect` executable over the calibration split in
//! CALIB_BATCH chunks and materializes every quantizable layer's input
//! tensor for all N calibration samples. Weights are supplied per call,
//! so the same executable serves FP capture (paper default) and
//! quantized-prefix re-capture (`recapture_every` config).
//!
//! Memory: per-layer caches are taken (moved out) by the calibration loop
//! as it walks the layers, so peak usage is one full capture plus one
//! layer's reference outputs.

use crate::coordinator::model::LoadedModel;
use crate::data::Split;
use crate::io::manifest::Manifest;
use crate::runtime::{literal_to_tensor, Runtime};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Per-layer activation caches for the calibration set.
pub struct ActCache {
    slots: Vec<Option<Tensor>>,
    pub samples: usize,
}

impl ActCache {
    /// Take layer `li`'s cache (freeing it from the pool).
    pub fn take(&mut self, li: usize) -> Result<Tensor> {
        self.slots
            .get_mut(li)
            .and_then(Option::take)
            .ok_or_else(|| Error::invariant(format!("activation cache for layer {li} already taken")))
    }

    /// Borrow without consuming (observers need a look before calibration).
    pub fn peek(&self, li: usize) -> Result<&Tensor> {
        self.slots
            .get(li)
            .and_then(Option::as_ref)
            .ok_or_else(|| Error::invariant(format!("activation cache for layer {li} missing")))
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

/// Capture all layer inputs with the given weights (usually FP).
pub fn capture(
    rt: &Runtime,
    manifest: &Manifest,
    model: &LoadedModel,
    weights: &[Tensor],
    calib: &Split,
    samples: usize,
) -> Result<ActCache> {
    let cb = manifest.dataset.calib_batch;
    let samples = samples.min(calib.len()) / cb * cb;
    if samples == 0 {
        return Err(Error::config(format!(
            "need at least {cb} calibration samples"
        )));
    }
    let exe = rt.load(&model.info.collect)?;
    let k = model.num_layers();

    // Upload weights + biases once for the whole pass.
    let wbufs = rt.upload_all(weights)?;
    let bbufs = rt.upload_all(&model.biases)?;

    let mut slots: Vec<Option<Tensor>> = vec![None; k];
    rt.metrics.time("pipeline.capture", || -> Result<()> {
        for start in (0..samples).step_by(cb) {
            let (x, _) = calib.batch(start, cb)?;
            let xbuf = rt.upload(&x)?;
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + 2 * k);
            args.push(&xbuf);
            args.extend(wbufs.iter());
            args.extend(bbufs.iter());
            let outs = exe.run_b(&args)?;
            if outs.len() != k + 1 {
                return Err(Error::runtime(format!(
                    "collect returned {} outputs, expected {} layers + logits",
                    outs.len(),
                    k
                )));
            }
            for li in 0..k {
                let t = literal_to_tensor(&outs[li])?;
                let slot = &mut slots[li];
                if slot.is_none() {
                    let mut shape = t.shape().to_vec();
                    shape[0] = samples;
                    *slot = Some(Tensor::zeros(shape));
                }
                slot.as_mut().unwrap().write_axis0(start, &t)?;
            }
        }
        Ok(())
    })?;

    Ok(ActCache {
        slots,
        samples,
    })
}

/// Reference outputs y_ref = layer_fwd(x, w_fp) for a whole cache, in
/// calib-batch chunks (phase 2 input for the reconstruction loss).
pub fn reference_outputs(
    rt: &Runtime,
    layer_fwd_path: &str,
    xcache: &Tensor,
    w_fp: &Tensor,
    batch: usize,
) -> Result<Tensor> {
    let exe = rt.load(layer_fwd_path)?;
    let wbuf = rt.upload(w_fp)?;
    let samples = xcache.shape()[0];
    let mut out: Option<Tensor> = None;
    for start in (0..samples).step_by(batch) {
        let x = xcache.slice_axis0(start, batch)?;
        let xbuf = rt.upload(&x)?;
        let outs = exe.run_b(&[&xbuf, &wbuf])?;
        let y = literal_to_tensor(&outs[0])?;
        if out.is_none() {
            let mut shape = y.shape().to_vec();
            shape[0] = samples;
            out = Some(Tensor::zeros(shape));
        }
        out.as_mut().unwrap().write_axis0(start, &y)?;
    }
    out.ok_or_else(|| Error::invariant("empty activation cache"))
}
