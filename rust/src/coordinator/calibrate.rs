//! Per-layer calibration — the paper's §3.3 (Attention Round) and the
//! AdaRound baseline, driven over backend calibration sessions
//! ([`crate::backend::CalibScan`]: the AOT step/scan executables on
//! PJRT, a native fused-Adam loop on the host backend).
//!
//! The reconstruction objective is ‖ŵx − wx‖²_F per module (paper §3.1,
//! Taylor-expansion argument); Adam runs *inside* the session, and the
//! K-step scan variant keeps α/m/v backend-resident for K iterations per
//! coordinator round trip.
//!
//! τ convention: the sessions receive τ in integer-grid units (α lives
//! on the grid: ŵ = s·clip(⌊w/s + α⌉, l, h)). The paper's Figure-2 sweep
//! over τ ∈ [0, 1] with optimum ≈ 0.5 only makes dimensional sense on the
//! grid (half a quantization cell); DESIGN.md §2 records this reading.

use crate::backend::{Backend, ScanKind, ScanSetup, ScanState};
use crate::coordinator::config::CalibConfig;
use crate::io::manifest::LayerInfo;
use crate::quant::rounding::{adaround_h, adaround_finalize, attention_finalize};
use crate::quant::scale::mse_optimal_scale;
use crate::quant::QGrid;
use crate::tensor::Tensor;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Outcome of calibrating one layer.
#[derive(Debug, Clone)]
pub struct CalibratedLayer {
    pub qweight: Tensor,
    pub grid: QGrid,
    /// Mean reconstruction loss over the first / last scan call —
    /// convergence diagnostics surfaced in the run report.
    pub first_loss: f32,
    pub last_loss: f32,
    /// Trained rounding variable (α or V) for ablation inspection.
    pub variable: Tensor,
}

/// Sample a (K·B) stack of x / y_ref batches from the caches.
fn sample_stack(
    xcache: &Tensor,
    yref: &Tensor,
    rng: &mut Rng,
    k: usize,
    batch: usize,
) -> Result<(Tensor, Tensor)> {
    let n = xcache.shape()[0];
    let idx: Vec<usize> = (0..k * batch).map(|_| rng.below(n)).collect();
    let xs = xcache.gather_axis0(&idx)?;
    let ys = yref.gather_axis0(&idx)?;
    let mut xshape = vec![k, batch];
    xshape.extend_from_slice(&xcache.shape()[1..]);
    let mut yshape = vec![k, batch];
    yshape.extend_from_slice(&yref.shape()[1..]);
    Ok((xs.reshape(xshape)?, ys.reshape(yshape)?))
}

/// Calibrate one layer with Attention Round (paper §3.3).
#[allow(clippy::too_many_arguments)]
pub fn calibrate_attention(
    backend: &dyn Backend,
    layer: &LayerInfo,
    w_fp: &Tensor,
    xcache: &Tensor,
    yref: &Tensor,
    bits: u8,
    cfg: &CalibConfig,
    scan_k: usize,
    calib_batch: usize,
    rng: &mut Rng,
) -> Result<CalibratedLayer> {
    let scale = mse_optimal_scale(w_fp.data(), bits)?;
    let grid = QGrid::signed(bits, scale)?;

    // α ~ N(0, τ²) on the integer grid (paper §3.3 initialization).
    let mut alpha = Tensor::zeros(w_fp.shape().to_vec());
    if cfg.tau > 0.0 {
        rng.fill_gaussian(alpha.data_mut(), 0.0, cfg.tau);
    }
    let mut scan = backend.begin_scan(
        ScanSetup {
            layer,
            w_fp,
            grid,
            lr: cfg.lr,
            kind: ScanKind::Attention { tau: cfg.tau },
        },
        ScanState::new(alpha),
    )?;

    let calls = cfg.iters.div_ceil(scan_k).max(1);
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    backend.metrics().time("pipeline.calibrate", || -> Result<()> {
        for call in 0..calls {
            let (xs, ys) = sample_stack(xcache, yref, rng, scan_k, calib_batch)?;
            let loss = scan.scan(&xs, &ys, 0.0)?;
            if call == 0 {
                first_loss = loss;
            }
            last_loss = loss;
        }
        Ok(())
    })?;

    let alpha = scan.state().var.clone();
    let qdata = attention_finalize(w_fp.data(), alpha.data(), &grid);
    Ok(CalibratedLayer {
        qweight: Tensor::new(w_fp.shape().to_vec(), qdata)?,
        grid,
        first_loss,
        last_loss,
        variable: alpha,
    })
}

/// Calibrate one layer with AdaRound (Nagel et al. 2020 — the paper's
/// strongest baseline in Tables 1/2/5).
#[allow(clippy::too_many_arguments)]
pub fn calibrate_adaround(
    backend: &dyn Backend,
    layer: &LayerInfo,
    w_fp: &Tensor,
    xcache: &Tensor,
    yref: &Tensor,
    bits: u8,
    cfg: &CalibConfig,
    scan_k: usize,
    calib_batch: usize,
    rng: &mut Rng,
) -> Result<CalibratedLayer> {
    let _ = rng; // deterministic init; signature symmetric with attention
    let scale = mse_optimal_scale(w_fp.data(), bits)?;
    let grid = QGrid::signed(bits, scale)?;

    // V init so that h(V) equals the fractional part of w/s (AdaRound's
    // standard warm start: ŵ starts at round-to-nearest).
    let mut vvar = Tensor::zeros(w_fp.shape().to_vec());
    for (vv, &wv) in vvar.data_mut().iter_mut().zip(w_fp.data()) {
        let frac = (wv / grid.scale - (wv / grid.scale).floor()).clamp(0.01, 0.99);
        let sig = ((frac + 0.1) / 1.2).clamp(1e-4, 1.0 - 1e-4);
        *vv = (sig / (1.0 - sig)).ln();
        debug_assert!((adaround_h(*vv) - frac).abs() < 1e-2);
    }
    let mut scan = backend.begin_scan(
        ScanSetup {
            layer,
            w_fp,
            grid,
            lr: cfg.lr,
            kind: ScanKind::AdaRound { lambda: cfg.ada_lambda },
        },
        ScanState::new(vvar),
    )?;

    let calls = cfg.iters.div_ceil(scan_k).max(1);
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    backend.metrics().time("pipeline.calibrate", || -> Result<()> {
        for call in 0..calls {
            let progress = call as f32 / calls.max(1) as f32;
            let beta = cfg.ada_beta_hi + (cfg.ada_beta_lo - cfg.ada_beta_hi) * progress;
            let (xs, ys) = sample_stack(xcache, yref, rng, scan_k, calib_batch)?;
            let loss = scan.scan(&xs, &ys, beta)?;
            if call == 0 {
                first_loss = loss;
            }
            last_loss = loss;
        }
        Ok(())
    })?;

    let vvar = scan.state().var.clone();
    let qdata = adaround_finalize(w_fp.data(), vvar.data(), &grid);
    Ok(CalibratedLayer {
        qweight: Tensor::new(w_fp.shape().to_vec(), qdata)?,
        grid,
        first_loss,
        last_loss,
        variable: vvar,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_stack_shapes() {
        let xc = Tensor::new(vec![10, 2, 2], (0..40).map(|i| i as f32).collect()).unwrap();
        let yc = Tensor::new(vec![10, 3], (0..30).map(|i| i as f32).collect()).unwrap();
        let mut rng = Rng::new(0);
        let (xs, ys) = sample_stack(&xc, &yc, &mut rng, 4, 2).unwrap();
        assert_eq!(xs.shape(), &[4, 2, 2, 2]);
        assert_eq!(ys.shape(), &[4, 2, 3]);
    }
}
