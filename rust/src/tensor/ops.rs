//! Elementwise ops and reductions over [`Tensor`] / raw f32 slices.
//!
//! These run on the host in hot-ish paths (scale search iterates over the
//! full weight tensor dozens of times), so the slice variants avoid
//! allocation and are written to auto-vectorize.

use super::Tensor;

/// max |x|
pub fn abs_max(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()))
}

pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in xs {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Mean squared error between two equal-length slices (f64 accumulator —
/// the MSE scale search compares values that differ in the 6th digit).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Sum of squared values (f64 accumulator).
pub fn sum_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-1 accuracy given flattened logits (n, classes) and labels.
pub fn top1_accuracy(logits: &Tensor, labels: &[i32]) -> f64 {
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(n, labels.len());
    let mut correct = 0usize;
    for i in 0..n {
        if argmax(&logits.data()[i * c..(i + 1) * c]) as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Percentile (0..=100) by copy-and-select; used by observers.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abs_max_and_minmax() {
        let xs = [-3.0, 1.0, 2.5];
        assert_eq!(abs_max(&xs), 3.0);
        assert_eq!(min_max(&xs), (-3.0, 2.5));
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
    }

    #[test]
    fn accuracy() {
        let logits = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, 0.0, 0.0]).unwrap();
        assert_eq!(top1_accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(top1_accuracy(&logits, &[1, 2]), 0.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }
}
