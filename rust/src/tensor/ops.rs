//! Elementwise ops and reductions over [`Tensor`] / raw f32 slices.
//!
//! These run on the host in hot-ish paths (scale search iterates over the
//! full weight tensor dozens of times), so the slice variants avoid
//! allocation and are written to auto-vectorize.

use super::Tensor;

/// max |x|
pub fn abs_max(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()))
}

pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in xs {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo, hi)
}

pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Mean squared error between two equal-length slices (f64 accumulator —
/// the MSE scale search compares values that differ in the 6th digit).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Sum of squared values (f64 accumulator).
pub fn sum_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Index of the first maximum, ignoring NaNs.
///
/// A plain `v > best` scan is NaN-poisoned: with a NaN at index 0 every
/// comparison is false and the NaN's index comes back silently. NaN
/// entries are skipped instead; an all-NaN (or empty) slice returns 0.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    let mut found = false;
    for (i, &v) in xs.iter().enumerate() {
        if !v.is_nan() && (!found || v > best_v) {
            best = i;
            best_v = v;
            found = true;
        }
    }
    best
}

/// Top-1 accuracy given flattened logits (n, classes) and labels.
pub fn top1_accuracy(logits: &Tensor, labels: &[i32]) -> f64 {
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(n, labels.len());
    let mut correct = 0usize;
    for i in 0..n {
        if argmax(&logits.data()[i * c..(i + 1) * c]) as i32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Percentile (0..=100) by copy-and-select; used by observers. See
/// [`percentile_with`] for the allocation-free form.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    let mut scratch = Vec::new();
    percentile_with(xs, p, &mut scratch)
}

/// Percentile (0..=100) via `select_nth_unstable_by` — O(n) instead of
/// the O(n log n) full sort — into a caller-provided scratch buffer, so
/// repeated observer calls (one per layer per percentile) reuse one
/// allocation. NaN inputs no longer panic (the old sort did): under
/// IEEE `total_cmp` positive NaNs order above +∞ and negative NaNs
/// below −∞, so extreme percentiles of NaN-polluted data can return
/// NaN — observers assume finite activations either way.
pub fn percentile_with(xs: &[f32], p: f64, scratch: &mut Vec<f32>) -> f32 {
    assert!(!xs.is_empty());
    scratch.clear();
    scratch.extend_from_slice(xs);
    let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
    let idx = idx.min(xs.len() - 1);
    let (_, v, _) = scratch.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
    *v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abs_max_and_minmax() {
        let xs = [-3.0, 1.0, 2.5];
        assert_eq!(abs_max(&xs), 3.0);
        assert_eq!(min_max(&xs), (-3.0, 2.5));
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
    }

    #[test]
    fn accuracy() {
        let logits = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 1.0, 0.0, 0.0]).unwrap();
        assert_eq!(top1_accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(top1_accuracy(&logits, &[1, 2]), 0.5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn percentile_select_matches_full_sort() {
        let mut rng = crate::util::rng::Rng::new(77);
        let mut xs = vec![0.0f32; 5000];
        rng.fill_gaussian(&mut xs, 0.0, 2.0);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut scratch = Vec::new();
        for p in [0.0, 0.1, 25.0, 50.0, 99.9, 100.0] {
            let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
            let want = sorted[idx.min(xs.len() - 1)];
            assert_eq!(percentile_with(&xs, p, &mut scratch), want, "p={p}");
            assert_eq!(percentile(&xs, p), want, "p={p}");
        }
    }

    #[test]
    fn argmax_ignores_nan() {
        assert_eq!(argmax(&[f32::NAN, 1.0, 3.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0); // all-NaN falls back to 0
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
    }
}
