//! Dense f32 tensor substrate.
//!
//! Deliberately minimal: contiguous row-major storage, shape bookkeeping,
//! the reductions and elementwise ops the quantizer and observers need.
//! Heavy math goes through PJRT (Layer 2) or `linalg`; this type is the
//! host-side currency between npy files, literals, and the quantizer.

pub mod ops;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Tensor {
            shape: vec![data.len()],
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Rows of a 2-D view (n_rows, row_len) without copying.
    pub fn rows_2d(&self) -> Result<(usize, usize)> {
        match self.shape.len() {
            2 => Ok((self.shape[0], self.shape[1])),
            _ => Err(Error::shape(format!("expected 2-D, got {:?}", self.shape))),
        }
    }

    /// Slice of samples [start, start+count) along axis 0 (copying).
    pub fn slice_axis0(&self, start: usize, count: usize) -> Result<Tensor> {
        if self.shape.is_empty() {
            return Err(Error::shape("cannot slice a scalar"));
        }
        let n0 = self.shape[0];
        if start + count > n0 {
            return Err(Error::shape(format!(
                "slice [{start}, {}) out of axis-0 bound {n0}",
                start + count
            )));
        }
        let stride: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Ok(Tensor {
            shape,
            data: self.data[start * stride..(start + count) * stride].to_vec(),
        })
    }

    /// Gather samples by index along axis 0 (copying) — batch assembly.
    pub fn gather_axis0(&self, idx: &[usize]) -> Result<Tensor> {
        if self.shape.is_empty() {
            return Err(Error::shape("cannot gather a scalar"));
        }
        let n0 = self.shape[0];
        let stride: usize = self.shape[1..].iter().product();
        let mut data = Vec::with_capacity(idx.len() * stride);
        for &i in idx {
            if i >= n0 {
                return Err(Error::shape(format!("index {i} out of bound {n0}")));
            }
            data.extend_from_slice(&self.data[i * stride..(i + 1) * stride]);
        }
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        Ok(Tensor { shape, data })
    }

    /// Write a slice of samples into [start, ...) along axis 0.
    pub fn write_axis0(&mut self, start: usize, src: &Tensor) -> Result<()> {
        if self.shape[1..] != src.shape[1..] {
            return Err(Error::shape(format!(
                "axis-0 write shape mismatch: {:?} vs {:?}",
                self.shape, src.shape
            )));
        }
        let stride: usize = self.shape[1..].iter().product();
        let count = src.shape[0];
        if start + count > self.shape[0] {
            return Err(Error::shape("axis-0 write out of bounds"));
        }
        self.data[start * stride..(start + count) * stride]
            .copy_from_slice(&src.data);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice_and_gather() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.slice_axis0(1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        let g = t.gather_axis0(&[3, 0]).unwrap();
        assert_eq!(g.data(), &[6.0, 7.0, 0.0, 1.0]);
        assert!(t.gather_axis0(&[4]).is_err());
    }

    #[test]
    fn write_axis0_roundtrip() {
        let mut t = Tensor::zeros(vec![4, 3]);
        let src = Tensor::new(vec![2, 3], vec![1.0; 6]).unwrap();
        t.write_axis0(2, &src).unwrap();
        assert_eq!(&t.data()[6..], &[1.0; 6]);
        assert_eq!(&t.data()[..6], &[0.0; 6]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0]);
        let t = t.reshape(vec![2, 2]).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert!(t.clone().reshape(vec![3, 2]).is_err());
    }
}
