//! Literal ⇄ Tensor conversion.

use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

/// Convert an f32 (or s32 — converted) literal to a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| Error::runtime(format!("literal shape: {e}")))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match shape.ty() {
        xla::ElementType::F32 => lit
            .to_vec::<f32>()
            .map_err(|e| Error::runtime(format!("literal to_vec: {e}")))?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(|e| Error::runtime(format!("literal to_vec: {e}")))?
            .into_iter()
            .map(|v| v as f32)
            .collect(),
        other => {
            return Err(Error::runtime(format!(
                "unsupported literal element type {other:?}"
            )))
        }
    };
    Tensor::new(dims, data)
}

pub fn literals_to_tensors(lits: &[xla::Literal]) -> Result<Vec<Tensor>> {
    lits.iter().map(literal_to_tensor).collect()
}

/// Read a scalar f32 out of a literal (loss values etc.).
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    let t = literal_to_tensor(lit)?;
    if t.len() != 1 {
        return Err(Error::shape(format!(
            "expected scalar literal, got shape {:?}",
            t.shape()
        )));
    }
    Ok(t.data()[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_roundtrip() {
        let lit = xla::Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0])
            .reshape(&[2, 3])
            .unwrap();
        let t = literal_to_tensor(&lit).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data()[4], 5.0);
    }

    #[test]
    fn scalar_literal() {
        let lit = xla::Literal::scalar(7.5f32);
        assert_eq!(literal_scalar(&lit).unwrap(), 7.5);
        let vec = xla::Literal::vec1(&[1.0f32, 2.0]);
        assert!(literal_scalar(&vec).is_err());
    }

    #[test]
    fn s32_converts() {
        let lit = xla::Literal::vec1(&[1i32, -2, 3]);
        let t = literal_to_tensor(&lit).unwrap();
        assert_eq!(t.data(), &[1.0, -2.0, 3.0]);
    }
}
