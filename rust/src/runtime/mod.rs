//! PJRT runtime — loads AOT artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): HLO **text** →
//! `HloModuleProto::from_text_file` → `compile` → `execute_b`. Text is the
//! interchange format because xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids.
//!
//! Performance notes (EXPERIMENTS.md §Perf quantifies these):
//! * Executables are compiled once and cached by artifact path.
//! * All execution goes through `execute_b` with device-resident
//!   [`xla::PjRtBuffer`]s: constant operands (weights, cached activations)
//!   are uploaded once per pipeline phase and reused across thousands of
//!   steps, instead of re-marshalling literals per call.
//! * Multi-output executables return a single tuple buffer on this PJRT
//!   build; `run`/`run_b` decompose it on the host. The calibration loop
//!   amortizes that hop with the K-step `calib_scan` executables (see
//!   python/compile/quant.py).

pub mod convert;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::timer::Metrics;

pub use convert::{literal_to_tensor, literals_to_tensors};

/// A compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute on device-resident buffers; decompose the output tuple
    /// into literals (host).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| Error::runtime(format!("{}: {e}", self.name)))?;
        let first = outs
            .into_iter()
            .next()
            .and_then(|replica| replica.into_iter().next())
            .ok_or_else(|| Error::runtime(format!("{}: no outputs", self.name)))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("{}: {e}", self.name)))?;
        decompose(lit, &self.name)
    }
}

fn decompose(lit: xla::Literal, name: &str) -> Result<Vec<xla::Literal>> {
    // aot.py lowers everything with return_tuple=True, so the root is
    // always a tuple — even single outputs arrive as a 1-tuple.
    lit.to_tuple()
        .map_err(|e| Error::runtime(format!("{name}: tuple decompose: {e}")))
}

/// The PJRT client plus the executable cache. One per process.
///
/// `Send + Sync`: executables are shared as [`Arc`]s and the cache sits
/// behind a `Mutex`, so the experiment harness can fan table rows out
/// across the thread pool against one runtime. (This holds for the
/// vendored host stub; a real `xla_extension` client would need its own
/// thread-safety audit before lifting the bound.)
pub struct Runtime {
    client: xla::PjRtClient,
    root: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    pub metrics: Metrics,
}

impl Runtime {
    pub fn new(artifacts_root: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PJRT CPU client: {e}")))?;
        Ok(Runtime {
            client,
            root: artifacts_root.into(),
            cache: Mutex::new(HashMap::new()),
            metrics: Metrics::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest-relative path (cached).
    pub fn load(&self, rel: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(rel) {
            return Ok(Arc::clone(e));
        }
        let path = self.root.join(rel);
        let exe = self.metrics.time("runtime.compile", || -> Result<_> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::config("non-utf8 artifact path"))?,
            )
            .map_err(|e| Error::runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .map_err(|e| Error::runtime(format!("compile {}: {e}", path.display())))
        })?;
        self.metrics.incr("runtime.compiled_executables", 1);
        let exe = Arc::new(Executable {
            exe,
            name: rel.to_string(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(rel.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    // ---- host -> device transfers ---------------------------------------

    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.metrics.incr("runtime.uploads", 1);
        self.client
            .buffer_from_host_buffer(t.data(), t.shape(), None)
            .map_err(|e| Error::runtime(format!("upload: {e}")))
    }

    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| Error::runtime(format!("upload scalar: {e}")))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| Error::runtime(format!("upload i32: {e}")))
    }

    /// Upload a whole weight set once; reuse across every execute_b call.
    pub fn upload_all(&self, ts: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        ts.iter().map(|t| self.upload(t)).collect()
    }
}

#[cfg(test)]
mod tests {
    //! Runtime tests that don't need artifacts (integration tests with
    //! real artifacts live in rust/tests/).
    use super::*;

    #[test]
    fn client_boots_and_uploads() {
        let rt = Runtime::new("/nonexistent-artifacts").unwrap();
        assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let buf = rt.upload(&t).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let rt = Runtime::new("/nonexistent-artifacts").unwrap();
        assert!(rt.load("hlo/nope.hlo.txt").is_err());
        assert_eq!(rt.cached_count(), 0);
    }

    #[test]
    fn runtime_is_send_sync() {
        // Compile-time check: the experiment harness shares one Runtime
        // across pool workers, so these bounds must never regress.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<Arc<Executable>>();
        assert_send_sync::<Executable>();
    }

    #[test]
    fn scalar_upload_roundtrip() {
        let rt = Runtime::new(".").unwrap();
        let buf = rt.upload_scalar(3.25).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        let t = literal_to_tensor(&lit).unwrap();
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.data(), &[3.25]);
    }
}
