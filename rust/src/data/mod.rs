//! Dataset handling: loading the artifact splits, batching, and the Rust
//! port of the synthetic generator (bench workload generation without
//! touching Python).

pub mod synth;

use std::path::Path;

use crate::io::npy;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// One dataset split held in memory (NHWC images + labels).
#[derive(Debug, Clone)]
pub struct Split {
    pub images: Tensor,
    pub labels: Vec<i32>,
}

impl Split {
    pub fn load(dir: &Path, split: &str) -> Result<Self> {
        let images = npy::read_f32(&dir.join(format!("{split}_x.npy")))?;
        let (lshape, labels) = npy::read_i32(&dir.join(format!("{split}_y.npy")))?;
        if lshape.len() != 1 || lshape[0] != images.shape()[0] {
            return Err(Error::shape(format!(
                "labels {lshape:?} do not match images {:?}",
                images.shape()
            )));
        }
        Ok(Split { images, labels })
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Contiguous batch [start, start+n).
    pub fn batch(&self, start: usize, n: usize) -> Result<(Tensor, &[i32])> {
        let x = self.images.slice_axis0(start, n)?;
        Ok((x, &self.labels[start..start + n]))
    }

    /// Random batch of size n (with replacement across calls, without
    /// within a batch).
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Result<(Tensor, Vec<i32>)> {
        let idx = rng.sample_indices(self.len(), n);
        let x = self.images.gather_axis0(&idx)?;
        let y = idx.iter().map(|&i| self.labels[i]).collect();
        Ok((x, y))
    }

    /// Number of whole batches of size n.
    pub fn num_batches(&self, n: usize) -> usize {
        self.len() / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_split(n: usize) -> Split {
        let images = Tensor::new(
            vec![n, 2, 2, 1],
            (0..n * 4).map(|i| i as f32).collect(),
        )
        .unwrap();
        let labels = (0..n as i32).collect();
        Split { images, labels }
    }

    #[test]
    fn batch_slicing() {
        let s = fake_split(10);
        let (x, y) = s.batch(2, 3).unwrap();
        assert_eq!(x.shape(), &[3, 2, 2, 1]);
        assert_eq!(y, &[2, 3, 4]);
        assert_eq!(s.num_batches(3), 3);
    }

    #[test]
    fn sample_shapes_and_label_alignment() {
        let s = fake_split(10);
        let mut rng = Rng::new(1);
        let (x, y) = s.sample(&mut rng, 4).unwrap();
        assert_eq!(x.shape(), &[4, 2, 2, 1]);
        // each sampled image's first pixel is 4*label
        for (b, &lab) in y.iter().enumerate() {
            assert_eq!(x.data()[b * 4], (lab * 4) as f32);
        }
    }

    #[test]
    fn roundtrip_via_npy() {
        let dir = std::env::temp_dir().join(format!("ar_split_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let s = fake_split(6);
        npy::write_f32(&dir.join("t_x.npy"), &s.images).unwrap();
        npy::write_i32(&dir.join("t_y.npy"), &[6], &s.labels).unwrap();
        let back = Split::load(&dir, "t").unwrap();
        assert_eq!(back.images, s.images);
        assert_eq!(back.labels, s.labels);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
