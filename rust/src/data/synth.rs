//! Rust port of the synthetic dataset generator (python/compile/data.py).
//!
//! Used by the bench harness to create unlimited workload batches without
//! the Python build path. The class-conditional texture *parameters* are
//! identical by construction (same closed-form formulas); the sample-level
//! RNG differs (xorshift vs NumPy PCG), so the two generators agree in
//! distribution, not bitwise — tests assert matching moments and the
//! classifier transfers across both (the integration test feeds Rust
//! samples through the FP model and checks accuracy stays in-band).

use crate::data::Split;
use crate::io::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

pub const NUM_CLASSES: usize = 16;
pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;

/// Canonical split seeds for the artifact-free host pipeline. Disjoint
/// from each other and from the model-construction seeds in
/// `backend::host`, so calibration, evaluation, training and the head
/// prototypes never share samples.
pub const CALIB_SEED: u64 = 2001;
pub const EVAL_SEED: u64 = 2002;
pub const TRAIN_SEED: u64 = 2003;

/// An in-memory [`Split`] straight from the generator — the host
/// backend's replacement for the npy split files.
pub fn split(n: usize, seed: u64) -> Split {
    let (images, labels) = generate(n, seed);
    Split { images, labels }
}

/// Deterministic He-scaled Gaussian weights + zero biases for a
/// host-native (2-D weight) model. The head's weights are placeholders:
/// `backend::HostBackend` replaces them with the closed-form
/// nearest-class-mean readout at load time.
pub fn synthetic_weights(info: &ModelInfo, seed: u64) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let mut weights = Vec::with_capacity(info.layers.len());
    let mut biases = Vec::with_capacity(info.layers.len());
    for layer in &info.layers {
        let [n, m] = layer.wshape.as_slice() else {
            return Err(Error::shape(format!(
                "{}/{}: synthetic layers need 2-D wshape, got {:?}",
                info.name, layer.name, layer.wshape
            )));
        };
        let (n, m) = (*n, *m);
        let mut rng = Rng::new(seed ^ (layer.index as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut data = vec![0.0f32; n * m];
        rng.fill_gaussian(&mut data, 0.0, (2.0 / n as f32).sqrt());
        weights.push(Tensor::new(vec![n, m], data)?);
        biases.push(Tensor::zeros(vec![m]));
    }
    Ok((weights, biases))
}

/// Class-conditional texture parameters — must mirror data.py exactly.
#[derive(Debug, Clone, Copy)]
pub struct ClassParams {
    pub freq: f64,
    pub theta_deg: f64,
    pub color: [f64; 3],
    pub second_freq: f64,
}

pub fn class_params(c: usize) -> ClassParams {
    let cf = c as f64;
    let color_phase = (cf * 2.399) % (2.0 * std::f64::consts::PI);
    ClassParams {
        freq: 1.5 + 0.45 * ((c % 8) as f64),
        theta_deg: (cf * 137.508) % 180.0,
        color: [
            0.6 + 0.4 * color_phase.sin(),
            0.6 + 0.4 * (color_phase + 2.094).sin(),
            0.6 + 0.4 * (color_phase + 4.189).sin(),
        ],
        second_freq: 2.2 + 0.3 * (((c / 8) % 2) as f64),
    }
}

/// Generate n samples; returns (images NHWC, labels).
pub fn generate(n: usize, seed: u64) -> (Tensor, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let mut labels = Vec::with_capacity(n);
    let mut data = vec![0.0f32; n * IMG * IMG * CHANNELS];

    for i in 0..n {
        let c = rng.below(NUM_CLASSES);
        labels.push(c as i32);
        let p = class_params(c);
        let th = (p.theta_deg + rng.gaussian() * 9.0).to_radians();
        let phase = rng.next_f64() * 2.0 * std::f64::consts::PI;
        let contrast = 0.45 + rng.next_f64() * 0.75;
        let (sin_t, cos_t) = th.sin_cos();

        let img = &mut data[i * IMG * IMG * CHANNELS..(i + 1) * IMG * IMG * CHANNELS];
        for yy in 0..IMG {
            for xx in 0..IMG {
                let fy = yy as f64 / IMG as f64;
                let fx = xx as f64 / IMG as f64;
                let u = cos_t * fx + sin_t * fy;
                let v = -sin_t * fx + cos_t * fy;
                let g = (2.0 * std::f64::consts::PI * p.freq * u + phase).sin();
                let g2 =
                    (2.0 * std::f64::consts::PI * p.second_freq * v + phase * 0.5).sin();
                let tex = contrast * (0.8 * g + 0.35 * g2);
                for ch in 0..CHANNELS {
                    let noise = rng.gaussian();
                    img[(yy * IMG + xx) * CHANNELS + ch] =
                        (tex * p.color[ch] + noise) as f32;
                }
            }
        }
        // cutout patch, mirroring data.py
        let ph = 8 + rng.below(9);
        let pw = 8 + rng.below(9);
        let py = rng.below(IMG - ph + 1);
        let px = rng.below(IMG - pw + 1);
        for yy in py..py + ph {
            for xx in px..px + pw {
                for ch in 0..CHANNELS {
                    img[(yy * IMG + xx) * CHANNELS + ch] = 0.0;
                }
            }
        }
    }
    (
        Tensor::new(vec![n, IMG, IMG, CHANNELS], data).unwrap(),
        labels,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    #[test]
    fn shapes_and_label_range() {
        let (x, y) = generate(8, 42);
        assert_eq!(x.shape(), &[8, 32, 32, 3]);
        assert!(y.iter().all(|&l| (0..16).contains(&l)));
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = generate(4, 7);
        let (b, _) = generate(4, 7);
        assert_eq!(a, b);
        let (c, _) = generate(4, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn moments_are_sane() {
        // zero-ish mean, unit-ish std (noise sigma 1 dominates)
        let (x, _) = generate(64, 0);
        let mean = ops::mean(x.data());
        let var = ops::sum_sq(x.data()) / x.len() as f64 - (mean as f64).powi(2);
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((0.5..2.0).contains(&var), "var {var}");
    }

    #[test]
    fn class_params_match_python_formulas() {
        let p = class_params(3);
        assert!((p.freq - (1.5 + 0.45 * 3.0)).abs() < 1e-12);
        assert!((p.theta_deg - ((3.0 * 137.508) % 180.0)).abs() < 1e-9);
        let p8 = class_params(8);
        assert!((p8.second_freq - 2.5).abs() < 1e-12);
    }

    #[test]
    fn split_wraps_generator() {
        let s = split(12, 5);
        assert_eq!(s.len(), 12);
        assert_eq!(s.images.shape(), &[12, 32, 32, 3]);
        let (x, _) = generate(12, 5);
        assert_eq!(s.images, x);
    }

    #[test]
    fn synthetic_weights_deterministic_and_scaled() {
        let info = crate::io::manifest::Manifest::synthetic().models[0].clone();
        let (w1, b1) = synthetic_weights(&info, 7).unwrap();
        let (w2, _) = synthetic_weights(&info, 7).unwrap();
        assert_eq!(w1.len(), 3);
        assert_eq!(w1[0].shape(), &[3, 16]);
        assert_eq!(w1[1], w2[1], "same seed, same weights");
        let (w3, _) = synthetic_weights(&info, 8).unwrap();
        assert_ne!(w1[1], w3[1], "different seed, different weights");
        assert!(b1.iter().all(|b| b.data().iter().all(|&v| v == 0.0)));
        // He scaling: std ≈ sqrt(2/n) for the 16-in block layer
        let var = ops::sum_sq(w1[1].data()) / w1[1].len() as f64;
        assert!((var - 2.0 / 16.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn cutout_leaves_zero_patch() {
        let (x, _) = generate(1, 123);
        let zeros = x.data().iter().filter(|&&v| v == 0.0).count();
        assert!(zeros >= 8 * 8 * 3, "expected a cutout patch, {zeros} zeros");
    }
}
