//! Mixed-precision bit allocation (paper §3.4, Algorithm 1).
//!
//! Rate-distortion view: each layer's weight matrix W ∈ R^{n×m} (m filter
//! vectors of dim n) has a lossy coding length
//!
//!   L(W) = ½ · log₂ det(I + n/(m·ε²) · W·Wᵀ)          (Eq. 12)
//!
//! Layers with longer coding length carry more information and get wider
//! bit widths. Allocation is: compute L per layer, 1-D k-means with
//! k = |bit list| clusters, sort cluster centers ascending, hand the
//! sorted bit list to the sorted clusters. This replaces the combinatorial
//! search HAQ-style methods solve — the paper's efficiency claim.

pub mod kmeans;

use crate::io::manifest::LayerInfo;
use crate::linalg::{log2_det_spd, Mat};
use crate::tensor::Tensor;
use crate::trace::{self, Category};
use crate::util::error::{Error, Result};
use crate::util::threadpool::{self, ThreadPool};

/// Coding length of one layer (Eq. 12) on the shared host pool.
pub fn coding_length(w2d_rows_n: &Mat, eps2: f64) -> Result<f64> {
    coding_length_with(threadpool::global(), w2d_rows_n, eps2)
}

/// Coding length of one layer (Eq. 12), computed on the smaller Gram side
/// (Sylvester: det(I + c·WWᵀ) = det(I + c·WᵀW)) so cost is
/// O(min(n,m)²·max(n,m)). The Gram product runs blocked across `pool`;
/// the n > m side uses `gram_tr_with`, which reads the row-major storage
/// directly instead of materializing the transpose.
pub fn coding_length_with(pool: &ThreadPool, w2d_rows_n: &Mat, eps2: f64) -> Result<f64> {
    let n = w2d_rows_n.rows; // filter dimension
    let m = w2d_rows_n.cols; // number of filters
    if n == 0 || m == 0 {
        return Err(Error::shape("empty weight matrix"));
    }
    let c = n as f64 / (m as f64 * eps2);
    // Gram on the smaller side.
    let mut a = if n <= m {
        w2d_rows_n.gram_with(pool) // n x n
    } else {
        w2d_rows_n.gram_tr_with(pool) // m x m, no transposed copy
    };
    a.scale(c);
    a.add_scaled_identity(1.0);
    Ok(0.5 * log2_det_spd(&a)?)
}

/// The original single-threaded implementation (naive Gram + explicit
/// transpose on the n > m side). Reference baseline for property tests
/// and the before/after hotpath benches.
pub fn coding_length_scalar(w2d_rows_n: &Mat, eps2: f64) -> Result<f64> {
    let n = w2d_rows_n.rows;
    let m = w2d_rows_n.cols;
    if n == 0 || m == 0 {
        return Err(Error::shape("empty weight matrix"));
    }
    let c = n as f64 / (m as f64 * eps2);
    let mut a = if n <= m {
        w2d_rows_n.gram_naive()
    } else {
        let mut t = Mat::zeros(m, n);
        for i in 0..n {
            for j in 0..m {
                *t.at_mut(j, i) = w2d_rows_n.at(i, j);
            }
        }
        t.gram_naive()
    };
    a.scale(c);
    a.add_scaled_identity(1.0);
    Ok(0.5 * log2_det_spd(&a)?)
}

/// Reshape a conv/linear weight tensor into the paper's (n, m) coding
/// view: m columns = output filters, each of dimension n.
pub fn coding_view(w: &Tensor, coding_n: usize, coding_m: usize) -> Result<Mat> {
    if coding_n * coding_m != w.len() {
        return Err(Error::shape(format!(
            "coding view {coding_n}x{coding_m} != {} weights",
            w.len()
        )));
    }
    // Weight layout is (..., out_ch) row-major: element (flat_i, o) with
    // flat_i over the filter dims. That is exactly an n x m row-major
    // matrix with rows = filter dim.
    Mat::from_rows_f32(coding_n, coding_m, w.data())
}

/// Result of Algorithm 1 for one model.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Per-layer bit width, same order as the manifest layers.
    pub bits: Vec<u8>,
    /// Per-layer coding lengths (diagnostics / Figure 3-5 data).
    pub lengths: Vec<f64>,
    /// Model size in bytes counting quantized conv/linear weights only
    /// (the paper's Table 4 accounting).
    pub size_bytes: f64,
}

/// Algorithm 1 on the shared host pool.
pub fn allocate(
    layers: &[LayerInfo],
    weights: &[Tensor],
    bit_list: &[u8],
    eps2: f64,
) -> Result<Allocation> {
    allocate_with(threadpool::global(), layers, weights, bit_list, eps2)
}

/// Algorithm 1: assign a bit width to every layer.
///
/// `pinned` layers (first/last, §4.1) are forced to 8-bit and excluded
/// from clustering, mirroring the paper's setup. Per-layer coding
/// lengths are independent, so they fan out across `pool` with dynamic
/// load balancing (layer sizes vary by orders of magnitude); each
/// worker computes its layer's Gram sequentially to avoid nested
/// oversubscription.
pub fn allocate_with(
    pool: &ThreadPool,
    layers: &[LayerInfo],
    weights: &[Tensor],
    bit_list: &[u8],
    eps2: f64,
) -> Result<Allocation> {
    if bit_list.is_empty() {
        return Err(Error::config("empty bit list"));
    }
    if layers.len() != weights.len() {
        return Err(Error::shape(format!(
            "allocate: {} layers but {} weight tensors",
            layers.len(),
            weights.len()
        )));
    }
    let mut bits_sorted: Vec<u8> = bit_list.to_vec();
    bits_sorted.sort_unstable();

    // Step 1-5: coding lengths, one layer per pool task.
    let _alloc_span =
        trace::span(Category::Alloc, format!("allocate:{}layers", layers.len()));
    let k_layers = layers.len();
    let seq = ThreadPool::seq();
    let lengths: Vec<f64> = pool
        .scope_map(k_layers, |i| -> Result<f64> {
            // per-layer span on the *pool worker's* lane — coding-length
            // cost is the allocate phase's hot part and varies by orders
            // of magnitude across layers
            let _span =
                trace::span(Category::Alloc, format!("coding-length:{}", layers[i].name));
            let mat = coding_view(&weights[i], layers[i].coding_n, layers[i].coding_m)?;
            coding_length_with(&seq, &mat, eps2)
        })
        .into_iter()
        .collect::<Result<Vec<f64>>>()?;

    // Steps 6-8: cluster the non-pinned lengths, map sorted centers to
    // sorted bit widths.
    let free: Vec<usize> = (0..layers.len())
        .filter(|&i| !layers[i].pinned_8bit)
        .collect();
    let free_lengths: Vec<f64> = free.iter().map(|&i| lengths[i]).collect();
    let k = bits_sorted.len().min(free_lengths.len()).max(1);
    let assignment = kmeans::cluster_1d(&free_lengths, k)?;

    let mut bits = vec![8u8; layers.len()];
    for (fi, &layer_idx) in free.iter().enumerate() {
        // cluster ids come out ordered by center (0 = smallest center);
        // when k < len(bit_list) (degenerate tiny models) use the top of
        // the sorted list.
        let cluster = assignment[fi];
        let bit_idx = cluster + bits_sorted.len() - k;
        bits[layer_idx] = bits_sorted[bit_idx];
    }

    let size_bytes = model_size_bytes(layers, &bits);
    Ok(Allocation {
        bits,
        lengths,
        size_bytes,
    })
}

/// Single-precision allocation (the Table 4 baseline rows): every
/// non-pinned layer gets `bits`.
pub fn uniform_allocation(layers: &[LayerInfo], bits_val: u8) -> Allocation {
    let bits: Vec<u8> = layers
        .iter()
        .map(|l| if l.pinned_8bit { 8 } else { bits_val })
        .collect();
    let size_bytes = model_size_bytes(layers, &bits);
    Allocation {
        bits,
        lengths: vec![],
        size_bytes,
    }
}

/// Table 4's size metric: quantized conv/linear weights only.
pub fn model_size_bytes(layers: &[LayerInfo], bits: &[u8]) -> f64 {
    layers
        .iter()
        .zip(bits)
        .map(|(l, &b)| l.params as f64 * b as f64 / 8.0)
        .sum()
}

pub fn format_size_mb(bytes: f64) -> String {
    format!("{:.2}M", bytes / 1_000_000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(i: usize, params: usize, n: usize, m: usize, pinned: bool) -> LayerInfo {
        debug_assert_eq!(params, n * m);
        LayerInfo::synthetic(i, n, m, pinned)
    }

    fn gaussian_tensor(n: usize, m: usize, std: f32, seed: u64) -> Tensor {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut data = vec![0.0f32; n * m];
        rng.fill_gaussian(&mut data, 0.0, std);
        Tensor::new(vec![n, m], data).unwrap()
    }

    #[test]
    fn coding_length_monotone_in_information() {
        // Higher-variance weights carry more information -> longer code.
        let small = coding_view(&gaussian_tensor(16, 32, 0.01, 1), 16, 32).unwrap();
        let big = coding_view(&gaussian_tensor(16, 32, 0.5, 1), 16, 32).unwrap();
        let l_small = coding_length(&small, 0.01).unwrap();
        let l_big = coding_length(&big, 0.01).unwrap();
        assert!(l_big > l_small, "{l_big} <= {l_small}");
    }

    #[test]
    fn coding_length_sylvester_sides_agree() {
        // n < m and transposed n > m must give the same value when the
        // (n, m) roles are kept (c depends on n, m separately, so compare
        // direct vs gram-side shortcut by brute force on the big side).
        let w = gaussian_tensor(8, 24, 0.2, 3);
        let mat = coding_view(&w, 8, 24).unwrap();
        let l_fast = coding_length(&mat, 0.05).unwrap();
        // brute force on the m x m side
        let mut t = Mat::zeros(24, 8);
        for i in 0..8 {
            for j in 0..24 {
                *t.at_mut(j, i) = mat.at(i, j);
            }
        }
        let mut a = t.gram();
        a.scale(8.0 / (24.0 * 0.05));
        a.add_scaled_identity(1.0);
        let l_slow = 0.5 * log2_det_spd(&a).unwrap();
        assert!((l_fast - l_slow).abs() < 1e-6, "{l_fast} vs {l_slow}");
    }

    #[test]
    fn allocate_pins_first_last_and_orders_bits() {
        let layers = vec![
            layer(0, 100, 10, 10, true),
            layer(1, 100, 10, 10, false),
            layer(2, 100, 10, 10, false),
            layer(3, 100, 10, 10, false),
            layer(4, 100, 10, 10, true),
        ];
        let weights = vec![
            gaussian_tensor(10, 10, 0.1, 0),
            gaussian_tensor(10, 10, 0.02, 1), // low info
            gaussian_tensor(10, 10, 0.2, 2),  // mid
            gaussian_tensor(10, 10, 1.5, 3),  // high info
            gaussian_tensor(10, 10, 0.1, 4),
        ];
        let alloc = allocate(&layers, &weights, &[3, 4, 5], 0.01).unwrap();
        assert_eq!(alloc.bits[0], 8);
        assert_eq!(alloc.bits[4], 8);
        // more information -> at least as many bits
        assert!(alloc.bits[1] <= alloc.bits[2]);
        assert!(alloc.bits[2] <= alloc.bits[3]);
        assert_eq!(alloc.bits[1], 3);
        assert_eq!(alloc.bits[3], 5);
    }

    #[test]
    fn size_accounting() {
        let layers = vec![layer(0, 1000, 10, 100, false)];
        assert_eq!(model_size_bytes(&layers, &[4]), 500.0);
        assert_eq!(model_size_bytes(&layers, &[8]), 1000.0);
        let alloc = uniform_allocation(&layers, 4);
        assert_eq!(alloc.size_bytes, 500.0);
    }

    #[test]
    fn uniform_allocation_respects_pins() {
        let layers = vec![layer(0, 10, 1, 10, true), layer(1, 10, 1, 10, false)];
        let a = uniform_allocation(&layers, 3);
        assert_eq!(a.bits, vec![8, 3]);
    }
}
