//! Exact 1-D k-means via dynamic programming.
//!
//! Algorithm 1 clusters per-layer coding lengths into |bit list| groups.
//! In one dimension, optimal k-means clusters are contiguous in sorted
//! order, so an O(k·n²) DP finds the *global* optimum — no Lloyd
//! restarts, fully deterministic, which matters for reproducible bit
//! allocations (Figures 3-5 must come out identical run to run).

use crate::util::error::{Error, Result};

/// Cluster 1-D values into k groups. Returns per-value cluster ids,
/// numbered by ascending cluster center (0 = smallest).
pub fn cluster_1d(values: &[f64], k: usize) -> Result<Vec<usize>> {
    let n = values.len();
    if k == 0 {
        return Err(Error::config("k must be > 0"));
    }
    if n == 0 {
        return Ok(vec![]);
    }
    let k = k.min(n);

    // sort indices
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap());
    let sorted: Vec<f64> = order.iter().map(|&i| values[i]).collect();

    // prefix sums for O(1) within-cluster SSE
    let mut pre = vec![0.0f64; n + 1];
    let mut pre2 = vec![0.0f64; n + 1];
    for i in 0..n {
        pre[i + 1] = pre[i] + sorted[i];
        pre2[i + 1] = pre2[i] + sorted[i] * sorted[i];
    }
    // SSE of sorted[i..j] (exclusive j)
    let sse = |i: usize, j: usize| -> f64 {
        let cnt = (j - i) as f64;
        if cnt <= 0.0 {
            return 0.0;
        }
        let s = pre[j] - pre[i];
        let s2 = pre2[j] - pre2[i];
        (s2 - s * s / cnt).max(0.0)
    };

    // dp[c][j] = min cost of clustering sorted[0..j] into c+1 clusters
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; n + 1]; k];
    let mut back = vec![vec![0usize; n + 1]; k];
    for j in 1..=n {
        dp[0][j] = sse(0, j);
    }
    for c in 1..k {
        for j in c + 1..=n {
            for split in c..j {
                let cost = dp[c - 1][split] + sse(split, j);
                if cost < dp[c][j] {
                    dp[c][j] = cost;
                    back[c][j] = split;
                }
            }
        }
    }

    // recover boundaries
    let mut boundaries = vec![n];
    let mut j = n;
    // the number of clusters actually used (some may be empty when values
    // have duplicates and k > distinct count — DP handles it by smallest
    // feasible c)
    let mut c = k - 1;
    while c > 0 {
        let split = back[c][j];
        boundaries.push(split);
        j = split;
        c -= 1;
    }
    boundaries.push(0);
    boundaries.reverse(); // [0, b1, ..., n]

    // assign cluster ids in sorted order, then scatter back
    let mut ids_sorted = vec![0usize; n];
    for ci in 0..boundaries.len() - 1 {
        for i in boundaries[ci]..boundaries[ci + 1] {
            ids_sorted[i] = ci;
        }
    }
    let mut out = vec![0usize; n];
    for (pos, &orig) in order.iter().enumerate() {
        out[orig] = ids_sorted[pos];
    }
    Ok(out)
}

/// Cluster centers (means), ascending — diagnostics for the reports.
pub fn centers(values: &[f64], ids: &[usize], k: usize) -> Vec<f64> {
    let mut sums = vec![0.0f64; k];
    let mut counts = vec![0usize; k];
    for (&v, &id) in values.iter().zip(ids) {
        sums[id] += v;
        counts[id] += 1;
    }
    (0..k)
        .map(|i| {
            if counts[i] > 0 {
                sums[i] / counts[i] as f64
            } else {
                f64::NAN
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn well_separated_clusters() {
        let values = [0.1, 0.2, 5.0, 5.1, 10.0, 10.2];
        let ids = cluster_1d(&values, 3).unwrap();
        assert_eq!(ids, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn unsorted_input_scatters_correctly() {
        let values = [10.0, 0.1, 5.0, 0.2, 10.2, 5.1];
        let ids = cluster_1d(&values, 3).unwrap();
        assert_eq!(ids, vec![2, 0, 1, 0, 2, 1]);
    }

    #[test]
    fn k_ge_n_gives_singletons() {
        let values = [3.0, 1.0, 2.0];
        let ids = cluster_1d(&values, 5).unwrap();
        assert_eq!(ids, vec![2, 0, 1]);
    }

    #[test]
    fn k1_single_cluster() {
        let ids = cluster_1d(&[1.0, 2.0, 3.0], 1).unwrap();
        assert_eq!(ids, vec![0, 0, 0]);
    }

    #[test]
    fn optimality_vs_bruteforce() {
        // DP must match exhaustive search on small instances.
        let values = [0.0, 1.0, 1.5, 4.0, 4.1, 9.0, 9.5, 10.0];
        let k = 3;
        let ids = cluster_1d(&values, k).unwrap();
        let cost = |assignment: &[usize]| -> f64 {
            let c = centers(&values, assignment, k);
            values
                .iter()
                .zip(assignment)
                .map(|(&v, &id)| (v - c[id]).powi(2))
                .sum()
        };
        let dp_cost = cost(&ids);
        // brute force over contiguous splits (optimal is contiguous)
        let n = values.len();
        let mut best = f64::INFINITY;
        for b1 in 1..n - 1 {
            for b2 in b1 + 1..n {
                let mut a = vec![0usize; n];
                for i in b1..b2 {
                    a[i] = 1;
                }
                for i in b2..n {
                    a[i] = 2;
                }
                best = best.min(cost(&a));
            }
        }
        assert!((dp_cost - best).abs() < 1e-9, "dp {dp_cost} vs brute {best}");
    }

    #[test]
    fn centers_ascending() {
        let values = [0.1, 5.0, 10.0, 0.2, 5.1];
        let ids = cluster_1d(&values, 3).unwrap();
        let c = centers(&values, &ids, 3);
        assert!(c[0] < c[1] && c[1] < c[2]);
    }

    #[test]
    fn duplicates_dont_crash() {
        let values = [2.0; 10];
        let ids = cluster_1d(&values, 3).unwrap();
        assert_eq!(ids.len(), 10);
    }
}
