//! Typed view over `artifacts/manifest.json` — the contract between the
//! Python build path and the Rust coordinator.
//!
//! aot.py freezes executable argument orders and layer metadata here; the
//! runtime asserts arities at load time so a stale artifacts directory
//! fails loudly instead of mis-executing.

use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::{parse_file, Json};

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub index: usize,
    pub name: String,
    pub kind: String,
    pub act: String,
    pub wshape: Vec<usize>,
    pub params: usize,
    /// Rate-distortion view (paper Eq. 12): n = filter dim, m = #filters.
    pub coding_n: usize,
    pub coding_m: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    /// First/last layers are pinned to 8-bit (paper §4.1).
    pub pinned_8bit: bool,
    /// Residual 1x1 downsample branch (paper §4.5.3 singles these out).
    pub downsample: bool,
    pub sig: String,
    pub calib_step: String,
    pub adaround_step: String,
    pub layer_fwd: String,
    /// K-step fused calibration executables (lax.scan; the hot path).
    pub calib_scan: String,
    pub adaround_scan: String,
}

impl LayerInfo {
    /// Host-executable layer descriptor: a 2-D (conv-as-matmul / linear)
    /// weight with no device artifacts. `kind` is `"conv"` (1×1 conv
    /// over NHWC) or `"linear"` (dense, 4-D input average-pooled first);
    /// `act` is `"relu"` or `"identity"` — see `backend::host` for the
    /// execution convention.
    pub fn host(
        index: usize,
        name: &str,
        kind: &str,
        act: &str,
        wshape: [usize; 2],
        pinned: bool,
    ) -> Self {
        LayerInfo {
            index,
            name: name.to_string(),
            kind: kind.to_string(),
            act: act.to_string(),
            wshape: wshape.to_vec(),
            params: wshape[0] * wshape[1],
            coding_n: wshape[0],
            coding_m: wshape[1],
            in_shape: vec![],
            out_shape: vec![],
            pinned_8bit: pinned,
            downsample: false,
            sig: "host".into(),
            calib_step: String::new(),
            adaround_step: String::new(),
            layer_fwd: String::new(),
            calib_scan: String::new(),
            adaround_scan: String::new(),
        }
    }

    /// Synthetic layer descriptor for tests and benches: an (n × m)
    /// coding view with no device artifacts attached.
    pub fn synthetic(index: usize, coding_n: usize, coding_m: usize, pinned: bool) -> Self {
        LayerInfo {
            index,
            name: format!("l{index}"),
            kind: "conv".into(),
            act: "relu".into(),
            wshape: vec![coding_n, coding_m],
            params: coding_n * coding_m,
            coding_n,
            coding_m,
            in_shape: vec![],
            out_shape: vec![],
            pinned_8bit: pinned,
            downsample: false,
            sig: "synthetic".into(),
            calib_step: String::new(),
            adaround_step: String::new(),
            layer_fwd: String::new(),
            calib_scan: String::new(),
            adaround_scan: String::new(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub fp_acc: f64,
    pub layers: Vec<LayerInfo>,
    pub w_files: Vec<String>,
    pub b_files: Vec<String>,
    pub forward: String,
    pub forward_actq: String,
    pub collect: String,
    pub qat_step: Option<String>,
}

#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub dir: String,
    pub num_classes: usize,
    pub image_hw: usize,
    pub channels: usize,
    pub calib_batch: usize,
    pub eval_batch: usize,
    pub qat_batch: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub dataset: DatasetInfo,
    pub models: Vec<ModelInfo>,
    /// Steps fused per calib_scan invocation (aot.py SCAN_K).
    pub scan_k: usize,
}

/// Marker value for [`Manifest::synthetic`]'s dataset directory: data
/// comes from the in-process generator, never from disk.
pub const SYNTHETIC_DIR: &str = "<synthetic>";

/// The synthetic manifest's model name.
pub const SYNTHETIC_MODEL: &str = "synthnet";

impl Manifest {
    /// An artifact-free manifest for the host backend: the synthetic
    /// dataset geometry (matching `data::synth`) plus a 3-layer
    /// ResNet-style toy model — stem conv → block conv → pooled linear
    /// head, first/last pinned to 8-bit like the zoo models. Models with
    /// empty `w_files` are built in memory by `backend::HostBackend`
    /// (deterministic feature weights + closed-form head), so the whole
    /// pipeline runs with zero files on disk. `fp_acc` starts at 0.0 and
    /// is measured by `experiments::Ctx::synthetic`.
    pub fn synthetic() -> Manifest {
        let layers = vec![
            LayerInfo::host(0, "stem", "conv", "relu", [3, 16], true),
            LayerInfo::host(1, "block", "conv", "relu", [16, 16], false),
            LayerInfo::host(2, "head", "linear", "identity", [16, 16], true),
        ];
        let model = ModelInfo {
            name: SYNTHETIC_MODEL.to_string(),
            fp_acc: 0.0,
            layers,
            w_files: vec![],
            b_files: vec![],
            forward: String::new(),
            forward_actq: String::new(),
            collect: String::new(),
            qat_step: None,
        };
        Manifest {
            root: PathBuf::from(SYNTHETIC_DIR),
            dataset: DatasetInfo {
                dir: SYNTHETIC_DIR.to_string(),
                num_classes: 16,
                image_hw: 32,
                channels: 3,
                calib_batch: 16,
                eval_batch: 64,
                qat_batch: 32,
            },
            models: vec![model],
            scan_k: 4,
        }
    }

    /// Is this the in-memory synthetic manifest (no files behind it)?
    pub fn is_synthetic(&self) -> bool {
        self.dataset.dir == SYNTHETIC_DIR
    }

    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let j = parse_file(&path)?;
        let d = j.get("dataset")?;
        let dataset = DatasetInfo {
            dir: d.get("dir")?.as_str()?.to_string(),
            num_classes: d.get("num_classes")?.as_usize()?,
            image_hw: d.get("image_hw")?.as_usize()?,
            channels: d.get("channels")?.as_usize()?,
            calib_batch: d.get("calib_batch")?.as_usize()?,
            eval_batch: d.get("eval_batch")?.as_usize()?,
            qat_batch: d.get("qat_batch")?.as_usize()?,
        };
        let mut models = Vec::new();
        for (name, m) in j.get("models")?.as_obj()? {
            models.push(parse_model(name, m)?);
        }
        let scan_k = j
            .opt("scan_k")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        Ok(Manifest {
            root,
            dataset,
            models,
            scan_k,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                Error::config(format!(
                    "model {name:?} not in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let mut layers = Vec::new();
    for l in m.get("layers")?.as_arr()? {
        layers.push(LayerInfo {
            index: l.get("index")?.as_usize()?,
            name: l.get("name")?.as_str()?.to_string(),
            kind: l.get("kind")?.as_str()?.to_string(),
            act: l.get("act")?.as_str()?.to_string(),
            wshape: l.get("wshape")?.usize_vec()?,
            params: l.get("params")?.as_usize()?,
            coding_n: l.get("coding_n")?.as_usize()?,
            coding_m: l.get("coding_m")?.as_usize()?,
            in_shape: l.get("in_shape")?.usize_vec()?,
            out_shape: l.get("out_shape")?.usize_vec()?,
            pinned_8bit: l.get("pinned_8bit")?.as_bool()?,
            downsample: l.get("downsample")?.as_bool()?,
            sig: l.get("sig")?.as_str()?.to_string(),
            calib_step: l.get("calib_step")?.as_str()?.to_string(),
            adaround_step: l.get("adaround_step")?.as_str()?.to_string(),
            layer_fwd: l.get("layer_fwd")?.as_str()?.to_string(),
            calib_scan: l
                .opt("calib_scan")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
            adaround_scan: l
                .opt("adaround_scan")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
        });
    }
    // layers must arrive ordered; the pipeline indexes by position.
    for (i, l) in layers.iter().enumerate() {
        if l.index != i {
            return Err(Error::parse(format!(
                "manifest layers out of order at {i} (index {})",
                l.index
            )));
        }
    }
    Ok(ModelInfo {
        name: name.to_string(),
        fp_acc: m.get("fp_acc")?.as_f64()?,
        layers,
        w_files: m.get("w_files")?.str_vec()?,
        b_files: m.get("b_files")?.str_vec()?,
        forward: m.get("forward")?.as_str()?.to_string(),
        forward_actq: m.get("forward_actq")?.as_str()?.to_string(),
        collect: m.get("collect")?.as_str()?.to_string(),
        qat_step: m
            .opt("qat_step")
            .map(|j| j.as_str().map(str::to_string))
            .transpose()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal manifest fixture exercising the full parse path.
    const FIXTURE: &str = r#"{
      "format_version": 1,
      "dataset": {"dir": "data", "num_classes": 16, "image_hw": 32,
                  "channels": 3, "calib_batch": 32, "eval_batch": 128,
                  "qat_batch": 64,
                  "splits": {"calib": {"n": 1024, "seed": 2000}}},
      "models": {
        "m": {
          "fp_acc": 0.9,
          "num_layers": 1,
          "w_files": ["weights/m/00_stem.w.npy"],
          "b_files": ["weights/m/00_stem.b.npy"],
          "forward": "hlo/forward_m.hlo.txt",
          "forward_actq": "hlo/forward_actq_m.hlo.txt",
          "collect": "hlo/collect_m.hlo.txt",
          "layers": [{
            "index": 0, "name": "stem", "kind": "conv", "ksize": 3,
            "stride": 1, "groups": 1, "act": "relu",
            "wshape": [3,3,3,16], "params": 432,
            "coding_n": 27, "coding_m": 16,
            "in_shape": [32,32,32,3], "out_shape": [32,32,32,16],
            "pinned_8bit": true, "downsample": false, "sig": "s",
            "calib_step": "hlo/calib_s.hlo.txt",
            "adaround_step": "hlo/adaround_s.hlo.txt",
            "layer_fwd": "hlo/layerfwd_s.hlo.txt"
          }]
        }
      }
    }"#;

    #[test]
    fn synthetic_manifest_is_host_native() {
        let m = Manifest::synthetic();
        assert!(m.is_synthetic());
        let model = m.model(SYNTHETIC_MODEL).unwrap();
        assert_eq!(model.layers.len(), 3);
        assert!(model.w_files.is_empty(), "synthetic = no files");
        assert!(model.layers.first().unwrap().pinned_8bit);
        assert!(model.layers.last().unwrap().pinned_8bit);
        assert!(!model.layers[1].pinned_8bit);
        // feature widths chain: 3 -> 16 -> 16 -> 16 classes
        assert_eq!(model.layers[0].wshape, vec![3, 16]);
        assert_eq!(model.layers[2].wshape, vec![16, 16]);
        assert!(m.scan_k >= 1);
    }

    #[test]
    fn parses_fixture() {
        let dir = std::env::temp_dir().join(format!("ar_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), FIXTURE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dataset.calib_batch, 32);
        let model = m.model("m").unwrap();
        assert_eq!(model.layers.len(), 1);
        assert!(model.layers[0].pinned_8bit);
        assert!(model.qat_step.is_none());
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
