//! Artifact I/O: the `.npy` codec and the manifest loader.

pub mod manifest;
pub mod npy;
