//! NumPy `.npy` reader/writer (format spec v1.0).
//!
//! The interchange format between the Python build path (weights,
//! datasets) and the Rust coordinator. Reading supports little-endian
//! f32/f64/i32/i64 C-order arrays (everything aot.py emits, plus the f64
//! and i64 defaults NumPy falls back to); writing emits `<f4` / `<i4`.

use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dtype {
    F32,
    F64,
    I32,
    I64,
}

impl Dtype {
    fn from_descr(descr: &str) -> Result<Self> {
        match descr {
            "<f4" | "|f4" => Ok(Dtype::F32),
            "<f8" | "|f8" => Ok(Dtype::F64),
            "<i4" | "|i4" => Ok(Dtype::I32),
            "<i8" | "|i8" => Ok(Dtype::I64),
            other => Err(Error::parse(format!("unsupported npy dtype {other:?}"))),
        }
    }

    fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F64 | Dtype::I64 => 8,
        }
    }
}

struct Header {
    dtype: Dtype,
    shape: Vec<usize>,
}

fn parse_header(text: &str) -> Result<Header> {
    // Python dict literal, e.g.
    // {'descr': '<f4', 'fortran_order': False, 'shape': (1024, 32, 32, 3), }
    let descr = extract_quoted(text, "descr")?;
    let dtype = Dtype::from_descr(&descr)?;
    if text.contains("'fortran_order': True") {
        return Err(Error::parse("fortran-order npy not supported"));
    }
    let shape_src = text
        .split("'shape':")
        .nth(1)
        .ok_or_else(|| Error::parse("npy header missing shape"))?;
    let open = shape_src
        .find('(')
        .ok_or_else(|| Error::parse("npy shape missing '('"))?;
    let close = shape_src
        .find(')')
        .ok_or_else(|| Error::parse("npy shape missing ')'"))?;
    let mut shape = Vec::new();
    for part in shape_src[open + 1..close].split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(
            part.parse::<usize>()
                .map_err(|_| Error::parse(format!("bad npy dim {part:?}")))?,
        );
    }
    Ok(Header { dtype, shape })
}

fn extract_quoted(text: &str, key: &str) -> Result<String> {
    let pat = format!("'{key}':");
    let rest = text
        .split(&pat)
        .nth(1)
        .ok_or_else(|| Error::parse(format!("npy header missing {key}")))?;
    let start = rest
        .find('\'')
        .ok_or_else(|| Error::parse("npy header quote"))?;
    let end = rest[start + 1..]
        .find('\'')
        .ok_or_else(|| Error::parse("npy header quote"))?;
    Ok(rest[start + 1..start + 1 + end].to_string())
}

fn read_header(r: &mut impl Read) -> Result<Header> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        return Err(Error::parse("not an npy file (bad magic)"));
    }
    let (major, _minor) = (magic[6], magic[7]);
    let hlen = if major == 1 {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    let mut htext = vec![0u8; hlen];
    r.read_exact(&mut htext)?;
    parse_header(
        std::str::from_utf8(&htext).map_err(|_| Error::parse("npy header utf-8"))?,
    )
}

/// Read an npy file as f32 (f64 narrowed, integer types converted).
pub fn read_f32(path: &Path) -> Result<Tensor> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| Error::parse(format!("open {}: {e}", path.display())))?;
    let h = read_header(&mut f)?;
    let n: usize = h.shape.iter().product();
    let mut raw = vec![0u8; n * h.dtype.size()];
    f.read_exact(&mut raw)?;
    let data: Vec<f32> = match h.dtype {
        Dtype::F32 => raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        Dtype::F64 => raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
        Dtype::I32 => raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
        Dtype::I64 => raw
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
    };
    Tensor::new(h.shape, data)
}

/// Read an npy file of integer labels as i32.
pub fn read_i32(path: &Path) -> Result<(Vec<usize>, Vec<i32>)> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| Error::parse(format!("open {}: {e}", path.display())))?;
    let h = read_header(&mut f)?;
    let n: usize = h.shape.iter().product();
    let mut raw = vec![0u8; n * h.dtype.size()];
    f.read_exact(&mut raw)?;
    let data: Vec<i32> = match h.dtype {
        Dtype::I32 => raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        Dtype::I64 => raw
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as i32)
            .collect(),
        Dtype::F32 => raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as i32)
            .collect(),
        Dtype::F64 => raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as i32)
            .collect(),
    };
    Ok((h.shape, data))
}

fn header_bytes(descr: &str, shape: &[usize]) -> Vec<u8> {
    let shape_txt = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut dict = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_txt}, }}"
    );
    // total header (magic 8 + len 2 + dict) must be a multiple of 64
    let base = 8 + 2;
    let total = ((base + dict.len() + 1 + 63) / 64) * 64;
    while base + dict.len() + 1 < total {
        dict.push(' ');
    }
    dict.push('\n');
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(MAGIC);
    out.push(1);
    out.push(0);
    out.extend_from_slice(&(dict.len() as u16).to_le_bytes());
    out.extend_from_slice(dict.as_bytes());
    out
}

pub fn write_f32(path: &Path, t: &Tensor) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&header_bytes("<f4", t.shape()))?;
    let mut raw = Vec::with_capacity(t.len() * 4);
    for &v in t.data() {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&raw)?;
    Ok(())
}

pub fn write_i32(path: &Path, shape: &[usize], data: &[i32]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&header_bytes("<i4", shape))?;
    let mut raw = Vec::with_capacity(data.len() * 4);
    for &v in data {
        raw.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&raw)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ar_npy_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.5, -2.0, 0.0, 3.25, 4.0, -5.5]).unwrap();
        let p = tmpfile("f32");
        write_f32(&p, &t).unwrap();
        let back = read_f32(&p).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn i32_roundtrip() {
        let p = tmpfile("i32");
        write_i32(&p, &[4], &[1, -2, 3, 40000]).unwrap();
        let (shape, data) = read_i32(&p).unwrap();
        assert_eq!(shape, vec![4]);
        assert_eq!(data, vec![1, -2, 3, 40000]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn scalar_and_1d_headers() {
        let p = tmpfile("hdr");
        write_f32(&p, &Tensor::scalar(7.0)).unwrap();
        let t = read_f32(&p).unwrap();
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.data(), &[7.0]);
        write_f32(&p, &Tensor::from_vec(vec![1.0, 2.0])).unwrap();
        assert_eq!(read_f32(&p).unwrap().shape(), &[2]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("bad");
        std::fs::write(&p, b"NOTNUMPYATALL").unwrap();
        assert!(read_f32(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn header_is_64_aligned() {
        for shape in [vec![], vec![7], vec![128, 64, 3, 3]] {
            let h = header_bytes("<f4", &shape);
            assert_eq!(h.len() % 64, 0, "shape {shape:?}");
        }
    }
}
