"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contracts: pytest + hypothesis sweep shapes and
dtypes and assert the Pallas kernels (interpret=True) match these
references to float32 tolerance. They are also what the L2 graphs would
fall back to on a backend without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fakequant_ref(w, alpha, s, lo, hi):
    """Attention-Round forward, Eq. (3): ŵ = s·clip(⌊w/s + α⌉, lo, hi).

    `jnp.round` is round-half-to-even; the paper's ⌊·⌉ is unspecified at
    halves — half-to-even is what both layers implement, so the contract
    is consistent across the stack.
    """
    return s * jnp.clip(jnp.round(w / s + alpha), lo, hi)


def attention_grad_ref(g, alpha, tau_over_s):
    """Attention-decay backward rule, Eq. (6).

    dz/dα = 0.5 + 0.5·erf(α/(√2·τ/s)) when the upstream gradient is
    positive, and 0.5 − 0.5·erf(·) otherwise; dL/dα = g · dz/dα.
    τ=0 appears in the Figure-2 sweep; a tiny epsilon keeps the erf
    argument finite there (the rule degenerates to a step function).
    """
    t = jnp.maximum(tau_over_s, 1e-8)
    e = jax.lax.erf(alpha / (jnp.sqrt(2.0) * t))
    dz = jnp.where(g > 0, 0.5 + 0.5 * e, 0.5 - 0.5 * e)
    return g * dz


def qmatmul_ref(x, w, sx, sw, lo_x, hi_x, lo_w, hi_w):
    """Fake-quantized matmul: both operands round-to-nearest quantized,
    accumulated in f32 (the MXU-style reference)."""
    xq = sx * jnp.clip(jnp.round(x / sx), lo_x, hi_x)
    wq = sw * jnp.clip(jnp.round(w / sw), lo_w, hi_w)
    return xq @ wq


def gram_ref(w):
    """Gram matrix W·Wᵀ (rows are the coding-length vectors, Eq. 9)."""
    return w @ w.T


def nearest_round_ref(w, s, lo, hi):
    return s * jnp.clip(jnp.round(w / s), lo, hi)


def coding_length_ref(w2d, eps2):
    """Eq. (12): L(W) = ½·log2 det(I + n/(m·ε²)·W Wᵀ), computed on the
    smaller Gram side (Sylvester's determinant identity)."""
    n, m = w2d.shape  # n = filter dim, m = #filters (paper's W ∈ R^{n×m})
    if n <= m:
        g = w2d @ w2d.T
        eye = jnp.eye(n)
    else:
        g = w2d.T @ w2d
        eye = jnp.eye(m)
    a = eye + (n / (m * eps2)) * g
    sign, logdet = jnp.linalg.slogdet(a)
    return 0.5 * logdet / jnp.log(2.0)
